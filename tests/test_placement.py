"""The unified per-layer weight-placement subsystem (core/placement).

Covers the four consumers of a PlacementPlan: the executable linear
dispatch (models/layers + serving), the analytical memsys walk, the
paging split, and the greedy hot-set budget solver.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memsys, placement, scenarios
from repro.core.memsys import NOMINAL, network_walk, scenario_costs
from repro.core.paging import HostPagedStore, build_pages
from repro.core.perf_model import mnv2_budget_plan, mnv2_plan_walk, \
    mnv2_scenario_table, mobilenet_v2_jobs
from repro.core.placement import (Placement, PlacementPlan, SCENARIOS,
                                  as_plan, linear_dispatch, plan_for_budget)
from repro.core.weight_store import freeze, pack_param, uniform_policy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine


CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)

# hot attention projections stream fused At-MRAM; cold MLP weights are
# paged through the background path (l3flash degrades to l3mram semantics
# inside jit — same numerics, different byte accounting)
MIXED = (PlacementPlan(default=Placement("l1mram", 8, "resident"))
         .with_rule("mlp/*", Placement("l3flash", 8, "paged")))


# ---------------------------------------------------------------------------
# the scenario vocabulary has exactly one home
# ---------------------------------------------------------------------------

def test_single_scenario_definition_site():
    # the analytical and executable stacks share the placement tuple
    assert memsys.SCENARIOS is placement.SCENARIOS
    assert scenarios.SCENARIOS is placement.SCENARIOS
    # the analytical cost table covers exactly the same set
    assert set(scenario_costs(NOMINAL).keys()) == set(SCENARIOS)
    # and every scenario has an executable weight path
    x = jnp.ones((2, 16), jnp.float32)
    p = pack_param(jnp.ones((8, 16), jnp.float32), 8)
    for sc in SCENARIOS:
        assert scenarios.linear_apply(x, p, scenario=sc).shape == (2, 8)


def test_placement_validation():
    with pytest.raises(ValueError):
        Placement("l9mram")
    with pytest.raises(ValueError):
        Placement("l1mram", residency="floating")
    with pytest.raises(ValueError):
        Placement("l1mram", weight_bits=3)


def test_rule_matching_paths():
    plan = (PlacementPlan(default=Placement("l1mram"))
            .with_rule("mlp/*", Placement("l3mram"))
            .with_rule("layers/attn/wq", Placement("l2mram")))
    # short suffix rules match any store prefix (stacked or per-layer)
    assert plan.scenario_for("mlp/w_down") == "l3mram"
    assert plan.scenario_for("layers/mlp/w_down") == "l3mram"
    assert plan.scenario_for("layer07/mlp/w_down") == "l3mram"
    # exact store-path rules (plan_for_budget output) match exactly: the
    # model call sites pass the same canonical "layers/..." path, and a
    # per-layer store path never collides with a stacked-store rule
    assert plan.scenario_for("layers/attn/wq") == "l2mram"
    assert plan.scenario_for("layer00/attn/wq") == "l1mram"
    # everything else falls back to the default
    assert plan.scenario_for("layers/attn/wk") == "l1mram"
    assert plan.scenario_for(None) == "l1mram"
    assert plan.scenarios_used() == ("l3mram", "l2mram", "l1mram")


def test_legacy_engine_interop():
    legacy = dict(scenario="l2mram", mode="xla", bits=4)
    plan = as_plan(legacy)
    assert plan.default == Placement("l2mram", 4, "resident")
    assert linear_dispatch(legacy, "anything") == ("l2mram", "xla", 4)
    assert linear_dispatch(plan, "anything") == ("l2mram", "xla", 4)
    assert linear_dispatch(None, None) == ("l1mram", "xla", 8)
    # plans are hashable (closed over inside jit) and idempotent
    assert as_plan(plan) is plan
    hash(MIXED)


# ---------------------------------------------------------------------------
# executable path: mixed plans through the real model
# ---------------------------------------------------------------------------

def test_uniform_plan_matches_legacy_dict(rng):
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    tokens = jnp.asarray(rng.integers(0, 256, (1, 8)), jnp.int32)
    legacy = tfm.forward(packed, tokens, CFG,
                         engine=dict(scenario="l1mram", mode="xla", bits=8))
    plan = tfm.forward(packed, tokens, CFG, engine=PlacementPlan.uniform())
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(plan))


def test_mixed_plan_bit_exact_vs_uniform(rng):
    """All scenarios share the same math (tested in test_paging_store);
    a mixed plan must therefore be bit-exact against uniform l1mram."""
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 10)), jnp.int32)
    uniform = tfm.forward(packed, tokens, CFG, engine=PlacementPlan.uniform())
    mixed = tfm.forward(packed, tokens, CFG, engine=MIXED)
    np.testing.assert_array_equal(np.asarray(uniform), np.asarray(mixed))


def test_all_placements_equivalent_through_model(rng):
    """Per-scenario uniform plans all agree on one model (numerical
    equivalence of the four weight paths at model scale)."""
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    tokens = jnp.asarray(rng.integers(0, 256, (1, 8)), jnp.int32)
    outs = {sc: np.asarray(tfm.forward(packed, tokens, CFG,
                                       engine=PlacementPlan.uniform(sc)))
            for sc in SCENARIOS}
    for sc in SCENARIOS:
        np.testing.assert_allclose(outs[sc], outs["l1mram"], rtol=2e-4,
                                   atol=2e-4)


def test_serving_engine_mixed_plan_matches_uniform(rng):
    """A mixed plan (hot attn resident/l1mram, cold mlp paged/l3flash)
    serves end-to-end through ServingEngine with the same tokens as the
    uniform l1mram plan."""
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    prompts = [rng.integers(0, 256, 4 + i).astype(np.int32) for i in range(4)]

    def serve(plan):
        eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64, plan=plan)
        for uid, prompt in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
        return {r.uid: r.generated for r in eng.run_until_done()}

    uniform = serve(PlacementPlan.uniform())
    mixed = serve(MIXED)
    assert uniform == mixed
    # legacy engine dict still supported and agrees
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        engine=dict(scenario="l1mram", mode="xla", bits=8))
    for uid, prompt in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
    legacy = {r.uid: r.generated for r in eng.run_until_done()}
    assert legacy == uniform


def test_per_param_bits_from_plan(rng):
    """freeze_for_serving(plan=...) packs each parameter at the plan's
    bits, and the dispatch reads them back consistently: the plan-frozen
    store behaves bit-identically to a hand-spliced mixed-precision one."""
    plan = (PlacementPlan(default=Placement("l1mram", 8))
            .with_rule("mlp/*", Placement("l1mram", 4)))
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8, plan=plan)
    w8 = params["layers"]["attn"]["wq"]
    w4 = params["layers"]["mlp"]["w_down"]
    p8 = packed["layers"]["attn"]["wq"]["packed"]
    p4 = packed["layers"]["mlp"]["w_down"]["packed"]
    assert p8.shape[-1] == w8.shape[-1]          # 8-bit: 1 byte/weight
    assert p4.shape[-1] == w4.shape[-1] // 2     # 4-bit: 2 weights/byte
    # splice a reference store by hand: mlp subtree from a uniform 4-bit
    # freeze, everything else uniform 8-bit — must match the plan freeze
    spliced = freeze_for_serving(params, bits=8)
    spliced["layers"]["mlp"] = freeze_for_serving(
        params, bits=4)["layers"]["mlp"]
    tokens = jnp.asarray(rng.integers(0, 256, (1, 8)), jnp.int32)
    out = tfm.forward(packed, tokens, CFG, engine=plan)
    ref = tfm.forward(spliced, tokens, CFG, engine=plan)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_engineconfig_plan_and_plan_apply(rng):
    """The typed EngineConfig front-end and scenarios.plan_apply resolve
    the same per-path placement as the layers.linear dispatch."""
    from repro.core import engine as core_engine
    from repro.core.scenarios import plan_apply

    x = jnp.asarray(rng.normal(size=(3, 32)), jnp.float32)
    p = pack_param(jnp.asarray(rng.normal(size=(16, 32)), jnp.float32), 8)
    plan = (PlacementPlan.uniform("l1mram")
            .with_rule("cold/*", Placement("l3mram")))
    cfg = core_engine.EngineConfig.from_plan(plan)
    assert cfg.plan is plan and cfg.mode == "xla"
    assert cfg.scenario_for("cold/w") == "l3mram"
    assert cfg.scenario_for("hot/w") == "l1mram"
    assert cfg.scenario_for(None) == "l1mram"
    ref = np.asarray(scenarios.linear_apply(x, p, scenario="l1mram"))
    for out in (core_engine.linear(x, p, cfg, path="hot/w"),
                core_engine.linear(x, p, cfg, path="cold/w"),
                plan_apply(x, p, plan, "cold/w")):
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5,
                                   atol=1e-5)


def test_serve_specs_match_frozen_layout_under_plan():
    """serve_spec_like(plan=...) mirrors freeze_for_serving(plan=...) so
    dry-run specs and real packed arrays stay layout-consistent under
    mixed-precision plans."""
    from repro.launch.steps import serve_param_specs

    plan = (PlacementPlan(default=Placement("l1mram", 8))
            .with_rule("mlp/*", Placement("l1mram", 4)))
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8, plan=plan)
    specs = serve_param_specs(CFG, plan=plan)
    real = {placement.path_key(p): l for p, l
            in jax.tree_util.tree_flatten_with_path(packed)[0]}
    spec = {placement.path_key(p): l for p, l
            in jax.tree_util.tree_flatten_with_path(specs)[0]}
    assert real.keys() == spec.keys()
    for k in real:
        assert tuple(real[k].shape) == tuple(spec[k].shape), k
    # packed_sizes reads exactly the dispatchable packed leaves
    sizes = placement.packed_sizes(packed)
    assert all(k.endswith(tuple(
        ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"))) for k in sizes)
    assert sizes["layers/mlp/w_down"] == real["layers/mlp/w_down/packed"].size


def test_encdec_mixed_plan_matches_uniform(rng):
    """The enc-dec zoo threads placement paths too: a plan that cools the
    cross-attention weights is bit-exact vs the uniform plan."""
    from repro.configs import get_config
    from repro.models import encdec

    cfg = get_config("whisper-tiny").smoke()
    params = encdec.init_params(cfg, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    frames = jnp.asarray(rng.normal(size=(1, cfg.n_audio_frames,
                                          cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    mixed = (PlacementPlan.uniform("l1mram")
             .with_rule("dec_layers/xattn/*", Placement("l3mram", 8,
                                                        "paged")))
    outs = {}
    for name, plan in (("uniform", PlacementPlan.uniform()),
                       ("mixed", mixed)):
        enc_out = encdec.encode(packed, frames, cfg, engine=plan)
        outs[name] = np.asarray(encdec.decode(packed, tokens, enc_out, cfg,
                                              engine=plan))
    np.testing.assert_array_equal(outs["uniform"], outs["mixed"])


def test_freeze_policy_takes_bits_from_plan(rng):
    plan = (PlacementPlan(default=Placement("l1mram", 8))
            .with_rule("layer00/*", Placement("l3flash", 4, "paged")))
    params = {f"layer{i:02d}": dict(w=jnp.asarray(rng.normal(size=(32, 32)),
                                                  jnp.float32))
              for i in range(2)}
    store = freeze(params, placement.freeze_policy(plan, min_size=16))
    assert store.params["layer00/w"].bits == 4
    assert store.params["layer01/w"].bits == 8
    assert (store.params["layer00/w"].nbytes_packed * 2
            == store.params["layer01/w"].nbytes_packed)


# ---------------------------------------------------------------------------
# budget solver + paging split
# ---------------------------------------------------------------------------

def _store(rng, n=8, d=32):
    params = {f"layer{i:02d}": dict(w=jnp.asarray(rng.normal(size=(d, d)),
                                                  jnp.float32))
              for i in range(n)}
    return freeze(params, uniform_policy(8, min_size=16))


def test_plan_for_budget_respects_budget(rng):
    store = _store(rng)                          # 8 equal 1 KiB params
    per = 32 * 32
    for k in range(9):
        plan = plan_for_budget(store, budget_bytes=k * per)
        assert plan.resident_bytes(store) <= k * per
        assert len(plan.rules) == k
        assert plan.fits(store, k * per)
        assert (plan.resident_bytes(store) + plan.paged_bytes(store)
                == store.packed_bytes)
    # zero budget: everything paged, default is the cold scenario
    plan0 = plan_for_budget(store, budget_bytes=0)
    assert plan0.default.paged and plan0.default.scenario == "l3flash"


def test_plan_for_budget_pins_highest_traffic(rng):
    sizes = {"big": 1000, "mid": 500, "small": 100}
    plan = plan_for_budget(sizes, budget_bytes=1100)
    resident, paged = plan.split_names(list(sizes))
    assert resident == ["big", "small"]          # big first, mid won't fit
    assert paged == ["mid"]
    # `uses` weighting flips the order: small is read 20x per inference
    plan = plan_for_budget(sizes, budget_bytes=600,
                           uses={"small": 20.0})
    resident, _ = plan.split_names(list(sizes))
    assert resident == ["mid", "small"]          # scores: 2000, 1000, 500


def test_build_pages_and_store_honour_plan(rng):
    store = _store(rng, n=6)
    per = 32 * 32
    plan = plan_for_budget(store, budget_bytes=2 * per)
    pages = build_pages(store, page_bytes=2 * per, plan=plan)
    paged_names = [n for p in pages for n in p.param_names]
    resident, paged = plan.split_names(list(store.params.keys()))
    assert paged_names == paged and len(resident) == 2

    hps = HostPagedStore(store, page_bytes=2 * per, plan=plan)
    assert sorted(hps.resident) == sorted(resident)
    streamed = dict(hps.resident)
    for page, dev_params in hps.stream():
        streamed.update(dev_params)
    assert sorted(streamed) == sorted(store.params)
    for name, p in store.params.items():
        np.testing.assert_array_equal(np.asarray(streamed[name].packed),
                                      np.asarray(p.packed))
    hps.close()


def test_weight_path_bytes_is_static_int():
    p = pack_param(jnp.ones((16, 32), jnp.float32), 8)
    for sc in SCENARIOS:
        b = scenarios.weight_path_bytes(p, sc)
        assert type(b) is int                    # no device round-trip


# ---------------------------------------------------------------------------
# analytical model: per-layer scenario walks
# ---------------------------------------------------------------------------

def test_uniform_plan_walk_matches_uniform_scenario():
    jobs = mobilenet_v2_jobs()
    for sc in SCENARIOS:
        t_str, e_str, _ = network_walk(jobs, sc)
        t_pln, e_pln, _ = network_walk(jobs, PlacementPlan.uniform(sc))
        assert t_pln == pytest.approx(t_str)
        assert e_pln == pytest.approx(e_str)


def test_per_layer_sequence_walk():
    jobs = mobilenet_v2_jobs()
    seq = ["l1mram"] * len(jobs)
    t_seq, e_seq, _ = network_walk(jobs, seq)
    t_uni, e_uni, _ = network_walk(jobs, "l1mram")
    assert t_seq == pytest.approx(t_uni) and e_seq == pytest.approx(e_uni)
    with pytest.raises(ValueError):
        network_walk(jobs, ["l1mram"] * (len(jobs) - 1))


def test_mixed_plan_walk_between_extremes():
    """The 2 MiB-budget mixed plan lands strictly between uniform l3flash
    and uniform l1mram on both latency and energy (Fig 10 interpolation)."""
    tab = mnv2_scenario_table()
    plan = mnv2_budget_plan(2 * 1024 * 1024)
    assert 0 < len(plan.rules) < len(mobilenet_v2_jobs())
    tm, em, _ = mnv2_plan_walk(plan)
    assert tab["l1mram"][0] < tm < tab["l3flash"][0]
    assert tab["l1mram"][1] < em < tab["l3flash"][1]

"""Encoded (compressed) cold pages: the page codec, the wire/device byte
split, and the fetch-side decode — end to end from quantize_blockwise up
through HostPagedStore and ServingEngine.

Byte vocabulary (see core/paging.Page): *device* bytes are the packed
buffer a page occupies in the pool budget; *wire* bytes are what crosses
the host->device link (encoded payload + scales); *raw* bytes are the
fp32-dense equivalent the compression ratio is quoted against.
"""

import jax
import numpy as np
import pytest

from repro.core import packing, paging, quantize
from repro.core.memsys import encoded_wire_bytes
from repro.core.paging import (HostPagedStore, build_pages,
                               encode_host_param, page_roundtrip_param,
                               page_sizes, packed_tree_store, thread_packed)
from repro.core.placement import Placement, PlacementPlan, plan_for_budget
from repro.core.weight_store import freeze, uniform_policy
from repro.kernels.qmatmul import qmatmul_f32, qmatmul_f32_blockscale
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine

BLOCK = quantize.PAGE_SCALE_BLOCK


def _params(rng, n_layers=6, d=64):
    return {f"layer{i}": dict(w=np.asarray(rng.normal(size=(d, d)),
                                           np.float32))
            for i in range(n_layers)}


# ---------------------------------------------------------------------------
# the blockwise page codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [31, 33, 70, 2 * BLOCK + 5])
def test_blockwise_codec_roundtrip_int4_odd_k(rng, k):
    """int4 blockwise quantization at K NOT a multiple of the scale block:
    the tail block carries its own scale and the reconstruction error is
    bounded by half an LSB of each block's scale."""
    w = np.asarray(rng.normal(size=(9, k)), np.float32)
    levels, scales = quantize.quantize_blockwise(w, 4)
    assert levels.shape == w.shape and levels.dtype == np.int8
    assert scales.shape == (9, -(-k // BLOCK))
    lo, hi = quantize.weight_qrange(4)
    assert levels.min() >= lo and levels.max() <= hi
    deq = quantize.dequantize_blockwise(levels, scales)
    assert deq.shape == w.shape
    # per-(row, block) half-LSB bound: |w - deq| <= scale/2 elementwise
    nblk = scales.shape[1]
    bound = np.repeat(scales, BLOCK, axis=1)[:, :k] * 0.5 + 1e-7
    assert (np.abs(w - deq) <= bound).all()
    # the codec is a projection: re-encoding its output is lossless
    levels2, scales2 = quantize.quantize_blockwise(deq, 4)
    np.testing.assert_array_equal(levels2, levels)
    np.testing.assert_allclose(scales2, scales, rtol=1e-6)


@pytest.mark.parametrize("channels,k", [(7, 50), (33, 70), (50, 33)])
def test_quantize_weights_roundtrip_int4_odd_channels(rng, channels, k):
    """Per-channel int4 quantize -> dequantize at channel counts that are
    NOT multiples of the packing factor or scale block."""
    w = np.asarray(rng.normal(size=(channels, k)), np.float32)
    qt = quantize.quantize_weights(w, 4, channel_axis=0)
    deq = np.asarray(qt.dequantize())
    scale = np.asarray(qt.scale).reshape(channels, 1)
    assert (np.abs(w - deq) <= scale * 0.5 + 1e-7).all()
    # round trip: requantizing the dequantized weights is the identity
    qt2 = quantize.quantize_weights(deq, 4, channel_axis=0)
    np.testing.assert_array_equal(np.asarray(qt2.values),
                                  np.asarray(qt.values))


# ---------------------------------------------------------------------------
# kernels on odd shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,k", [(8, 77), (4, 51), (2, 33)])
def test_qmatmul_f32_matches_dequant_reference_odd_k(rng, bits, k):
    """Pallas qmatmul vs the dequantized-matmul oracle on K values that
    leave a ragged tail in every packing factor.  Both paths accumulate
    in f32 and differ only in summation order (the kernel reduces over
    zero-padded bk blocks), so agreement is tight: rtol 1e-5."""
    m, n = 5, 13
    x = np.asarray(rng.normal(size=(m, k)), np.float32)
    w = np.asarray(rng.normal(size=(n, k)), np.float32)
    qt = quantize.quantize_weights(w, bits, channel_axis=0)
    packed = packing.pack(qt.values, bits)
    out = qmatmul_f32(jax.numpy.asarray(x), packed, qt.scale, bits=bits,
                      k_orig=k, bm=16, bn=16, bk=32, interpret=True)
    expect = quantize.dequant_matmul_reference(jax.numpy.asarray(x), qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits,k", [(8, 70), (4, 2 * BLOCK + 5)])
def test_qmatmul_blockscale_matches_blockwise_reference(rng, bits, k):
    """The wire-form kernel (per-block scales applied inside the
    reduction) equals x @ dequantize_blockwise(...)^T — the page codec's
    decoded form — to the same f32 summation-order tolerance."""
    m, n = 4, 9
    x = np.asarray(rng.normal(size=(m, k)), np.float32)
    w = np.asarray(rng.normal(size=(n, k)), np.float32)
    levels, scales = quantize.quantize_blockwise(w, bits)
    packed = packing.pack(levels, bits)
    out = qmatmul_f32_blockscale(jax.numpy.asarray(x), packed,
                                 jax.numpy.asarray(scales), bits=bits,
                                 k_orig=k, block=BLOCK, bm=16, bn=16,
                                 bk=2 * BLOCK, interpret=True)
    expect = x @ quantize.dequantize_blockwise(levels, scales).T
    np.testing.assert_allclose(np.asarray(out), expect,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# wire-byte accounting
# ---------------------------------------------------------------------------

def test_encoded_wire_bytes_matches_codec_buffers(rng):
    """The closed form the StallModel/planner charges equals the actual
    byte size of the codec's output buffers, including ragged tails."""
    for rows, k, page_bits in [(6, 64, 4), (5, 33, 2), (9, 70, 8)]:
        w = np.asarray(rng.normal(size=(rows, k)), np.float32)
        store = freeze({"p": dict(w=w)}, uniform_policy(8, min_size=1))
        hp = encode_host_param(store.params["p/w"], page_bits)
        want = encoded_wire_bytes(rows, k, page_bits, BLOCK)
        if page_bits == 8:
            # identity: the wire form is the device form + channel scales
            assert hp.wire_nbytes == (store.params["p/w"].nbytes_packed
                                      + rows * 4)
        else:
            assert hp.wire_nbytes == want


def test_page_wire_split_and_compression(rng):
    """build_pages splits every page's bytes three ways; the int8
    identity encoding moves ~wire/raw <= 0.3 of the fp32 dense bytes."""
    store = freeze(_params(rng), uniform_policy(8, min_size=16))
    plan = (PlacementPlan.uniform("l3flash", bits=8, residency="paged")
            .with_page_bits(8))
    pages = build_pages(store, page_bytes=3 * 64 * 64, plan=plan)
    for p in pages:
        assert p.encoding == "int8"
        assert p.wire_nbytes > p.nbytes          # channel scales ride along
        assert p.raw_nbytes > p.wire_nbytes      # fp32 dense >> int8 wire
    wire = sum(p.wire_nbytes for p in pages)
    raw = sum(p.raw_nbytes for p in pages)
    assert wire / raw <= 0.3 and raw / wire >= 3.5
    # fp pages: nothing encoded -> nothing saved (raw == wire)
    fp_pages = build_pages(store, page_bytes=3 * 64 * 64,
                           plan=PlacementPlan.uniform(
                               "l3flash", bits=8, residency="paged"))
    assert all(p.encoding == "fp" and p.raw_nbytes == p.wire_nbytes
               for p in fp_pages)
    # page_sizes hands the (device, wire, raw) triples to the predictors
    assert page_sizes(pages) == [(p.nbytes, p.wire_nbytes, p.raw_nbytes)
                                 for p in pages]


def test_build_pages_mixed_encodings_never_share_page(rng):
    """Params of different wire encodings must not share a page (a page
    decodes as one unit), even when their bytes would fit."""
    store = freeze(_params(rng, n_layers=4), uniform_policy(8, min_size=16))
    names = list(store.params)
    plan = PlacementPlan(default=Placement("l3flash", 8, "paged", None))
    plan = plan.with_rule(names[1], Placement("l3flash", 8, "paged", 4))
    pages = build_pages(store, page_bytes=10 * 64 * 64, plan=plan)
    assert len(pages) == 3                       # fp | int4 | fp
    assert [p.encoding for p in pages] == ["fp", "int4", "fp"]
    assert pages[1].param_names == (names[1],)


def test_build_pages_oversized_error_names_plan_path(rng):
    store = freeze(_params(rng, n_layers=2), uniform_policy(8, min_size=16))
    plan = PlacementPlan.uniform("l3flash", bits=8, residency="paged")
    with pytest.raises(ValueError, match=r"plan path .* l3flash/8b/fp.*"
                                         r"set page_bytes >= 4096"):
        build_pages(store, page_bytes=64 * 64 - 1, plan=plan)
    with pytest.raises(ValueError, match=r"param .*\(fp\)"):
        build_pages(store, page_bytes=64 * 64 - 1)


def test_plan_for_budget_bits_aware_and_tie_break():
    sizes = {"b": 100, "a": 100, "c": 50}
    # bits-aware budget: a 100-byte int8-measured param costs 50 B
    # resident at int4, so a 100 B budget pins BOTH ties
    plan = plan_for_budget(sizes, 100,
                           hot=Placement("l1mram", 4, "resident"),
                           cold=Placement("l3flash", 4, "paged"),
                           sizes_bits=8)
    resident, _ = plan.split_names(list(sizes))
    assert sorted(resident) == ["a", "b"]
    # deterministic tie-break: equal score + size falls back to the name,
    # independent of dict insertion order
    fwd = plan_for_budget({"b": 100, "a": 100}, 100)
    rev = plan_for_budget({"a": 100, "b": 100}, 100)
    assert fwd.rules == rev.rules
    assert [n for n, _ in fwd.rules] == ["a"]


# ---------------------------------------------------------------------------
# host store: encode at build, decode at fetch
# ---------------------------------------------------------------------------

def test_host_param_identity_decode_is_passthrough(rng):
    store = freeze(_params(rng, n_layers=1), uniform_policy(8, min_size=16))
    p = store.params["layer0/w"]
    for page_bits in (None, 8):                  # fp and run-quantized id.
        hp = encode_host_param(p, page_bits)
        packed, scale = hp.decode()
        np.testing.assert_array_equal(packed, np.asarray(p.packed))
        np.testing.assert_array_equal(scale, np.asarray(p.scale))


def test_host_param_reencode_decode_matches_roundtrip(rng):
    """A re-encoded param holds ONLY the compressed image; decode
    reconstructs the device form deterministically and equals the
    page_roundtrip_param reference transform."""
    store = freeze(_params(rng, n_layers=1), uniform_policy(8, min_size=16))
    p = store.params["layer0/w"]
    hp = encode_host_param(p, 4)
    assert hp.payload.nbytes + hp.scales.nbytes == hp.wire_nbytes
    assert hp.wire_nbytes < p.nbytes_packed      # int4 wire < int8 device
    packed, scale = hp.decode()
    rt = page_roundtrip_param(p, 4)
    np.testing.assert_array_equal(packed, np.asarray(rt.packed))
    np.testing.assert_allclose(scale, np.asarray(rt.scale), rtol=1e-6)
    # decode is idempotent/deterministic
    packed2, scale2 = hp.decode()
    np.testing.assert_array_equal(packed, packed2)
    np.testing.assert_array_equal(scale, scale2)


@pytest.mark.parametrize("page_bits", [None, 8])
def test_encoded_store_streams_bit_exact(rng, page_bits):
    """fp and identity encodings stream the exact device bytes; the wire
    ledger equals the sum of the streamed pages' wire sizes."""
    store = freeze(_params(rng), uniform_policy(8, min_size=16))
    plan = PlacementPlan.uniform("l3flash", bits=8, residency="paged")
    if page_bits is not None:
        plan = plan.with_page_bits(page_bits)
    paged = HostPagedStore(store, page_bytes=2 * 64 * 64, plan=plan)
    streamed = {}
    for page, dev_params in paged.stream():
        streamed.update(dev_params)
    for name, p in store.params.items():
        np.testing.assert_array_equal(np.asarray(streamed[name].packed),
                                      np.asarray(p.packed))
        np.testing.assert_array_equal(np.asarray(streamed[name].scale),
                                      np.asarray(p.scale))
    assert paged.bytes_streamed_wire == sum(p.wire_nbytes
                                            for p in paged.pages)
    assert paged.bytes_streamed_raw == sum(p.raw_nbytes
                                           for p in paged.pages)
    paged.close()


def test_encoded_store_lossy_stream_matches_roundtrip(rng):
    """int4 pages under an int8 store are lossy but deterministic: the
    fetched device bytes equal the page_roundtrip_param reference, and
    the wire ledger shows real compression."""
    store = freeze(_params(rng), uniform_policy(8, min_size=16))
    plan = (PlacementPlan.uniform("l3flash", bits=8, residency="paged")
            .with_page_bits(4))
    paged = HostPagedStore(store, page_bytes=2 * 64 * 64, plan=plan)
    streamed = {}
    for page, dev_params in paged.stream():
        streamed.update(dev_params)
    for name, p in store.params.items():
        rt = page_roundtrip_param(p, 4)
        np.testing.assert_array_equal(np.asarray(streamed[name].packed),
                                      np.asarray(rt.packed))
    assert paged.bytes_streamed_wire < paged.bytes_streamed_raw / 5
    assert paged.decode_s >= 0.0
    paged.close()


# ---------------------------------------------------------------------------
# serving end-to-end
# ---------------------------------------------------------------------------

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)


def _serve(cfg, packed, plan, prompts):
    from repro.core.placement import packed_sizes
    eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64, plan=plan)
    if plan.paged_bytes(packed_sizes(packed)) > 0:
        eng.attach_paging()
    for uid, prompt in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
    toks = {r.uid: r.generated for r in eng.run_until_done()}
    if eng.pager is not None:
        eng.pager.close()
    return toks, eng


def test_serving_encoded_pages_bit_exact_and_lossy(rng):
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    prompts = [rng.integers(0, 256, 4 + i).astype(np.int32)
               for i in range(4)]
    from repro.core.placement import packed_sizes
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)

    resident, _ = _serve(CFG, packed, PlacementPlan.uniform(), prompts)
    # fp and run-quantized identity encodings: bit-exact vs resident
    for page_bits in (None, 8):
        p = plan if page_bits is None else plan.with_page_bits(page_bits)
        got, eng = _serve(CFG, packed, p, prompts)
        assert got == resident
        if page_bits == 8:
            pg = eng.paging_summary()
            assert 0 < pg["bytes_streamed_wire"] <= \
                0.3 * pg["bytes_streamed_raw"]
    # lossy int4 pages == serving the round-tripped tree fully resident
    plan4 = plan.with_page_bits(4)
    store = packed_tree_store(packed, plan4)
    rt = {n: page_roundtrip_param(p, 4) for n, p in store.params.items()
          if plan4.placement_for(n).paged}
    assert rt, "plan paged nothing; the lossy leg tests nothing"
    want, _ = _serve(CFG, thread_packed(packed, rt),
                     PlacementPlan.uniform(), prompts)
    got, _ = _serve(CFG, packed, plan4, prompts)
    assert got == want

"""Serving engine + packed-store (At-MRAM) serving correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine, sample_token


CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)


def test_continuous_batching_matches_offline(rng):
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    eng = ServingEngine(CFG, params, batch_slots=3, max_len=64)
    reqs = {}
    for uid in range(5):
        r = Request(uid=uid, prompt=rng.integers(0, 256, 4 + uid).astype(np.int32),
                    max_new_tokens=5)
        reqs[uid] = r
        eng.submit(r)
    done = eng.run_until_done()
    assert sorted(r.uid for r in done) == list(range(5))
    for uid, r in reqs.items():
        toks = jnp.asarray(r.prompt)[None]
        for t in range(5):
            lg = tfm.forward(params, toks, CFG)
            nt = jnp.argmax(lg[:, -1], -1)
            assert r.generated[t] == int(nt[0]), f"uid {uid} tok {t}"
            toks = jnp.concatenate([toks, nt[:, None]], 1)


def test_packed_serving_close_to_dense(rng):
    """W8 packed serving (the At-MRAM path) tracks the dense model."""
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 12)), jnp.int32)
    dense = tfm.forward(params, tokens, CFG)
    quant = tfm.forward(packed, tokens, CFG,
                        engine=dict(scenario="l1mram", mode="xla", bits=8))
    # top-1 predictions should agree at int8 for nearly every position
    agree = np.mean(np.asarray(jnp.argmax(dense, -1) == jnp.argmax(quant, -1)))
    assert agree > 0.9
    # store density: packed leaves are ~1 byte/weight vs 4 (f32)
    n_dense = sum(l.size * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(params))
    n_packed = sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(packed))
    assert n_packed < 0.55 * n_dense


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_packed_bits_density(bits, rng):
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=bits)

    def packed_bytes(tree):
        return sum(l.size for p, l in
                   jax.tree_util.tree_flatten_with_path(tree)[0]
                   if l.dtype == jnp.uint8)

    b = packed_bytes(packed)
    b8 = packed_bytes(freeze_for_serving(params, bits=8))
    assert b == pytest.approx(b8 * bits / 8, rel=0.02)


def test_scenarios_identical_through_model(rng):
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    tokens = jnp.asarray(rng.integers(0, 256, (1, 8)), jnp.int32)
    outs = {}
    for sc in ("l1mram", "l2mram", "l3mram"):
        outs[sc] = np.asarray(tfm.forward(
            packed, tokens, CFG, engine=dict(scenario=sc, mode="xla",
                                             bits=8)))
    np.testing.assert_allclose(outs["l2mram"], outs["l1mram"], rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(outs["l3mram"], outs["l1mram"], rtol=2e-4,
                               atol=2e-4)


def test_sampler():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_token(logits, jax.random.PRNGKey(0), 0.0)[0]) == 1
    # top-k=1 equals greedy even at temperature
    assert int(sample_token(logits, jax.random.PRNGKey(1), 1.0, top_k=1)[0]) == 1


def test_paged_serving_stream(rng):
    """HostPagedStore streams layer pages through a tight budget and the
    model still computes correctly (the >8MiB-network path of §II-B2)."""
    from repro.core.paging import HostPagedStore
    from repro.core.weight_store import freeze, uniform_policy

    params = {f"l{i}": dict(w=jnp.asarray(rng.normal(size=(64, 64)),
                                          jnp.float32)) for i in range(6)}
    store = freeze(params, uniform_policy(8, min_size=16))
    paged = HostPagedStore(store, page_bytes=2 * 64 * 64)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    # run "layers" in page order, weights arriving from the paged stream
    y = x
    from repro.core import scenarios
    for page, dev_params in paged.stream():
        for name in page.param_names:
            y = jnp.tanh(scenarios.linear_apply(y, dev_params[name]))
    assert y.shape == (4, 64)
    assert paged.miss_count == 1     # proactive prefetch hid all but cold
    paged.close()

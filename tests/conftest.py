# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the 512-device override belongs ONLY to
# launch/dryrun.py).  Multi-device behaviour is tested via subprocesses
# (tests/test_multidevice.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""Property tests (hypothesis) for the quantization/packing substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional test dependency (the [test] extra in pyproject.toml): skip the
# property-test module instead of erroring the whole collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packing, quantize

BITS = st.sampled_from([2, 4, 8])


@given(bits=BITS,
       shape=st.tuples(st.integers(1, 5), st.integers(1, 33)))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(bits, shape):
    lo, hi = quantize.weight_qrange(bits)
    rng = np.random.default_rng(sum(shape) + bits)
    levels = jnp.asarray(rng.integers(lo, hi + 1, shape), jnp.int8)
    packed = packing.pack(levels, bits)
    # density: packed bytes == ceil(K / factor) per row
    assert packed.shape[-1] == packing.packed_last_dim(shape[-1], bits)
    out = packing.unpack(packed, bits, shape[-1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(levels))


@given(bits=BITS, k=st.integers(1, 40))
@settings(max_examples=30, deadline=None)
def test_bitplane_roundtrip(bits, k):
    lo, hi = quantize.weight_qrange(bits)
    rng = np.random.default_rng(k * 7 + bits)
    levels = jnp.asarray(rng.integers(lo, hi + 1, (3, k)), jnp.int8)
    planes = packing.to_bitplanes(levels, bits)
    assert planes.shape == (bits, 3, k)
    out = packing.from_bitplanes(planes, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(levels))


@given(bits=BITS)
@settings(max_examples=20, deadline=None)
def test_quantize_weights_range_and_sign(bits):
    rng = np.random.default_rng(bits)
    w = jnp.asarray(rng.normal(size=(6, 50)), jnp.float32)
    qt = quantize.quantize_weights(w, bits)
    lo, hi = quantize.weight_qrange(bits)
    vals = np.asarray(qt.values)
    assert vals.min() >= lo and vals.max() <= hi
    # zero rows stay zero; scale positive
    assert (np.asarray(qt.scale) > 0).all()
    # dequantized error bounded by scale/2 per element
    deq = np.asarray(qt.dequantize())
    err = np.abs(deq - np.asarray(w))
    bound = np.asarray(qt.scale)[:, None] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_quantize_zero_tensor():
    qt = quantize.quantize_weights(jnp.zeros((4, 16)), 4)
    assert np.asarray(qt.values).max() == 0
    np.testing.assert_array_equal(np.asarray(qt.dequantize()),
                                  np.zeros((4, 16), np.float32))


def test_requant_integer_projection(rng):
    """quantize.requantize matches the true int64 NEMO projection within
    1 LSB (the silicon's 48-bit intermediate, emulated in f32)."""
    acc = jnp.asarray(rng.integers(-2**20, 2**20, (64,)), jnp.int32)
    w_scale = jnp.asarray(rng.uniform(1e-3, 1e-2, (64,)), jnp.float32)
    rq = quantize.fold_requant(w_scale, 0.05, 0.05, None)
    out = quantize.requantize(acc, rq)
    # true integer oracle in numpy int64
    prod = np.asarray(acc, np.int64) * np.asarray(rq.mult, np.int64)
    exact = (prod + (1 << (rq.shift - 1))) >> rq.shift
    exact = np.clip(exact + np.asarray(rq.bias, np.int64), 0, 255)
    assert (np.abs(out.astype(np.int64) - exact) <= 1).all()


def test_fake_quant_ste_gradient():
    w = jnp.linspace(-1.0, 1.0, 32).reshape(2, 16)
    g = jax.grad(lambda w: jnp.sum(quantize.fake_quant_weights(w, 4)))(w)
    # straight-through: gradient flows (not all zero)
    assert float(jnp.abs(g).sum()) > 0


def test_fake_quant_on_grid():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    fq = quantize.fake_quant_weights(w, 4)
    qt = quantize.quantize_weights(fq, 4)
    # fake-quantized weights are fixed points of quantization
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(fq),
                               rtol=1e-5, atol=1e-6)


def test_activation_quantization(rng):
    x = jnp.asarray(rng.normal(size=(1000,)) * 3 + 2, jnp.float32)
    scale, zp = quantize.calibrate_activation_scale(x)
    q = quantize.quantize_activations(x, scale, zp)
    deq = (q.astype(jnp.float32) - zp) * scale
    # reconstruction error bounded by one step
    assert float(jnp.max(jnp.abs(deq - jnp.clip(x, (0 - zp) * scale,
                                                (255 - zp) * scale)))) <= float(scale) * 0.51 + 1e-6

"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes / dtypes / weight bit-widths.  Integer paths must match
bit-exactly; float paths to accumulation tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, quantize
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.qmatmul import qmatmul_f32, qmatmul_int8
from repro.kernels import neureka_conv as nkc

BITS = (2, 4, 8)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("m,k,n", [(16, 64, 32), (96, 200, 130), (1, 33, 7)])
def test_qmatmul_f32_sweep(rng, bits, m, k, n):
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    packed, scale = ops.prep_linear(w, bits)
    out = qmatmul_f32(x, packed, scale, bits=bits, k_orig=k,
                      bm=32, bn=32, bk=64, interpret=True)
    expect = ref.qmatmul_f32(x, packed, scale, bits=bits, k_orig=k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_dtypes(rng, bits, dtype):
    x = jnp.asarray(rng.normal(size=(24, 80)), dtype)
    w = jnp.asarray(rng.normal(size=(40, 80)), jnp.float32)
    packed, scale = ops.prep_linear(w, bits)
    out = qmatmul_f32(x, packed, scale, bits=bits, k_orig=80,
                      bm=16, bn=16, bk=40, interpret=True)
    expect = ref.qmatmul_f32(x, packed, scale, bits=bits, k_orig=80)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits", BITS)
def test_qmatmul_int8_exact(rng, bits):
    m, k, n = 40, 130, 50
    xq = jnp.asarray(rng.integers(0, 255, (m, k)), jnp.uint8)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    packed, scale = ops.prep_linear(w, bits)
    mult = jnp.asarray(rng.uniform(1e-4, 1e-3, (n,)), jnp.float32)
    bias = jnp.asarray(rng.integers(-8, 8, (n,)), jnp.int32)
    out = qmatmul_int8(xq, packed, mult, bias, bits=bits, k_orig=k,
                       bm=16, bn=32, bk=32, interpret=True)
    expect = ref.qmatmul_int8(xq, packed, mult, bias, bits=bits, k_orig=k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("hwc", [(12, 10, 24, 16), (7, 7, 3, 32)])
def test_conv3x3_dense(rng, bits, stride, hwc):
    h, w_, cin, cout = hwc
    x = jnp.asarray(rng.integers(0, 255, (h, w_, cin)), jnp.uint8)
    wf = jnp.asarray(rng.normal(size=(cout, 3, 3, cin)), jnp.float32)
    packed, scale = ops.prep_conv3x3(wf, bits)
    mult = jnp.asarray(rng.uniform(1e-4, 1e-3, (cout,)), jnp.float32)
    bias = jnp.asarray(rng.integers(-8, 8, (cout,)), jnp.int32)
    out = nkc.conv3x3_dense(x, packed, mult, bias, bits=bits, cin=cin,
                            stride=stride, bco=16, bci=8, interpret=True)
    expect = ref.conv3x3_dense(x, packed, mult, bias, bits=bits, cin=cin,
                               stride=stride)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stride", [1, 2])
def test_conv3x3_dw(rng, bits, stride):
    h, w_, c = 9, 11, 40
    x = jnp.asarray(rng.integers(0, 255, (h, w_, c)), jnp.uint8)
    wf = jnp.asarray(rng.normal(size=(c, 3, 3)), jnp.float32)
    packed, scale = ops.prep_dw3x3(wf, bits)
    mult = jnp.asarray(rng.uniform(1e-4, 1e-3, (c,)), jnp.float32)
    bias = jnp.asarray(rng.integers(-8, 8, (c,)), jnp.int32)
    out = nkc.conv3x3_dw(x, packed, mult, bias, bits=bits, stride=stride,
                         bc=16, interpret=True)
    expect = ref.conv3x3_dw(x, packed, mult, bias, bits=bits, stride=stride)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("bits", BITS)
def test_conv1x1(rng, bits):
    x = jnp.asarray(rng.integers(0, 255, (7, 9, 33)), jnp.uint8)
    wf = jnp.asarray(rng.normal(size=(17, 33)), jnp.float32)
    packed, scale = ops.prep_linear(wf, bits)
    mult = jnp.asarray(rng.uniform(1e-4, 1e-3, (17,)), jnp.float32)
    bias = jnp.asarray(rng.integers(-8, 8, (17,)), jnp.int32)
    out = nkc.conv1x1(x, packed, mult, bias, bits=bits, cin=33,
                      interpret=True)
    expect = ref.conv1x1(x, packed, mult, bias, bits=bits, cin=33)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("sq,sk,causal,window", [
    (64, 64, True, None), (37, 37, True, None), (17, 80, True, None),
    (64, 64, True, 16), (50, 50, False, None), (1, 64, True, None),
])
def test_flash_attention(rng, sq, sk, causal, window):
    q = jnp.asarray(rng.normal(size=(3, sq, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(3, sk, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, sk, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=16, bk=16, interpret=True)
    expect = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


def test_ops_mode_dispatch(rng):
    """xla / interpret modes agree through the public wrappers."""
    x = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    packed, scale = ops.prep_linear(w, 4)
    a = ops.quant_matmul(x, packed, scale, bits=4, k_orig=64, mode="xla")
    b = ops.quant_matmul(x, packed, scale, bits=4, k_orig=64,
                         mode="interpret", bm=16, bn=16, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
    assert a.shape == (4, 8, 32)

"""Async overlapped page streaming (core/paging.AsyncPageStream + the
serving pipeline built on it).

The tentpole invariants: the overlapped pipeline changes WHEN pages move,
never what the step computes — tokens bit-exact vs the synchronous path
and vs the fully resident plan, swap/miss/pool-hit counters unchanged by
overlap, exposed+hidden stall split matching the analytical
``stall += swap - hidden`` identity (memsys.overlap_stall), totals never
double-counting the pool's view of the same wall time, and early exits
cancelling in-flight passes without leaking fetches or pool guards.
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memsys import overlap_stall
from repro.core.paging import (AsyncPageStream, HostPagedStore,
                               SharedPagePool, page_sizes, pass_counters,
                               shared_pass_counters, thread_packed)
from repro.core.placement import PlacementPlan, packed_sizes, plan_for_budget
from repro.core.weight_store import freeze, uniform_policy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MultiScheduler, Request, Scheduler,
                           ServingEngine, validate)

CFG = ModelConfig(name="tinyA", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)
CFG_B = ModelConfig(name="tinyB", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
                    head_dim=12, remat=False)


@pytest.fixture(scope="module")
def packed():
    return freeze_for_serving(tfm.init_params(CFG, jax.random.PRNGKey(0)),
                              bits=8)


@pytest.fixture(scope="module")
def packed_b():
    return freeze_for_serving(tfm.init_params(CFG_B, jax.random.PRNGKey(1)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


def _flat_store(rng, n=6, d=32):
    params = {f"layer{i:02d}": dict(w=jnp.asarray(rng.normal(size=(d, d)),
                                                  jnp.float32))
              for i in range(n)}
    return freeze(params, uniform_policy(8, min_size=16))


def _serve(cfg, packed, plan, prompts, *, paged, async_io, seed=0,
           max_new=5, slots=2):
    eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64, plan=plan,
                        seed=seed)
    if paged:
        eng.attach_paging(resident_slots=slots)
    s = Scheduler(eng, prefill_chunk=8, async_io=async_io)
    for uid, p in enumerate(prompts):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    done = s.run_until_done()
    return {r.uid: r.generated for r in done}, s, eng


# ---------------------------------------------------------------------------
# tentpole: AsyncPageStream mechanics
# ---------------------------------------------------------------------------

def test_begin_pass_matches_sync_stream_pages_and_counters(rng):
    """One overlapped pass serves exactly the pages (same content) and
    the same swap/miss counters as one synchronous pass."""
    store = _flat_store(rng)
    sync = HostPagedStore(store, page_bytes=2 * 32 * 32)
    for _page, _params in sync.stream():
        pass
    paged = HostPagedStore(store, page_bytes=2 * 32 * 32)
    ps = paged.begin_pass()
    dev = ps.fence()
    assert set(dev) == set(store.params)
    for name, p in dev.items():
        np.testing.assert_array_equal(
            np.asarray(p.packed), np.asarray(store.params[name].packed))
    assert (paged.swap_count, paged.miss_count) == (sync.swap_count,
                                                    sync.miss_count)
    assert pass_counters(len(paged.pages)) == dict(swaps=paged.swap_count,
                                                   misses=paged.miss_count)
    sync.close()
    paged.close()


def test_fence_is_idempotent_and_close_after_fence_is_noop(rng):
    paged = HostPagedStore(_flat_store(rng, n=4), page_bytes=2 * 32 * 32)
    ps = paged.begin_pass()
    first = ps.fence()
    again = ps.fence()
    assert again is first                  # no re-wait, no re-accounting
    swaps = paged.swap_count
    ps.close()                             # no-op on a fenced pass
    assert paged.swap_count == swaps
    paged.close()


def test_fence_after_close_raises(rng):
    paged = HostPagedStore(_flat_store(rng, n=4), page_bytes=2 * 32 * 32)
    ps = paged.begin_pass()
    ps.close()
    with pytest.raises(RuntimeError, match="close"):
        ps.fence()
    paged.close()


def test_async_pass_single_slot_demand_fetches(rng):
    """resident_slots=1 has nowhere to double-buffer: the overlapped pass
    demand-fetches every page (misses == swaps == n_pages), exactly the
    sync single-slot schedule."""
    paged = HostPagedStore(_flat_store(rng), page_bytes=2 * 32 * 32)
    ps = paged.begin_pass(resident_slots=1)
    dev = ps.fence()
    n = len(paged.pages)
    assert len(dev) == sum(len(p.param_names) for p in paged.pages)
    assert paged.swap_count == n and paged.miss_count == n
    assert pass_counters(n, resident_slots=1) == dict(swaps=n, misses=n)
    paged.close()


def test_overlap_split_matches_memsys_identity(rng):
    """The measured exposed/hidden split equals the analytical
    ``stall += swap - hidden`` closed form applied to the measured
    (swap wall, compute window) — predicted-vs-measured agreement."""
    paged = HostPagedStore(_flat_store(rng, n=8), page_bytes=2 * 32 * 32)
    ps = paged.begin_pass()
    time.sleep(0.05)                       # a compute window to hide in
    ps.fence()
    pred = overlap_stall(ps.swap_s, ps.window_s)
    assert ps.exposed_s == pytest.approx(pred["exposed_s"], abs=5e-3)
    assert ps.hidden_s == pytest.approx(pred["hidden_s"], abs=5e-3)
    assert ps.swap_s == pytest.approx(ps.exposed_s + ps.hidden_s)
    # with a 50 ms window, this tiny stream must be (almost) fully hidden
    assert ps.hidden_s > 0.0
    assert ps.exposed_s < 0.045
    paged.close()


def test_overlap_stall_closed_form():
    r = overlap_stall(swap_s=3.0, compute_s=2.0)
    assert r == dict(swap_s=3.0, compute_s=2.0, hidden_s=2.0,
                     exposed_s=1.0, overlap_frac=pytest.approx(2 / 3))
    assert overlap_stall(0.0, 5.0)["overlap_frac"] == 0.0
    assert overlap_stall(2.0, 5.0)["exposed_s"] == 0.0


def test_early_close_cancels_without_leaking_pool_guard(rng):
    """Closing an unfenced pass cancels/drains its fetches and releases
    the pool's eviction guard, so the pool keeps evicting normally."""
    store = _flat_store(rng)
    pool = SharedPagePool(1 << 20)
    paged = HostPagedStore(store, page_bytes=2 * 32 * 32, pool=pool,
                           name="m")
    ps = paged.begin_pass()
    ps.close()
    assert not pool._active_fetch          # guard released, not leaked
    # the store stays fully usable: a fresh pass still streams everything
    dev = paged.begin_pass().fence()
    assert set(dev) == set(store.params)
    pool.close()


def test_scheduler_close_cancels_inflight_pass(rng, packed):
    """run_for can leave a begun pass in flight; Scheduler.close() must
    cancel/drain it (engine._inflight_pass cleared, pool guard empty)."""
    plan = _half_paged_plan(packed)
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64, plan=plan)
    eng.attach_paging()
    s = Scheduler(eng, prefill_chunk=8, async_io=True)
    for uid in range(3):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256, 6).astype(np.int32),
                         max_new_tokens=8))
    s.tick()                               # begins the next tick's pass
    assert eng._inflight_pass is not None
    s.close()
    assert eng._inflight_pass is None
    # still serviceable after the cancel: drain the rest synchronously
    rest = s.run_until_done()
    assert {r.uid for r in rest} == {0, 1, 2}
    eng.pager.close()


# ---------------------------------------------------------------------------
# tentpole: async-vs-sync serving equivalence
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_serving_bit_exact_and_counters_unchanged(rng, packed):
    """Overlap changes WHEN pages move, never what the step computes:
    identical tokens, identical tick count, identical swap/miss counters
    vs both the sync streaming path and the fully resident plan."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 3 + 5 * uid).astype(np.int32)
               for uid in range(4)]
    a_tok, a_s, a_eng = _serve(CFG, packed, plan, prompts, paged=True,
                               async_io=True)
    s_tok, s_s, s_eng = _serve(CFG, packed, plan, prompts, paged=True,
                               async_io=False)
    r_tok, _, _ = _serve(CFG, packed, PlacementPlan.uniform(), prompts,
                         paged=False, async_io=True)
    assert a_tok == s_tok == r_tok
    assert a_s.ticks == s_s.ticks
    assert (a_eng.swap_count, a_eng.miss_count) == (s_eng.swap_count,
                                                    s_eng.miss_count)
    per_pass = pass_counters(len(a_eng.pager.pages), 2)
    assert a_eng.swap_count == a_s.ticks * per_pass["swaps"]
    assert a_eng.miss_count == a_s.ticks * per_pass["misses"]
    # no orphaned pass after a drained run (the begin predicate is exact)
    assert a_eng._inflight_pass is None
    # the sync path hides (almost) nothing; both books balance
    assert a_eng.paging_stall_s + a_eng.paging_hidden_s > 0
    a_eng.pager.close()
    s_eng.pager.close()


def test_async_single_slot_serving_bit_exact(rng, packed):
    """resident_slots=1 under the overlapped pipeline: demand-fetch every
    page, tokens bit-exact, counters == ticks x the single-slot pass."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 4 + 3 * uid).astype(np.int32)
               for uid in range(3)]
    a_tok, a_s, a_eng = _serve(CFG, packed, plan, prompts, paged=True,
                               async_io=True, slots=1)
    r_tok, _, _ = _serve(CFG, packed, PlacementPlan.uniform(), prompts,
                         paged=False, async_io=True)
    assert a_tok == r_tok
    n = len(a_eng.pager.pages)
    assert a_eng.swap_count == a_s.ticks * n
    assert a_eng.miss_count == a_s.ticks * n
    a_eng.pager.close()


def test_no_orphan_pass_when_request_finishes_in_one_tick(rng, packed):
    """Regression: a request whose prefill AND final decode complete in
    the SAME tick (prompt <= chunk, max_new_tokens == 2 — decode_tick
    runs right after the finishing prefill chunk) must not trick the
    begin predicate into kicking a pass no tick will ever fence; an
    orphan pass streams extra pages and skews the counters off the
    ticks x pass_counters schedule."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 4).astype(np.int32) for _ in range(3)]
    toks, s, eng = _serve(CFG, packed, plan, prompts, paged=True,
                          async_io=True, max_new=2)
    assert all(len(t) == 2 for t in toks.values())
    assert eng._inflight_pass is None
    per_pass = pass_counters(len(eng.pager.pages), 2)
    assert eng.swap_count == s.ticks * per_pass["swaps"]
    assert eng.miss_count == s.ticks * per_pass["misses"]
    eng.pager.close()


def test_engine_last_overlap_satisfies_identity_every_tick(rng, packed):
    """Per tick, the engine's measured (swap_s, window_s, exposed_s,
    hidden_s) must satisfy memsys.overlap_stall's closed form — the
    analytical model wired to the runtime counters."""
    plan = _half_paged_plan(packed)
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64, plan=plan)
    eng.attach_paging()
    s = Scheduler(eng, prefill_chunk=8, async_io=True)
    for uid in range(3):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256, 8).astype(np.int32),
                         max_new_tokens=4))
    checked = 0
    while s.pending:
        s.tick()
        ov = eng.last_overlap
        assert ov is not None
        pred = overlap_stall(ov["swap_s"], ov["window_s"])
        assert ov["exposed_s"] == pytest.approx(pred["exposed_s"], abs=5e-3)
        assert ov["hidden_s"] == pytest.approx(pred["hidden_s"], abs=5e-3)
        checked += 1
    assert checked == s.ticks and checked > 1
    assert eng.paging_stall_s == pytest.approx(
        sum(t for t in s.metrics.tick_exposed_s))
    assert eng.paging_hidden_s == pytest.approx(
        sum(t for t in s.metrics.tick_hidden_s))
    eng.pager.close()


def test_thread_template_cached_and_equivalent(rng, packed):
    """The cached thread template is built ONCE at attach_paging and
    produces exactly thread_packed's tree every tick (no per-tick
    re-flatten of the full resident+host view)."""
    plan = _half_paged_plan(packed)
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64, plan=plan)
    eng.attach_paging()
    template = eng._thread_template
    assert template is not None
    dev = eng.pager.begin_pass().fence()
    via_cache = eng._thread_tick(dev)
    via_rebuild = thread_packed(eng.params, dev)
    la = jax.tree_util.tree_leaves(via_cache)
    lb = jax.tree_util.tree_leaves(via_rebuild)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # a tick must not rebuild the template
    eng.tick_params()
    assert eng._thread_template is template
    eng.pager.close()


# ---------------------------------------------------------------------------
# tentpole: multi-tenant overlap (shared pool determinism + accounting)
# ---------------------------------------------------------------------------

def _serve_tenants(packed_a, packed_b, prompts, budget, *, async_io):
    eng_a = ServingEngine(CFG, packed_a, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_a), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(budget), async_io=async_io)
    ms.add_model("a", eng_a, prefill_chunk=8)
    ms.add_model("b", eng_b, prefill_chunk=8)
    for uid, p in enumerate(prompts):
        ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=4))
        ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=4))
    done = ms.run_until_done()
    return ms, done


def _paged_bytes(packed):
    sizes = packed_sizes(packed)
    plan = _half_paged_plan(packed)
    return sum(v for k, v in sizes.items() if plan.placement_for(k).paged)


@pytest.mark.slow
@pytest.mark.parametrize("budget_kind", ["roomy", "tight"])
def test_tenant_overlap_preserves_pool_counters(rng, packed, packed_b,
                                                budget_kind):
    """Overlapped tenant passes serialize on the pool's shared fetch
    worker in begin order, so tokens AND every pool counter (swaps,
    misses, pool_hits, evicted) are identical to the synchronous run and
    to the static shared_pass_counters prediction."""
    prompts = [rng.integers(0, 256, 3 + 4 * i).astype(np.int32)
               for i in range(3)]
    cold = _paged_bytes(packed) + _paged_bytes(packed_b)
    budget = (1 << 30) if budget_kind == "roomy" else int(cold * 0.6)

    ms_a, done_a = _serve_tenants(packed, packed_b, prompts, budget,
                                  async_io=True)
    ms_s, done_s = _serve_tenants(packed, packed_b, prompts, budget,
                                  async_io=False)
    for m in ("a", "b"):
        assert ({r.uid: r.generated for r in done_a[m]}
                == {r.uid: r.generated for r in done_s[m]})
    assert ms_a.pass_log == ms_s.pass_log
    sum_a, sum_s = ms_a.pool.summary(), ms_s.pool.summary()
    pred = shared_pass_counters(
        {m: page_sizes(ms_a.model(m).engine.pager.pages)
         for m in ("a", "b")}, budget, passes=ms_a.pass_log)
    for m in ("a", "b"):
        got_a = {k: sum_a["models"][m][k]
                 for k in ("swaps", "misses", "pool_hits", "evicted")}
        got_s = {k: sum_s["models"][m][k]
                 for k in ("swaps", "misses", "pool_hits", "evicted")}
        want = {k: pred[m][k] for k in got_a}
        assert got_a == got_s == want, (m, got_a, got_s, pred[m])
        # wire-byte ledger identical async vs sync, and exactly predicted
        assert (sum_a["models"][m]["bytes_streamed_wire"]
                == sum_s["models"][m]["bytes_streamed_wire"]
                == pred[m]["bytes_wire"])
    if budget_kind == "tight":
        assert sum_a["evictions"] > 0      # contention actually happened
    ms_a.close()
    ms_s.close()


def test_pass_log_tracks_begin_order_under_live_traffic(rng, packed,
                                                        packed_b):
    """Regression: with live mid-run submissions a tenant can go idle
    and re-enter the rotation, so the order passes BEGIN (and execute on
    the pool worker) is not the registration-rotation order the fence
    loop sees.  pass_log is owned by the pool and logged at pass
    construction, so shared_pass_counters(passes=pass_log) still replays
    the pool's true lookup/admit/evict sequence and the counters match."""
    cold = _paged_bytes(packed) + _paged_bytes(packed_b)
    eng_a = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(int(cold * 0.6)),
                        async_io=True)
    ms.add_model("a", eng_a, prefill_chunk=8)
    ms.add_model("b", eng_b, prefill_chunk=8)
    # a: one short request that drains immediately; b: long-running work
    ms.submit("a", Request(uid=0, prompt=rng.integers(0, 256, 4)
                           .astype(np.int32), max_new_tokens=2))
    ms.submit("b", Request(uid=0, prompt=rng.integers(0, 256, 6)
                           .astype(np.int32), max_new_tokens=10))
    for _ in range(3):                     # a drains; b keeps streaming
        ms.tick()
    assert not ms.model("a").pending and ms.model("b").pending
    # live traffic: a re-enters the rotation mid-run
    ms.submit("a", Request(uid=1, prompt=rng.integers(0, 256, 4)
                           .astype(np.int32), max_new_tokens=4))
    ms.run_until_done()
    # the fence-rotation order would claim a,b alternation throughout;
    # the true begin order has b-only stretches while a sat idle
    assert ms.pass_log.count("a") == eng_a.miss_count  # 1 miss per pass
    pred = shared_pass_counters(
        {m: [p.nbytes for p in ms.model(m).engine.pager.pages]
         for m in ("a", "b")}, ms.pool.budget_bytes, passes=ms.pass_log)
    summ = ms.pool.summary()
    for m in ("a", "b"):
        got = {k: summ["models"][m][k]
               for k in ("swaps", "misses", "pool_hits", "evicted")}
        assert got == {k: pred[m][k] for k in got}, (m, got, pred[m],
                                                    ms.pass_log)
    ms.close()


def test_sync_mode_reports_zero_hidden(rng, packed):
    """async_io=False (and any demand-begun fence) spends the whole
    stream wall blocked inside the call: hidden must be exactly 0 and
    overlap_frac 0 — the v2-era accounting, byte for byte."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 6).astype(np.int32) for _ in range(2)]
    _tok, s, eng = _serve(CFG, packed, plan, prompts, paged=True,
                          async_io=False, max_new=3)
    assert eng.paging_hidden_s == 0.0
    assert eng.paging_stall_s > 0.0
    ps = eng.paging_summary()
    assert ps["hidden_s"] == 0.0 and ps["overlap_frac"] == 0.0
    assert all(h == 0.0 for h in s.metrics.tick_hidden_s)
    eng.pager.close()


def test_totals_sum_per_model_exposed_once(rng, packed, packed_b):
    """Double-attribution regression: the multi doc's totals paging
    seconds equal the SUM of the per-model engine-side exposed/hidden —
    the shared pool's per-model stalls are the same wall time seen from
    the pool and must not be added on top."""
    prompts = [rng.integers(0, 256, 5).astype(np.int32) for _ in range(2)]
    cold = _paged_bytes(packed) + _paged_bytes(packed_b)
    ms, _done = _serve_tenants(packed, packed_b, prompts, int(cold * 0.6),
                               async_io=True)
    doc = validate(ms.summary())
    exp_sum = sum(doc["models"][m]["paging"]["exposed_s"]
                  for m in doc["models"])
    hid_sum = sum(doc["models"][m]["paging"]["hidden_s"]
                  for m in doc["models"])
    assert doc["totals"]["paging_exposed_s"] == pytest.approx(exp_sum)
    assert doc["totals"]["paging_hidden_s"] == pytest.approx(hid_sum)
    # pool and engine report the SAME per-model wall time (one pass, two
    # vantage points) — equal, not twice
    for m in doc["models"]:
        assert (doc["shared_pool"]["models"][m]["exposed_s"]
                == pytest.approx(doc["models"][m]["paging"]["exposed_s"]))
        assert (doc["shared_pool"]["models"][m]["hidden_s"]
                == pytest.approx(doc["models"][m]["paging"]["hidden_s"]))
    ms.close()


def test_multischeduler_close_cancels_inflight_passes(rng, packed,
                                                      packed_b):
    """Early exit mid-run: close() cancels every tenant's unfenced pass
    and releases the pool guard — no leaked fetches, no stuck guard."""
    prompts = [rng.integers(0, 256, 8).astype(np.int32) for _ in range(3)]
    cold = _paged_bytes(packed) + _paged_bytes(packed_b)
    eng_a = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(int(cold * 0.6)),
                        async_io=True)
    ms.add_model("a", eng_a, prefill_chunk=8)
    ms.add_model("b", eng_b, prefill_chunk=8)
    for uid, p in enumerate(prompts):
        ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=8))
        ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=8))
    ms.tick()                              # leaves passes in flight
    assert (eng_a._inflight_pass is not None
            or eng_b._inflight_pass is not None)
    ms.close()
    assert eng_a._inflight_pass is None and eng_b._inflight_pass is None
    assert not ms.pool._active_fetch


def test_metrics_v9_schema_validates_and_rejects_stale():
    from repro.serving import MetricsRecorder
    from repro.serving.metrics import SCHEMA, _empty_paging

    assert SCHEMA == "repro.serving.metrics/v9"
    rec = MetricsRecorder(clock=lambda: 0.0)
    rec.record_tick(latency_s=0.002, paging_exposed_s=0.0005,
                    paging_hidden_s=0.002)
    doc = rec.summary()
    validate(doc)
    assert doc["ticks"]["paging_exposed_ms"]["max"] == pytest.approx(0.5)
    assert doc["ticks"]["paging_hidden_ms"]["max"] == pytest.approx(2.0)
    for k in ("exposed_s", "hidden_s", "overlap_frac",
              "kv_swaps", "kv_pool_hits", "kv_writebacks", "kv_dropped",
              "kv_exposed_s", "kv_hidden_s",
              "bytes_streamed_wire", "bytes_streamed_raw"):
        assert k in doc["paging"]
    stale = dict(doc, schema="repro.serving.metrics/v3")
    with pytest.raises(ValueError, match="schema"):
        validate(stale)
    # a v3-shaped payload (right schema string, no kv_* fields) must be
    # rejected by name
    v3_paging = {k: v for k, v in _empty_paging().items()
                 if not k.startswith("kv_")}
    with pytest.raises(ValueError, match="kv_swaps"):
        validate(dict(doc, paging=v3_paging))
    # a v6-shaped payload (no wire/raw byte ledgers) likewise
    v6_paging = {k: v for k, v in _empty_paging().items()
                 if not k.startswith("bytes_streamed")}
    with pytest.raises(ValueError, match="bytes_streamed"):
        validate(dict(doc, paging=v6_paging))
    # a v7-shaped payload (no faults section) likewise
    v7 = {k: v for k, v in doc.items() if k != "faults"}
    with pytest.raises(ValueError, match="faults"):
        validate(v7)
    # a v8-shaped payload (no per-device split) likewise
    v8_paging = {k: v for k, v in _empty_paging().items()
                 if k != "devices"}
    with pytest.raises(ValueError, match="devices"):
        validate(dict(doc, paging=v8_paging))
    broken = dict(doc, paging=dict(swap_count=0, miss_count=0,
                                   stall_s=0.0, n_pages=0))
    with pytest.raises(ValueError, match="exposed_s"):
        validate(broken)


def test_paging_summary_overlap_fields(rng, packed):
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 6).astype(np.int32)]
    _tok, _s, eng = _serve(CFG, packed, plan, prompts, paged=True,
                           async_io=True, max_new=3)
    ps = eng.paging_summary()
    assert ps["exposed_s"] == eng.paging_stall_s
    assert ps["hidden_s"] == eng.paging_hidden_s
    assert ps["stall_s"] == ps["exposed_s"]          # v2 alias
    total = ps["exposed_s"] + ps["hidden_s"]
    assert ps["overlap_frac"] == pytest.approx(
        ps["hidden_s"] / total if total else 0.0)
    eng.pager.close()


def test_pool_guard_protects_mid_fetch_model():
    """While a model's pass fetches are executing, admit() must not evict
    ITS pages to make room for another model's admission — the async
    extension of the fetcher guard, exercised here directly."""
    pool = SharedPagePool(100)

    class _Stub:
        pages = []
        swap_count = miss_count = 0
    pool.register("victim", _Stub())
    pool.register("bully", _Stub())
    pool.admit("victim", 0, 60, {})
    pool._pass_begin("victim")             # victim's pass is mid-fetch
    pool.admit("bully", 0, 60, {})         # wants room, can't take it
    assert pool.lookup("victim", 0) is not None
    assert pool.counters["victim"]["evicted"] == 0
    assert pool.lookup("bully", 0) is None   # didn't fit, not cached
    pool._pass_end("victim")
    pool.admit("bully", 1, 60, {})         # guard released: now it can
    assert pool.counters["victim"]["evicted"] == 1
    assert pool.lookup("bully", 1) is not None

"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward + one train step on CPU, asserting output shapes
and finiteness.  (Full configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.steps import make_train_step, _init_fn, _loss_fn
from repro.optim import adamw


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.slow
def test_arch_smoke(arch):
    cfg = ARCHS[arch].smoke()
    init = _init_fn(cfg)
    params = init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 32

    batch = dict(tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                    jnp.int32),
                 labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                    jnp.int32))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)), jnp.float32)

    # forward (via the loss fn, which exercises the full graph)
    loss = _loss_fn(cfg)(params, batch, cfg)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one full train step (grads + optimizer update)
    opt = adamw()
    step = jax.jit(make_train_step(cfg, opt))
    new_params, new_opt, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite params"
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b_.astype(jnp.float32)))) > 0
        for a, b_ in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(new_params)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "hymba-1.5b",
                                  "falcon-mamba-7b", "qwen2-moe-a2.7b"])
def test_arch_serve_smoke(arch):
    """Reduced-config prefill + one decode step for key families."""
    from repro.models import transformer as tfm
    cfg = ARCHS[arch].smoke().replace(capacity_factor=8.0)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    cache = tfm.init_serve_cache(cfg, 2, 64)
    lg, cache = tfm.step(params, tokens, cache, jnp.int32(0), cfg)
    full = tfm.forward(params, tokens, cfg)[:, -16:]
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full),
                               rtol=3e-3, atol=3e-3)
    nt = jnp.argmax(lg[:, -1:], -1)
    pos = 16 + cfg.n_meta_tokens
    lg2, cache = tfm.step(params, nt, cache, jnp.int32(pos), cfg)
    ref = tfm.forward(params, jnp.concatenate([tokens, nt], 1), cfg)
    np.testing.assert_allclose(np.asarray(lg2[:, -1]),
                               np.asarray(ref[:, -1]), rtol=5e-3, atol=5e-3)


def test_param_counts_match_published():
    """Total parameter counts land on the published model sizes."""
    from repro.models.transformer import total_param_count
    expected = {
        "qwen3-0.6b": (0.55e9, 0.65e9),
        "qwen2.5-3b": (2.9e9, 3.3e9),
        "olmo-1b": (1.0e9, 1.3e9),
        "gemma-7b": (8.0e9, 9.0e9),     # gemma-7b is 8.5B with embeddings
        "whisper-tiny": (0.025e9, 0.045e9),
        "qwen2-moe-a2.7b": (13e9, 15e9),
        "arctic-480b": (450e9, 500e9),
        "hymba-1.5b": (1.4e9, 1.8e9),
        "falcon-mamba-7b": (6.5e9, 7.8e9),
        "llava-next-34b": (32e9, 36e9),
    }
    for arch, (lo, hi) in expected.items():
        n = total_param_count(ARCHS[arch])
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"

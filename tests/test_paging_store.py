"""WeightStore (MRAM analogue) + virtual paging (paper §II-B2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paging, weight_store
from repro.core.weight_store import freeze, uniform_policy


def _params(rng, n_layers=6, d=64):
    return {f"layer{i}": dict(w=jnp.asarray(rng.normal(size=(d, d)),
                                            jnp.float32))
            for i in range(n_layers)}


def test_freeze_density_gain(rng):
    params = _params(rng)
    s4 = freeze(params, uniform_policy(4, min_size=16))
    s8 = freeze(params, uniform_policy(8, min_size=16))
    # int4 packs 2 weights/byte: ~8x denser than f32-equivalent bf16... vs
    # bf16 dense equivalent: 4x for int4, 2x for int8
    assert s4.density_gain() == pytest.approx(4.0, rel=0.05)
    assert s8.density_gain() == pytest.approx(2.0, rel=0.05)
    assert s4.packed_bytes * 2 == s8.packed_bytes


def test_store_capacity_accounting(rng):
    params = _params(rng, n_layers=4, d=128)
    store = freeze(params, uniform_policy(8, min_size=16))
    assert store.packed_bytes == 4 * 128 * 128
    assert store.fits(budget_bytes=4 * 128 * 128)
    assert not store.fits(budget_bytes=4 * 128 * 128 - 1)


def test_dequantized_params_close(rng):
    params = _params(rng, n_layers=2)
    store = freeze(params, uniform_policy(8, min_size=16))
    deq = store.dequantized_params()
    for k, p in params.items():
        orig = np.asarray(p["w"])
        got = np.asarray(deq[f"{k}/w"])
        assert np.abs(got - orig).max() < np.abs(orig).max() * 0.02


def test_schedule_invariants_smoke():
    """ONE deterministic case per regime, for ``-x -q`` speed — the full
    randomized strategy space (pages x slots x ticks x budgets) lives in
    tests/test_paging_properties.py under hypothesis (optional [test]
    extra)."""
    sched = paging.make_schedule(7, resident_slots=3)
    paging.validate_schedule(sched, resident_slots=3)
    assert [e.page for e in sched] == list(range(7))
    for e in sched[:-1]:
        assert e.prefetch_next == e.page + 1
    assert paging.pass_counters(7, 3) == dict(swaps=7, misses=1)


def test_schedule_single_slot_demand_fetches():
    """Regression: resident_slots=1 used to emit entries whose
    ``evicts == page`` (prefetching k+1 evicts the in-use page k), which
    validate_schedule rejects.  A single live slot has nowhere to
    double-buffer: no prefetch, demand-fetch every page, and the static
    pass counters predict swaps == misses == n_pages."""
    sched = paging.make_schedule(9, resident_slots=1)
    paging.validate_schedule(sched, resident_slots=1)
    assert [e.page for e in sched] == list(range(9))
    assert all(e.prefetch_next is None for e in sched)
    assert all(e.evicts != e.page for e in sched)
    pc = paging.pass_counters(9, resident_slots=1)
    assert pc == dict(swaps=9, misses=9)


def test_make_schedule_rejects_zero_slots():
    with pytest.raises(ValueError, match="resident_slots"):
        paging.make_schedule(4, resident_slots=0)


def test_host_paged_store_single_slot_streams_all(rng):
    """A resident_slots=1 streaming pass serves every page (demand
    fetches, no prefetch) instead of streaming a broken schedule."""
    params = _params(rng, n_layers=6, d=32)
    store = freeze(params, uniform_policy(8, min_size=16))
    paged = paging.HostPagedStore(store, page_bytes=2 * 32 * 32)
    seen = [n for _page, ps in paged.stream(resident_slots=1) for n in ps]
    assert seen == list(store.params.keys())
    assert paged.miss_count == len(paged.pages)      # every fetch a miss
    assert paged.swap_count == len(paged.pages)
    paged.close()


def test_build_pages_order_and_limit(rng):
    params = _params(rng, n_layers=8, d=32)
    store = freeze(params, uniform_policy(8, min_size=16))
    per = 32 * 32
    pages = paging.build_pages(store, page_bytes=3 * per)
    # first-fit preserving order: 3+3+2
    assert [len(p.param_names) for p in pages] == [3, 3, 2]
    names = [n for p in pages for n in p.param_names]
    assert names == list(store.params.keys())
    with pytest.raises(ValueError):
        paging.build_pages(store, page_bytes=per - 1)


def test_host_paged_store_streams_all(rng):
    params = _params(rng, n_layers=6, d=32)
    store = freeze(params, uniform_policy(8, min_size=16))
    paged = paging.HostPagedStore(store, page_bytes=2 * 32 * 32)
    seen = []
    for page, dev_params in paged.stream():
        for name, p in dev_params.items():
            np.testing.assert_array_equal(
                np.asarray(p.packed), np.asarray(store.params[name].packed))
            seen.append(name)
    assert seen == list(store.params.keys())
    # proactive prefetch: only the first page is a demand miss
    assert paged.miss_count == 1
    assert paged.swap_count == len(paged.pages)
    paged.close()


def test_stall_model_hides_swaps():
    pages = [paging.Page(i, (f"p{i}",), 1000) for i in range(4)]
    m = paging.StallModel(swap_bandwidth_bytes_per_s=1e6)   # 1 ms per page
    # compute long enough to hide every swap except the cold first
    r = m.run(pages, [0.002] * 4)
    assert r["stall_s"] == pytest.approx(0.001)
    # compute too short: swaps dominate
    r2 = m.run(pages, [0.0001] * 4)
    assert r2["stall_s"] > r["stall_s"]


def test_scenarios_same_numerics(rng):
    from repro.core import scenarios
    from repro.core.weight_store import pack_param
    x = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    p = pack_param(w, 8)
    outs = {s: scenarios.linear_apply(x, p, scenario=s)
            for s in scenarios.SCENARIOS}
    base = np.asarray(outs["l1mram"])
    for s, o in outs.items():
        np.testing.assert_allclose(np.asarray(o), base, rtol=1e-5, atol=1e-5)
    # byte accounting ordering: at-memory strictly cheapest
    b = {s: scenarios.weight_path_bytes(p, s) for s in scenarios.SCENARIOS}
    assert b["l1mram"] < b["l2mram"] < b["l3mram"] == b["l3flash"]

"""Deadline-aware serving scheduler (serving/sched) + engine rework.

Covers the tentpole surfaces of the scheduler PR: EDF-with-priority
admission, chunked/bucketed prefill exactness and its bounded jit cache,
per-slot temperature sampling, live paged-weight streaming through the
engine tick (bit-exactness + static counter prediction), the paging
close/stream lifecycle fixes, and the metrics JSON schema.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paging import HostPagedStore, pass_counters
from repro.core.placement import (Placement, PlacementPlan, packed_sizes,
                                  plan_for_budget)
from repro.core.weight_store import freeze, uniform_policy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MetricsRecorder, Request, Scheduler,
                           ServingEngine, sample_token, sample_token_batch)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)


@pytest.fixture(scope="module")
def packed():
    params = tfm.init_params(CFG, jax.random.PRNGKey(0))
    return freeze_for_serving(params, bits=8)


# ---------------------------------------------------------------------------
# admission policy
# ---------------------------------------------------------------------------

def test_edf_with_priority_admission_order(packed):
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    s = Scheduler(eng)
    s.add_stream("hand", priority=2, deadline_ms=50.0)
    s.add_stream("gaze", priority=2, deadline_ms=10.0)
    s.add_stream("bg", priority=0)
    p = np.arange(4, dtype=np.int32)
    s.submit(Request(uid=0, prompt=p), stream="bg")       # first in, low prio
    s.submit(Request(uid=1, prompt=p), stream="hand")
    s.submit(Request(uid=2, prompt=p), stream="gaze")     # same prio, tighter
    s.submit(Request(uid=3, prompt=p,
                     deadline_ms=5.0, priority=2), stream="hand")
    order = [r.uid for r in s.admission_order()]
    # priority class first; EDF inside the class; best-effort last
    assert order == [3, 2, 1, 0]
    # requests inherit stream defaults unless they carry their own
    by_uid = {r.uid: r for r in s.queue}
    assert by_uid[1].deadline_ms == 50.0 and by_uid[1].priority == 2
    assert by_uid[3].deadline_ms == 5.0
    assert by_uid[0].deadline_ms is None


def test_single_slot_serves_in_priority_order(packed):
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    s = Scheduler(eng)
    s.add_stream("hi", priority=1)
    p = np.arange(3, dtype=np.int32)
    for uid in range(4):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=2),
                 stream="hi" if uid >= 2 else "default")
    done = s.run_until_done()
    assert [r.uid for r in done] == [2, 3, 0, 1]
    assert all(r.first_token_s is not None and r.finish_s is not None
               for r in done)


def test_unknown_stream_rejected(packed):
    s = Scheduler(ServingEngine(CFG, packed, batch_slots=1, max_len=64))
    with pytest.raises(KeyError):
        s.submit(Request(uid=0, prompt=np.arange(3, dtype=np.int32)),
                 stream="nope")


# ---------------------------------------------------------------------------
# chunked + bucketed prefill
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_prefill_matches_offline(rng, packed):
    """Prompts longer than the chunk are absorbed over several ticks in
    power-of-two buckets; the greedy continuation must equal offline
    full-prompt generation token for token."""
    prompts = [rng.integers(0, 256, n).astype(np.int32)
               for n in (1, 5, 19, 40)]
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        plan=PlacementPlan.uniform())
    s = Scheduler(eng, prefill_chunk=8)
    for uid, p in enumerate(prompts):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
    done = {r.uid: r.generated for r in s.run_until_done()}
    for uid, p in enumerate(prompts):
        toks = jnp.asarray(p)[None]
        for t in range(4):
            lg = tfm.forward(packed, toks, CFG, engine=PlacementPlan.uniform())
            nt = jnp.argmax(lg[:, -1], -1)
            assert done[uid][t] == int(nt[0]), f"uid {uid} tok {t}"
            toks = jnp.concatenate([toks, nt[:, None]], 1)


def test_long_prompt_does_not_monopolize_ticks(rng, packed):
    """While a 32-token prompt chunk-prefills at 4 tokens/tick, the short
    co-resident request keeps decoding — the anti-head-of-line property
    chunked prefill exists for."""
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    s = Scheduler(eng, prefill_chunk=4)
    s.submit(Request(uid=0, prompt=rng.integers(0, 256, 32).astype(np.int32),
                     max_new_tokens=2))
    s.submit(Request(uid=1, prompt=rng.integers(0, 256, 3).astype(np.int32),
                     max_new_tokens=3))
    done = s.run_until_done()
    # short request finishes strictly before the long one
    assert [r.uid for r in done] == [1, 0]
    long_req = next(r for r in done if r.uid == 0)
    # 32 tokens at 4/tick = 8 prefill ticks before its first token
    assert s.ticks >= 8
    assert len(long_req.generated) == 2


@pytest.mark.slow
def test_prefill_jit_cache_bounded(rng, packed):
    """Randomized prompt lengths compile at most log2(max_len)^2 prefill
    programs — power-of-two buckets x power-of-two KV spans (the chunked
    prefill attends only the live ``[0, kv_span)`` cache prefix), never
    one per exact length."""
    max_len = 128
    eng = ServingEngine(CFG, packed, batch_slots=4, max_len=max_len,
                        prefill_chunk=64)
    lengths = rng.integers(1, 60, 24)
    for uid, n in enumerate(lengths):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, 256, int(n)).astype(np.int32),
                           max_new_tokens=2))
    done = eng.run_until_done()
    assert len(done) == len(lengths)
    assert len({len(r.prompt) for r in done}) > 7   # genuinely varied
    assert len(eng._prefill_cache) <= math.log2(max_len) ** 2
    for bucket, _pfx, span in eng._prefill_cache:
        assert bucket & (bucket - 1) == 0
        assert span & (span - 1) == 0 or span == max_len
        assert span >= bucket                       # chunk must fit its span


def test_prefill_buckets_stay_pow2_for_non_pow2_max_len(rng, packed):
    """Near the cache boundary the bucket shrinks to the largest power of
    two that fits (instead of falling back to the exact tail length), so
    the compiled-shape set stays O(log^2) even for non-pow2 max_len (the
    kv span clamps to max_len there)."""
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=100,
                        prefill_chunk=64)
    for uid, n in enumerate(rng.integers(60, 98, 8)):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, 256,
                                               int(n)).astype(np.int32),
                           max_new_tokens=1))
    done = eng.run_until_done()
    assert len(done) == 8
    keys = list(eng._prefill_cache)
    assert all(b & (b - 1) == 0 for b, _pfx, _span in keys)  # pow2 buckets
    assert all(s & (s - 1) == 0 or s == 100 for _b, _pfx, s in keys)
    assert len(keys) <= math.log2(128) ** 2


def test_scheduler_threads_chunk_without_mutating_engine(rng, packed):
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        prefill_chunk=64)
    s = Scheduler(eng, prefill_chunk=4)
    assert eng.prefill_chunk == 64         # engine pacing untouched
    assert s.prefill_chunk == 4
    s.submit(Request(uid=0, prompt=rng.integers(0, 256, 16).astype(np.int32),
                     max_new_tokens=1))
    s.run_until_done()
    assert s.ticks >= 4                    # scheduler pacing still applies


@pytest.mark.slow
def test_ssm_slot_reuse_starts_cold(rng):
    """Reusing a batch slot must not leak the previous request's SSM
    recurrent state (h / conv) into the next prefill."""
    from repro.configs import get_config

    cfg = get_config("falcon-mamba-7b").smoke()
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(0)),
                                bits=8)
    a = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    b = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    def serve(prompts):
        eng = ServingEngine(cfg, packed, batch_slots=1, max_len=64)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        return {r.uid: r.generated for r in eng.run_until_done()}

    after_a = serve([a, b])[1]
    alone = serve([b])[0]
    assert after_a == alone


def test_moe_prefill_first_token_matches_offline(rng):
    """MoE prefill stays batch-1 (expert capacity is contended across the
    flattened batch, so padding rows could displace real routing): the
    PREFILL token of a lone request on a many-slot engine must equal
    offline forward.  (Decode-side capacity contention with empty batch
    rows is pre-existing engine semantics, so only token 1 is exact.)"""
    from repro.configs import get_config

    cfg = get_config("qwen2-moe-a2.7b").smoke()
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(0)),
                                bits=8)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = ServingEngine(cfg, packed, batch_slots=4, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=2))
    got = eng.run_until_done()[0].generated
    lg = tfm.forward(packed, jnp.asarray(prompt)[None], cfg)
    assert got[0] == int(jnp.argmax(lg[0, -1]))


def test_meta_token_single_prompt_rejected():
    """s==1 routes through decode and can never build the meta-token
    prefix the position accounting assumes; reject instead of serving
    garbage conditioning."""
    from repro.configs import get_config

    cfg = get_config("hymba-1.5b").smoke()
    assert cfg.n_meta_tokens > 0
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(0)),
                                bits=8)
    eng = ServingEngine(cfg, packed, batch_slots=1, max_len=64)
    with pytest.raises(ValueError, match="meta-token"):
        eng.submit(Request(uid=0, prompt=np.asarray([5], np.int32)))
    eng.submit(Request(uid=1, prompt=np.asarray([5, 6], np.int32),
                       max_new_tokens=2))
    assert len(eng.run_until_done()) == 1


def test_scheduler_adopts_engine_submissions(rng, packed):
    """Requests pushed through the still-public engine.submit() must be
    served by the scheduler, not spin `pending` forever."""
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=rng.integers(0, 256, 4).astype(np.int32),
                       max_new_tokens=2))
    s = Scheduler(eng)
    done = s.run_until_done(max_ticks=50)
    assert [r.uid for r in done] == [0]
    assert not eng.waiting


def test_empty_prompt_rejected(packed):
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))
    s = Scheduler(ServingEngine(CFG, packed, batch_slots=1, max_len=64))
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))


def test_scheduler_rejects_oversized_prompt_at_submit(rng, packed):
    s = Scheduler(ServingEngine(CFG, packed, batch_slots=1, max_len=32))
    with pytest.raises(ValueError, match="does not fit"):
        s.submit(Request(uid=0,
                         prompt=rng.integers(0, 256, 100).astype(np.int32)))
    assert not s.queue                     # nothing half-enqueued


# ---------------------------------------------------------------------------
# per-slot temperature sampling (satellite fix)
# ---------------------------------------------------------------------------

def test_sample_token_batch_semantics(rng):
    logits = jnp.asarray(rng.normal(size=(3, 32)), jnp.float32)
    key = jax.random.PRNGKey(7)
    # temp<=0 rows are greedy regardless of the others
    out = np.asarray(sample_token_batch(logits, key,
                                        jnp.asarray([0.0, 0.0, 0.0])))
    np.testing.assert_array_equal(out, np.asarray(jnp.argmax(logits, -1)))
    # a uniform-temperature batch matches the scalar sampler exactly
    for temp in (0.5, 2.0):
        batch = sample_token_batch(logits, key,
                                   jnp.full((3,), temp))
        scalar = sample_token(logits, key, temperature=temp)
        np.testing.assert_array_equal(np.asarray(batch), np.asarray(scalar))


def test_decode_uses_request_temperature(rng, packed, monkeypatch):
    """The engine must thread each request's OWN temperature into the
    batched sampler (the old engine sampled every stochastic slot at
    temperature 1.0)."""
    seen = []
    import repro.serving.engine as eng_mod
    real = eng_mod.sample_token_batch

    def spy(logits, key, temperatures):
        seen.append(np.asarray(temperatures).copy())
        return real(logits, key, temperatures)

    monkeypatch.setattr(eng_mod, "sample_token_batch", spy)
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=rng.integers(0, 256, 4).astype(np.int32),
                       max_new_tokens=3, temperature=0.0))
    eng.submit(Request(uid=1, prompt=rng.integers(0, 256, 4).astype(np.int32),
                       max_new_tokens=3, temperature=0.7))
    eng.run_until_done()
    assert seen, "decode never sampled"
    temps = np.stack([t for t in seen if t.shape == (2,)])
    assert (temps[:, 0] == 0.0).all()
    assert (temps[:, 1] == np.float32(0.7)).all()


def test_greedy_request_unaffected_by_sampled_neighbor(rng, packed):
    """Co-batching a stochastic request must not perturb the greedy one."""
    prompt = rng.integers(0, 256, 6).astype(np.int32)

    def serve(extra_temp):
        eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64, seed=3)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        eng.submit(Request(uid=1, prompt=prompt[::-1].copy(),
                           max_new_tokens=5, temperature=extra_temp))
        return {r.uid: r.generated for r in eng.run_until_done()}

    a, b = serve(0.0), serve(2.5)
    assert a[0] == b[0]


# ---------------------------------------------------------------------------
# live paged-weight streaming through the engine tick (satellite test)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_serving_bit_exact_and_counters(rng, packed):
    """A mixed plan_for_budget plan served with live HostPagedStore
    streaming is (a) bit-exact vs the fully resident plan, (b) its
    swap/miss counters equal ticks x the static make_schedule
    prediction."""
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    prompts = [rng.integers(0, 256, 3 + 5 * uid).astype(np.int32)
               for uid in range(4)]

    def serve(plan, paged):
        eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                            plan=plan)
        if paged:
            eng.attach_paging()
        s = Scheduler(eng, prefill_chunk=8)
        for uid, p in enumerate(prompts):
            s.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
        done = s.run_until_done()
        return {r.uid: r.generated for r in done}, s, eng

    mixed, s, eng = serve(plan, paged=True)
    resident, _, _ = serve(PlacementPlan.uniform(), paged=False)
    assert mixed == resident
    # every tick streams one full pass over the cold pages
    assert eng.pager is not None and len(eng.pager.pages) >= 2
    per_pass = pass_counters(len(eng.pager.pages),
                             eng.page_resident_slots)
    assert eng.swap_count == s.ticks * per_pass["swaps"]
    assert eng.miss_count == s.ticks * per_pass["misses"]
    assert eng.paging_stall_s > 0.0
    eng.pager.close()


def test_attach_paging_requires_paged_params(packed):
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        plan=PlacementPlan.uniform())
    with pytest.raises(ValueError):
        eng.attach_paging()


# ---------------------------------------------------------------------------
# paging store lifecycle (satellite fix)
# ---------------------------------------------------------------------------

def _store(rng, n=6, d=32):
    params = {f"layer{i:02d}": dict(w=jnp.asarray(rng.normal(size=(d, d)),
                                                  jnp.float32))
              for i in range(n)}
    return freeze(params, uniform_policy(8, min_size=16))


def test_stream_is_context_manager_and_early_exit_cleans_up(rng):
    store = _store(rng)
    paged = HostPagedStore(store, page_bytes=2 * 32 * 32)
    with paged.stream() as pages:
        for i, (page, _params) in enumerate(pages):
            if i == 0:
                break                      # bail out mid-pass
    assert not paged._live                 # live slots reclaimed
    # the store remains usable: a fresh full pass still streams everything
    seen = [n for page, ps in paged.stream() for n in ps]
    assert seen == [n for p in paged.pages for n in p.param_names]
    assert not paged._live                 # exhaustion also reclaims
    paged.close()                          # close waits by default


def test_close_waits_and_is_reentrant(rng):
    store = _store(rng, n=4)
    with HostPagedStore(store, page_bytes=2 * 32 * 32) as paged:
        for _ in paged.stream():
            break
    # __exit__ already closed (wait=True drains in-flight fetches);
    # closing again in either mode must not raise
    paged.close()
    paged.close(wait=False)


def test_pass_counters_prediction():
    for n_pages in range(1, 8):
        for slots in (2, 3):
            pc = pass_counters(n_pages, slots)
            assert pc["swaps"] == n_pages       # each page fetched once
            assert pc["misses"] == 1            # only the cold start


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_schema_and_deadlines():
    from repro.serving import validate

    rec = MetricsRecorder(clock=lambda: 0.0)
    rec.record_tick(latency_s=0.002, paging_exposed_s=0.0005,
                    paging_hidden_s=0.001)
    rec.record_tick(latency_s=0.004, paging_exposed_s=0.0)
    met = Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                  deadline_ms=20.0, stream="xr")
    met.arrival_s, met.first_token_s, met.finish_s = 0.0, 0.005, 0.015
    missed = Request(uid=1, prompt=np.arange(3, dtype=np.int32),
                     deadline_ms=10.0, stream="xr")
    missed.arrival_s, missed.first_token_s, missed.finish_s = 0.0, 0.02, 0.05
    best_effort = Request(uid=2, prompt=np.arange(3, dtype=np.int32))
    best_effort.arrival_s, best_effort.finish_s = 0.0, 1.0
    for r in (met, missed, best_effort):
        r.generated = [1, 2]
        rec.record_request(r)
    doc = rec.summary(paging=dict(swap_count=6, miss_count=2,
                                  exposed_s=0.001, hidden_s=0.004,
                                  overlap_frac=0.8, stall_s=0.001,
                                  n_pages=3,
                                  bytes_streamed_wire=600,
                                  bytes_streamed_raw=2400,
                                  kv_swaps=4, kv_pool_hits=2,
                                  kv_writebacks=3, kv_dropped=0,
                                  kv_preempt_drops=0,
                                  kv_exposed_s=0.0002, kv_hidden_s=0.001,
                                  kv_block_rows=16, devices=[]))
    validate(doc)
    assert doc["schema"] == "repro.serving.metrics/v9"
    assert doc["deadlines"] == dict(with_deadline=2, missed=1,
                                    miss_rate=0.5, truncated=0)
    assert doc["requests"]["count"] == 3
    assert doc["requests"]["tokens_out"] == 6
    assert doc["requests"]["truncated"] == 0
    assert doc["ticks"]["count"] == 2
    assert doc["ticks"]["latency_ms"]["max"] == pytest.approx(4.0)
    assert doc["paging"]["swap_count"] == 6
    assert doc["streams"]["xr"]["miss_rate"] == 0.5
    assert doc["streams"]["default"]["count"] == 1
    # TTFT of the met request: 5 ms
    assert doc["requests"]["ttft_ms"]["p50"] == pytest.approx(
        (0.005 + 0.02) / 2 * 1e3)
    import json
    json.loads(rec.to_json())              # serializable end to end


def test_metrics_truncated_excluded_from_miss_rate():
    """A deadline-carrying request retired by cache exhaustion is labeled
    truncated and EXCLUDED from the miss rate (partial service is neither
    a met nor a missed deadline)."""
    rec = MetricsRecorder(clock=lambda: 0.0)
    trunc = Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                    deadline_ms=10.0, stream="xr", truncated=True)
    trunc.arrival_s, trunc.finish_s = 0.0, 0.5     # would have missed
    met = Request(uid=1, prompt=np.arange(3, dtype=np.int32),
                  deadline_ms=10.0, stream="xr")
    met.arrival_s, met.finish_s = 0.0, 0.005
    for r in (trunc, met):
        r.generated = [1]
        rec.record_request(r)
    doc = rec.summary()
    assert doc["deadlines"] == dict(with_deadline=1, missed=0,
                                    miss_rate=0.0, truncated=1)
    assert doc["requests"]["truncated"] == 1
    assert doc["streams"]["xr"]["truncated"] == 1
    assert doc["streams"]["xr"]["miss_rate"] == 0.0


def test_metrics_deadline_met_exactly_at_bound():
    """latency * 1e3 == deadline_ms is a MET deadline (<=, not <)."""
    rec = MetricsRecorder(clock=lambda: 0.0)
    r = Request(uid=0, prompt=np.arange(2, dtype=np.int32),
                deadline_ms=10.0)
    r.arrival_s, r.finish_s = 0.0, 0.010
    r.generated = [1]
    rec_r = rec.record_request(r)
    assert rec_r.deadline_met is True
    doc = rec.summary()
    assert doc["deadlines"] == dict(with_deadline=1, missed=0,
                                    miss_rate=0.0, truncated=0)


def test_metrics_stream_with_only_best_effort_requests():
    """A stream whose requests all lack deadlines still gets a section —
    count populated, miss_rate 0.0 (not a division by zero)."""
    rec = MetricsRecorder(clock=lambda: 0.0)
    for uid in range(2):
        r = Request(uid=uid, prompt=np.arange(2, dtype=np.int32),
                    stream="bg")
        r.arrival_s, r.first_token_s, r.finish_s = 0.0, 0.001, 0.002
        r.generated = [1]
        rec.record_request(r)
    doc = rec.summary()
    assert doc["streams"]["bg"] == dict(
        count=2, missed=0, miss_rate=0.0, truncated=0,
        p99_ttft_ms=pytest.approx(1.0))
    assert doc["deadlines"]["with_deadline"] == 0


def test_quantiles_single_sample():
    from repro.serving.metrics import quantiles
    q = quantiles([7.0])
    assert q == dict(mean=7.0, p50=7.0, p99=7.0, max=7.0)


def test_record_request_engine_only():
    """An engine-only Request (no scheduler stamps: never admitted through
    a Scheduler, so priority/deadline/arrival defaults) must fold into a
    record without blowing up the aggregation."""
    rec = MetricsRecorder(clock=lambda: 0.0)
    r = Request(uid=0, prompt=np.arange(4, dtype=np.int32))
    r.generated = [1, 2, 3]
    rec_r = rec.record_request(r)
    assert rec_r.ttft_s is None and rec_r.latency_s is None
    assert rec_r.deadline_met is None
    doc = rec.summary()
    assert doc["requests"]["count"] == 1
    assert doc["requests"]["tokens_out"] == 3
    assert doc["requests"]["ttft_ms"] == dict(mean=0.0, p50=0.0, p99=0.0,
                                              max=0.0)
    assert doc["deadlines"] == dict(with_deadline=0, missed=0,
                                    miss_rate=0.0, truncated=0)


def test_scheduler_records_metrics(rng, packed):
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    s = Scheduler(eng)
    s.add_stream("xr", priority=1, deadline_ms=1e6)   # generous: all met
    for uid in range(3):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256, 4).astype(np.int32),
                         max_new_tokens=2), stream="xr")
    s.run_until_done()
    doc = s.metrics.summary(paging=eng.paging_summary())
    assert doc["requests"]["count"] == 3
    assert doc["deadlines"] == dict(with_deadline=3, missed=0,
                                    miss_rate=0.0, truncated=0)
    assert doc["ticks"]["count"] == s.ticks
    assert doc["throughput"]["tok_per_s"] > 0

"""Checkpoint / data / optimizer / trainer fault-tolerance tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CheckpointRestoreError,
                              save_pytree, restore_pytree)
from repro.data import SyntheticLMDataset, prefetch
from repro.optim import adamw, adafactor, clip_by_global_norm
from repro.runtime import FailureInjector, StragglerMonitor, Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(rng):
    return dict(a=jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                nested=dict(b=jnp.asarray(rng.integers(0, 10, (3,)),
                                          jnp.int32)),
                lst=[jnp.ones((2,)), jnp.zeros((5,), jnp.bfloat16)])


def test_save_restore_identity(tmp_path, rng):
    tree = _tree(rng)
    save_pytree(tree, tmp_path / "ck")
    out = restore_pytree(tree, tmp_path / "ck")
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left(tmp_path, rng):
    save_pytree(_tree(rng), tmp_path / "ck")
    assert not (tmp_path / "ck.tmp").exists()
    # overwrite is atomic too
    save_pytree(_tree(rng), tmp_path / "ck")
    assert (tmp_path / "ck" / "manifest.json").exists()


def test_manager_keep_n_and_latest(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    tree = _tree(rng)
    for step in (5, 10, 15, 20):
        mgr.save(step, tree)
    assert mgr.all_steps() == [15, 20]
    assert mgr.latest_step() == 20
    step, out = mgr.restore(tree)
    assert step == 20


def test_async_save_then_wait(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_n=3, async_save=True)
    tree = _tree(rng)
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_restore_shape_mismatch_raises(tmp_path, rng):
    save_pytree(dict(a=jnp.zeros((4,))), tmp_path / "ck")
    with pytest.raises(ValueError):
        restore_pytree(dict(a=jnp.zeros((5,))), tmp_path / "ck")


def test_failing_async_save_surfaces_on_next_call(tmp_path, rng,
                                                  monkeypatch):
    """A background save that dies must not vanish: the error is raised
    on the NEXT save()/wait(), and a later clean save still works."""
    mgr = CheckpointManager(tmp_path, keep_n=3, async_save=True)
    tree = _tree(rng)

    import repro.checkpoint.manager as mgr_mod
    boom = RuntimeError("disk on fire")

    def failing_save(tree, directory):
        raise boom
    monkeypatch.setattr(mgr_mod, "save_pytree", failing_save)
    mgr.save(1, tree)
    with pytest.raises(RuntimeError, match="disk on fire"):
        mgr.wait()
    # the error is consumed once, not resurfaced forever
    mgr.wait()
    monkeypatch.undo()
    mgr.save(2, tree)
    mgr.wait()
    assert mgr.all_steps() == [2]


def test_partial_tmp_checkpoint_is_invisible(tmp_path, rng):
    """A crashed writer's ``step_XXXX.tmp`` is not a checkpoint: it never
    appears in all_steps()/latest_step(), and restore() skips it."""
    mgr = CheckpointManager(tmp_path, keep_n=3, async_save=False)
    tree = _tree(rng)
    mgr.save(1, tree)
    # simulate a crash mid-write of step 2: .tmp exists, rename never ran
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "leaf_0.npy").write_bytes(b"junk")
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    step, _out = mgr.restore(tree)
    assert step == 1


def test_restore_errors_are_typed_and_name_the_step(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_n=3, async_save=False)
    tree = _tree(rng)
    # nothing saved yet
    with pytest.raises(CheckpointRestoreError, match="no checkpoints"):
        mgr.restore(tree)
    # a renamed-but-damaged checkpoint names the step it failed for
    mgr.save(7, tree)
    os.remove(tmp_path / "step_00000007" / "manifest.json")
    with pytest.raises(CheckpointRestoreError, match="step 7") as ei:
        mgr.restore(tree)
    assert ei.value.step == 7
    # an explicitly requested missing step likewise
    with pytest.raises(CheckpointRestoreError) as ei:
        mgr.restore(tree, step=99)
    assert ei.value.step == 99


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_step_determinism():
    ds = SyntheticLMDataset(1000, 16, 4, seed=3)
    a = ds.batch(7)
    b = ds.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_differs():
    d0 = SyntheticLMDataset(1000, 16, 8, n_hosts=2, host_id=0)
    d1 = SyntheticLMDataset(1000, 16, 8, n_hosts=2, host_id=1)
    assert d0.local_batch == 4
    assert not np.array_equal(d0.batch(0)["tokens"], d1.batch(0)["tokens"])


def test_data_labels_are_next_tokens():
    ds = SyntheticLMDataset(1000, 16, 2)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_matches_direct():
    ds = SyntheticLMDataset(100, 8, 2)
    it = prefetch(ds, start_step=3, depth=2)
    for step in (3, 4, 5):
        got = next(it)
        np.testing.assert_array_equal(got["tokens"], ds.batch(step)["tokens"])


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_decreases_quadratic(make_opt):
    opt = make_opt()
    params = dict(w=jnp.asarray([[2.0, -3.0], [1.0, 4.0]]),
                  b=jnp.asarray([1.0, -1.0]))
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(0.05, jnp.float32))
    assert float(loss(params)) < 0.2 * l0


def test_clip_by_global_norm():
    grads = dict(a=jnp.full((10,), 100.0))
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(10 * 100.0 ** 2), rel=1e-5)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert total == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# fault-tolerant trainer: restart equivalence
# ---------------------------------------------------------------------------

def _make_trainer(tmp_path, fail_at=None, total=12):
    from repro.configs import ARCHS
    from repro.launch.steps import make_train_step
    cfg = ARCHS["qwen3-0.6b"].smoke()
    opt = adamw()
    step_fn = jax.jit(make_train_step(cfg, opt))
    from repro.models import transformer as tfm

    def init_state():
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        return dict(params=params, opt_state=opt.init(params))

    ds = SyntheticLMDataset(cfg.vocab_size, 32, 2, seed=1)
    injector = FailureInjector(fail_at or [])
    return Trainer(TrainerConfig(total_steps=total, checkpoint_every=4,
                                 checkpoint_dir=str(tmp_path), log_every=100),
                   step_fn, init_state, ds, failure_injector=injector)


def test_trainer_restart_equivalence(tmp_path):
    """A run crashed at step 7 and restarted produces bit-identical final
    params to an uninterrupted run."""
    clean = _make_trainer(tmp_path / "clean").run()
    crashed = _make_trainer(tmp_path / "crash", fail_at=[7]).run()
    assert crashed["restarts"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(clean["params"]),
                    jax.tree_util.tree_leaves(crashed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_gives_up_after_max_restarts(tmp_path):
    t = _make_trainer(tmp_path, fail_at=[1], total=4)
    t.injector = FailureInjector([1, 2, 3])
    t.cfg.max_restarts = 1
    # keeps failing at fresh steps -> exceeds budget

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            raise RuntimeError("boom")

    t.injector = AlwaysFail()
    with pytest.raises(RuntimeError):
        t.run()


def test_straggler_monitor_flags_outlier():
    import time
    m = StragglerMonitor(threshold=3.0, warmup=2)
    for i in range(6):
        m.step_start()
        time.sleep(0.02 if i != 4 else 0.2)
        flagged = m.step_end()
        assert flagged == (i == 4)
    assert m.flagged == [4]

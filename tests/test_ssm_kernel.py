"""Fused selective-scan Pallas kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import hbm_bytes_per_token, selective_scan_fused
from repro.models.ssm import selective_scan


@pytest.mark.parametrize("bsz,s,di,n,chunk,dib", [
    (2, 20, 12, 4, 8, 8),
    (1, 64, 32, 16, 16, 16),
    (2, 33, 24, 8, 16, 8),      # padding on both S and Di
    (1, 7, 8, 4, 16, 32),       # chunk/di_block larger than the problem
])
def test_fused_scan_matches_oracle(rng, bsz, s, di, n, chunk, dib):
    x = jnp.asarray(rng.normal(size=(bsz, s, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (bsz, s, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (di, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y = selective_scan_fused(x, dt, A, B, C, D, chunk=chunk, di_block=dib,
                             interpret=True)
    y_ref, _ = selective_scan(x, dt, A, B, C, D, chunk=7)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)


def test_fused_scan_bf16(rng):
    bsz, s, di, n = 1, 32, 16, 8
    x = jnp.asarray(rng.normal(size=(bsz, s, di)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (bsz, s, di)), jnp.bfloat16)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (di, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.bfloat16)
    C = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.bfloat16)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y = selective_scan_fused(x, dt, A, B, C, D, chunk=16, di_block=16,
                             interpret=True)
    y_ref, _ = selective_scan(x.astype(jnp.float32), dt.astype(jnp.float32),
                              A, B.astype(jnp.float32),
                              C.astype(jnp.float32), D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), rtol=0.05, atol=0.05)


def test_traffic_model():
    fused, unfused = hbm_bytes_per_token(8192, 16)
    assert unfused / fused > 100     # the whole point of the kernel

"""Property tests over the paging schedule/counter algebra (hypothesis).

Replaces the old exhaustive parameter sweep in test_paging_store.py with
randomized properties over ``(pages, resident_slots, ticks, budgets)``
for ``pass_counters`` / ``shared_pass_counters`` / ``kv_pass_counters``.
The module importorskips when hypothesis is absent (the optional [test]
extra) — test_paging_store.py keeps one deterministic smoke case so the
invariants stay covered under a bare ``pytest -x -q``.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.paging import (kv_pass_counters, make_schedule,
                               pass_counters, shared_pass_counters,
                               validate_schedule)

N_PAGES = st.integers(min_value=1, max_value=12)
SLOTS = st.integers(min_value=1, max_value=4)
PAGE_SIZES = st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                      max_size=8)


@given(n_pages=N_PAGES, slots=SLOTS)
@settings(max_examples=60, deadline=None)
def test_schedule_invariants(n_pages, slots):
    """Every page resident before use, the in-use page never evicted,
    residency bounded by the slot count — for any (pages, slots)."""
    sched = make_schedule(n_pages, resident_slots=slots)
    validate_schedule(sched, resident_slots=slots)
    assert [e.page for e in sched] == list(range(n_pages))
    if slots == 1:
        # single slot: no double-buffering, demand-fetch everything
        assert all(e.prefetch_next is None for e in sched)
        assert pass_counters(n_pages, 1) == dict(swaps=n_pages,
                                                 misses=n_pages)
    else:
        # proactive: every non-final page prefetches its successor
        for e in sched[:-1]:
            assert e.prefetch_next == e.page + 1


@given(n_pages=N_PAGES, slots=st.integers(min_value=2, max_value=4))
@settings(max_examples=60, deadline=None)
def test_pass_counters_conservation(n_pages, slots):
    """With >= 2 slots, one pass fetches every page exactly once and only
    the cold start demand-misses."""
    pc = pass_counters(n_pages, slots)
    assert pc == dict(swaps=n_pages, misses=1)


@given(sizes_a=PAGE_SIZES, sizes_b=PAGE_SIZES,
       ticks=st.integers(min_value=1, max_value=5),
       budget=st.integers(min_value=1, max_value=512),
       slots=st.integers(min_value=1, max_value=3))
@settings(max_examples=60, deadline=None)
def test_shared_pass_counters_conservation(sizes_a, sizes_b, ticks,
                                           budget, slots):
    """Pool-level conservation laws for random page sizes / budgets:
    every pass still fetches every page exactly once (swap OR pool hit),
    misses follow the schedule, evictions never exceed admissions."""
    nbytes = dict(a=sizes_a, b=sizes_b)
    out = shared_pass_counters(nbytes, budget, resident_slots=slots,
                               ticks=ticks)
    for m, sizes in nbytes.items():
        c = out[m]
        n = len(sizes)
        # each pass looks every page up exactly once
        assert c["swaps"] + c["pool_hits"] == ticks * n
        # schedule-level demand misses are budget-independent
        per_pass = pass_counters(n, slots)["misses"]
        assert c["misses"] == ticks * per_pass
        assert 0 <= c["evicted"] <= c["swaps"] * 2  # loose sanity bound
    # a page can only be evicted if some pass admitted it
    total_evictions = sum(out[m]["evicted"] for m in nbytes)
    total_swaps = sum(out[m]["swaps"] for m in nbytes)
    assert total_evictions <= total_swaps


@given(sizes=PAGE_SIZES, ticks=st.integers(min_value=1, max_value=5),
       slots=st.integers(min_value=2, max_value=3))
@settings(max_examples=40, deadline=None)
def test_shared_roomy_budget_swaps_once(sizes, ticks, slots):
    """A budget that fits everything: each page swaps exactly once ever,
    every later pass is pure pool hits, nothing is evicted."""
    out = shared_pass_counters(dict(m=sizes), sum(sizes) + 1,
                               resident_slots=slots, ticks=ticks)
    assert out["m"]["swaps"] == len(sizes)
    assert out["m"]["pool_hits"] == (ticks - 1) * len(sizes)
    assert out["m"]["evicted"] == 0


@given(sizes=st.lists(st.integers(min_value=10, max_value=64), min_size=1,
                      max_size=8),
       ticks=st.integers(min_value=1, max_value=5))
@settings(max_examples=40, deadline=None)
def test_shared_never_fits_budget_always_swaps(sizes, ticks):
    """A budget smaller than every page caches nothing: all swaps, no
    hits, no evictions (admit's never-fits pre-check)."""
    out = shared_pass_counters(dict(m=sizes), min(sizes) - 1, ticks=ticks)
    assert out["m"]["pool_hits"] == 0
    assert out["m"]["swaps"] == ticks * len(sizes)
    assert out["m"]["evicted"] == 0


@given(sizes=PAGE_SIZES, ticks=st.integers(min_value=1, max_value=4),
       budget=st.integers(min_value=1, max_value=512))
@settings(max_examples=40, deadline=None)
def test_kv_pass_counters_weights_only_equals_shared(sizes, ticks, budget):
    """On a weights-only event stream the unified kv_pass_counters replay
    IS shared_pass_counters — the superset property the runtime relies
    on when KV paging is attached."""
    events = [("pass", "m")] * ticks
    uni = kv_pass_counters(dict(m=sizes), budget, events)
    old = shared_pass_counters(dict(m=sizes), budget, ticks=ticks)
    for k in ("swaps", "misses", "pool_hits", "evicted"):
        assert uni["m"][k] == old["m"][k]


@given(blocks=st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                       max_size=6),
       budget=st.one_of(st.none(), st.integers(min_value=1,
                                               max_value=4096)),
       nb=st.integers(min_value=1, max_value=256))
@settings(max_examples=60, deadline=None)
def test_kv_pass_counters_kv_conservation(blocks, budget, nb):
    """KV batches: every listed block is looked up exactly once (swap or
    hit); with budget=None (pool-less table) every fetch swaps."""
    events = []
    for n in blocks:
        events.append(("kv", "m/kv", tuple((p, nb) for p in range(n))))
    out = kv_pass_counters({}, budget, events)
    total = sum(blocks)
    if total == 0:
        assert out.get("m/kv", dict(swaps=0))["swaps"] == 0
        return
    c = out["m/kv"]
    assert c["swaps"] + c["pool_hits"] == total
    assert c["misses"] == c["swaps"]           # every kv swap is a miss
    if budget is None:
        assert c["pool_hits"] == 0 and c["swaps"] == total
    elif budget >= nb and max(blocks) > 0:
        # single member, enough room for one page: a re-listed block hits
        distinct = len({p for n in blocks for p in range(n)})
        if budget >= nb * distinct:
            assert c["swaps"] == distinct

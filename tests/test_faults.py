"""Fault-tolerant page I/O (core/faults + the paging/serving stack).

The headline guarantee under test: for ANY seeded within-budget
FaultPlan, decode output is bit-exact vs the fault-free run — faults
cost retries and latency, never tokens.  Around it: typed errors,
deterministic replay, CRC-before-install, fence deadlines leaving the
pass resumable, per-tenant tick deferral, the close(wait=False)
install-leak regression, and the wire-serve (decode-skipping) path.
"""

import time

import jax
import numpy as np
import pytest

from repro.core.faults import (FaultInjector, FaultPlan, PageFetchError,
                               PageFetchTimeout, PagingError,
                               TransientFetchFault, as_injector,
                               new_fault_counters)
from repro.core.paging import HostPagedStore, SharedPagePool, retry_fetch
from repro.core.placement import (Placement, PlacementPlan, packed_sizes,
                                  plan_for_budget, wire_served_bits)
from repro.core.weight_store import freeze, uniform_policy
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import MultiScheduler, Request, Scheduler, ServingEngine

# fast backoffs everywhere: the *policy* under test is deterministic
# retry/recovery, not the wall-clock cost of sleeping
FAST = dict(backoff_s=1e-5, backoff_cap_s=1e-4)


# ---------------------------------------------------------------------------
# plan + injector units
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        FaultPlan(max_attempts=0)
    # the structural guarantee: a within-budget fetch ALWAYS succeeds,
    # so a plan whose faulty window covers the whole budget is rejected
    with pytest.raises(ValueError, match="max_faulty_attempts"):
        FaultPlan(max_faulty_attempts=4, max_attempts=4)
    with pytest.raises(ValueError, match="rates"):
        FaultPlan(fail_rate=1.5)
    with pytest.raises(TypeError, match="FaultPlan or FaultInjector"):
        as_injector("chaos")
    inj = FaultInjector(FaultPlan(seed=1))
    assert as_injector(inj) is inj
    assert as_injector(None) is None
    assert as_injector(FaultPlan(seed=1)).plan == inj.plan


def test_injector_decisions_are_pure_and_flips_are_single_bit():
    plan = FaultPlan(seed=5, fail_rate=0.3, bitflip_rate=0.5)
    a, b = FaultInjector(plan), FaultInjector(plan)
    buf = bytes(range(64))
    fired = 0
    for page in range(8):
        for attempt in range(plan.max_attempts):
            assert (a._unit("fail", "m", page, attempt)
                    == b._unit("fail", "m", page, attempt))
            ca, cb = (a.corrupt("m", page, attempt, buf),
                      b.corrupt("m", page, attempt, buf))
            assert ca == cb                  # replayable corruption
            if ca is not None:
                fired += 1
                assert attempt < plan.max_faulty_attempts
                assert len(ca) == len(buf)
                diff = sum(bin(x ^ y).count("1") for x, y in zip(ca, buf))
                assert diff == 1             # exactly one flipped bit
    assert fired > 0
    # corruption is applied to a copy decision-by-decision; the pristine
    # buffer itself is never mutated
    assert buf == bytes(range(64))
    # past the faulty-attempt window nothing transient ever fires
    assert a.corrupt("m", 0, plan.max_faulty_attempts, buf) is None
    hot = FaultInjector(FaultPlan(seed=0, fail_rate=0.9))
    raised = 0
    for page in range(16):
        try:
            hot.pre_fetch("m", page, 0)
        except TransientFetchFault as e:
            raised += 1
            assert (e.model, e.page, e.attempt) == ("m", page, 0)
    assert raised > 0


def test_backoff_is_bounded_and_monotone():
    plan = FaultPlan(backoff_s=0.001, backoff_cap_s=0.004)
    waits = [plan.backoff(a) for a in range(1, 8)]
    assert waits[0] == 0.001
    assert waits == sorted(waits)
    assert max(waits) == 0.004               # capped, never unbounded


class _StubStore:
    """Minimal retry_fetch host: name + injector + counters (no device)."""

    def __init__(self, plan):
        self.name = "stub"
        self.faults = as_injector(plan)
        self.fault_counters = new_fault_counters()
        self.tracer = None


def test_retry_exhaustion_raises_typed_error():
    plan = FaultPlan(max_attempts=3, max_faulty_attempts=2, **FAST)
    store = _StubStore(plan)

    def attempt(a):
        raise TransientFetchFault(model="stub", page=7, attempt=a)

    with pytest.raises(PageFetchError) as ei:
        retry_fetch(store, 7, attempt)
    err = ei.value
    assert isinstance(err, PagingError)      # one except clause catches all
    assert (err.model, err.page, err.attempts) == ("stub", 7, 3)
    assert isinstance(err.last_error, TransientFetchFault)
    assert store.fault_counters["injected"] == 3
    assert store.fault_counters["retries"] == 2   # budget-1 retries


# ---------------------------------------------------------------------------
# store-level: bit-exact streams under any seeded plan (hypothesis)
# ---------------------------------------------------------------------------

def _flat_store():
    rng = np.random.default_rng(0)
    params = {f"p{i:02d}": rng.standard_normal((32, 24)).astype(np.float32)
              for i in range(6)}
    return freeze(params, uniform_policy(8, min_size=64))


FLAT = _flat_store()
PLAN = plan_for_budget(FLAT, FLAT.packed_bytes // 2)
PAGE_BYTES = 1600                            # ~2 params per page


def _stream(faults=None, *, plan=PLAN, async_io=False, pool=None, name="m"):
    store = HostPagedStore(FLAT, PAGE_BYTES, plan=plan, pool=pool,
                           name=name, faults=faults)
    try:
        dev = dict(store.resident)
        if async_io:
            with store.begin_pass(resident_slots=2) as apass:
                dev.update(apass.fence())
        else:
            for _page, dp in store.stream(resident_slots=2):
                dev.update(dp)
        counters = dict(store.fault_counters)
    finally:
        store.close()
    dev = {n: (np.asarray(p.packed), np.asarray(p.scale))
           for n, p in dev.items()}
    return dev, counters


def _assert_same(got, want):
    assert got.keys() == want.keys()
    for n in got:
        assert np.array_equal(got[n][0], want[n][0]), n
        assert np.array_equal(got[n][1], want[n][1]), n


def _check_stream_bit_exact(seed, fail, flip, spike, async_io, page_bits):
    """For ANY within-budget plan, over every page encoding (fp identity,
    int8 identity, int4 re-encoded) and both schedules: the streamed
    device bytes equal the fault-free stream's, every CRC-caught
    corruption was re-fetched, and a replay injects identically."""
    plan = (PLAN if page_bits is None else PLAN.with_page_bits(page_bits))
    fp = FaultPlan(seed=seed, fail_rate=fail, bitflip_rate=flip,
                   spike_rate=spike, spike_s=1e-4, **FAST)
    clean, zeros = _stream(None, plan=plan, async_io=async_io)
    assert all(v == 0 for v in zeros.values())
    dev, c1 = _stream(fp, plan=plan, async_io=async_io)
    _assert_same(dev, clean)                 # faults never change bytes
    assert c1["checksum_failures"] == c1["refetches"]  # none installed
    dev2, c2 = _stream(fp, plan=plan, async_io=async_io)
    _assert_same(dev2, clean)
    assert c1 == c2                          # seeded replay, exactly


# deterministic smoke cases keep the invariant covered under a bare
# `pytest -x -q`; the hypothesis sweep below (CI installs the [test]
# extra) randomizes the same property over the whole plan space
@pytest.mark.parametrize("seed,fail,flip,async_io,page_bits", [
    (11, 0.5, 0.5, False, None),             # fp pages, sync schedule
    (12, 0.5, 0.5, True, 8),                 # int8 identity, async
    (13, 0.5, 0.5, True, 4),                 # int4 re-encoded, async
])
def test_stream_bit_exact_under_faults(seed, fail, flip, async_io,
                                       page_bits):
    _check_stream_bit_exact(seed, fail, flip, 0.1, async_io, page_bits)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # optional [test] extra
    pass
else:
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           fail=st.floats(min_value=0.0, max_value=0.6),
           flip=st.floats(min_value=0.0, max_value=0.6),
           spike=st.floats(min_value=0.0, max_value=0.3),
           async_io=st.booleans(),
           page_bits=st.sampled_from([None, 8, 4]))
    @settings(max_examples=15, deadline=None)
    def test_stream_bit_exact_under_any_plan(seed, fail, flip, spike,
                                             async_io, page_bits):
        _check_stream_bit_exact(seed, fail, flip, spike, async_io, page_bits)


def test_pooled_stream_bit_exact_under_faults():
    """Same guarantee through a SharedPagePool: a pooled member's faulted
    stream matches its private fault-free stream, and pool-cached pages
    skip re-fetch (retries are per host fetch, not per lookup)."""
    clean, _ = _stream(None)
    fp = FaultPlan(seed=9, fail_rate=0.5, bitflip_rate=0.5, **FAST)
    pool = SharedPagePool(1 << 30)
    store = HostPagedStore(FLAT, PAGE_BYTES, plan=PLAN, pool=pool,
                           name="m", faults=fp)
    try:
        for _ in range(3):                   # pass 2+ rides the pool
            dev = dict(store.resident)
            for _page, dp in store.stream(resident_slots=2):
                dev.update(dp)
        got = {n: (np.asarray(p.packed), np.asarray(p.scale))
               for n, p in dev.items()}
        _assert_same(got, clean)
        c = store.fault_counters
        assert c["injected"] > 0 and c["retries"] > 0
        assert c["checksum_failures"] == c["refetches"]
        # roomy budget: after the first pass every page is a pool hit,
        # so the fault path ran exactly once per page
        assert store.swap_count == len(store.pages)
    finally:
        store.close()


def test_fence_timeout_is_typed_and_resumable():
    stuck = tuple(("m", i) for i in range(len(
        HostPagedStore(FLAT, PAGE_BYTES, plan=PLAN).pages)))
    fp = FaultPlan(seed=0, stuck_pages=stuck, stuck_s=0.05, **FAST)
    store = HostPagedStore(FLAT, PAGE_BYTES, plan=PLAN, name="m", faults=fp)
    try:
        apass = store.begin_pass(resident_slots=2)
        with pytest.raises(PageFetchTimeout) as ei:
            apass.fence(timeout_s=0.001)
        assert ei.value.model == "m" and ei.value.pending >= 1
        assert store.fault_counters["fetch_timeouts"] == 1
        clean, _ = _stream(None)
        dev = dict(store.resident)
        dev.update(apass.fence())            # resumes, completes, matches
        got = {n: (np.asarray(p.packed), np.asarray(p.scale))
               for n, p in dev.items()}
        _assert_same(got, clean)
    finally:
        store.close()


def test_close_no_wait_never_installs_inflight_pages():
    """Regression: close(wait=False) while a fetch is mid-flight must not
    install the page into the store or the shared pool afterwards (the
    closed flag is checked again between fetch and install)."""
    stuck = tuple(("m", i) for i in range(8))
    fp = FaultPlan(seed=0, stuck_pages=stuck, stuck_s=0.2, **FAST)
    pool = SharedPagePool(1 << 30)
    store = HostPagedStore(FLAT, PAGE_BYTES, plan=PLAN, pool=pool,
                           name="m", faults=fp)
    apass = store.begin_pass(resident_slots=2)
    store.close(wait=False)                  # fetch 0 is inside stuck_s
    time.sleep(0.5)                          # let the worker run its abort
    assert store.swap_count == 0             # nothing counted as installed
    assert store._live == {}
    assert pool.live_bytes == 0
    assert all(pool.lookup("m", i) is None for i in range(len(store.pages)))
    apass.close()                            # drains cancelled futures


# ---------------------------------------------------------------------------
# serving: tokens bit-exact under chaos, solo and under tenancy
# ---------------------------------------------------------------------------

CFG_A = ModelConfig(name="tinyFA", family="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                    head_dim=16, remat=False)
CFG_B = ModelConfig(name="tinyFB", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
                    head_dim=12, remat=False)
CHAOS = FaultPlan(seed=0, fail_rate=0.45, bitflip_rate=0.45,
                  spike_rate=0.1, spike_s=1e-4, **FAST)


@pytest.fixture(scope="module")
def packed_a():
    return freeze_for_serving(tfm.init_params(CFG_A, jax.random.PRNGKey(0)),
                              bits=8)


@pytest.fixture(scope="module")
def packed_b():
    return freeze_for_serving(tfm.init_params(CFG_B, jax.random.PRNGKey(1)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


def _paged_bytes(packed):
    sizes = packed_sizes(packed)
    plan = _half_paged_plan(packed)
    return sum(v for k, v in sizes.items() if plan.placement_for(k).paged)


def _prompts(n=4):
    rng = np.random.default_rng(0)
    return [rng.integers(0, 256, 3 + 4 * i).astype(np.int32)
            for i in range(n)]


def _serve_solo(cfg, packed, *, faults=None, async_io=True, seed=0):
    eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64,
                        plan=_half_paged_plan(packed), seed=seed)
    eng.attach_paging(faults=faults)
    s = Scheduler(eng, prefill_chunk=8, async_io=async_io)
    for uid, p in enumerate(_prompts()):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
    done = s.run_until_done()
    out = {r.uid: r.generated for r in done}
    fs, swaps, ticks = s.faults_summary(), eng.swap_count, s.ticks
    eng.pager.close()
    return out, fs, swaps, ticks


@pytest.mark.parametrize("async_io", [True, False])
@pytest.mark.slow
def test_solo_serving_bit_exact_under_faults(async_io):
    clean, zeros, swaps0, _ = _serve_solo(CFG_A, freeze_for_serving(
        tfm.init_params(CFG_A, jax.random.PRNGKey(0)), bits=8),
        async_io=async_io)
    assert all(v == 0 for v in zeros.values())
    chaos, fs, swaps1, _ = _serve_solo(CFG_A, freeze_for_serving(
        tfm.init_params(CFG_A, jax.random.PRNGKey(0)), bits=8),
        faults=CHAOS, async_io=async_io)
    assert chaos == clean                    # tokens never change
    assert fs["injected"] > 0 and fs["retries"] > 0
    assert fs["checksum_failures"] == fs["refetches"]
    assert fs["deferred_ticks"] == 0         # no deadline configured
    # retries re-run the host fetch, never the logical swap accounting
    assert swaps1 == swaps0


@pytest.mark.slow
def test_two_tenant_chaos_acceptance(packed_a, packed_b):
    """The bench/CI chaos leg's contract as a test: two tenants through
    one tight SharedPagePool under a seeded plan stay token-for-token
    bit-exact vs the fault-free run, with at least one retried transient
    AND one CRC-caught bit-flip actually exercised, every corruption
    re-fetched, and the swap/weight counters unchanged by the faults."""
    budget = int((_paged_bytes(packed_a) + _paged_bytes(packed_b)) * 0.6)

    def run(faults):
        eng_a = ServingEngine(CFG_A, packed_a, batch_slots=2, max_len=64,
                              plan=_half_paged_plan(packed_a), seed=0)
        eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                              plan=_half_paged_plan(packed_b), seed=1)
        ms = MultiScheduler(pool=SharedPagePool(budget), faults=faults)
        ms.add_model("a", eng_a, prefill_chunk=8)
        ms.add_model("b", eng_b, prefill_chunk=8)
        for uid, p in enumerate(_prompts()):
            ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=5))
            ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=5))
        done = ms.run_until_done()
        toks = {m: {r.uid: r.generated for r in rs}
                for m, rs in done.items()}
        doc = ms.summary()
        swaps = {m: ms.model(m).engine.swap_count for m in ("a", "b")}
        ms.close()
        return toks, doc, swaps

    toks0, doc0, swaps0 = run(None)
    assert all(v == 0 for v in doc0["totals"]["faults"].values())
    toks1, doc1, swaps1 = run(CHAOS)
    assert toks1 == toks0                    # bit-exact across the board
    ft = doc1["totals"]["faults"]
    assert ft["injected"] > 0 and ft["retries"] > 0
    assert ft["checksum_failures"] > 0       # CRC path genuinely exercised
    assert ft["checksum_failures"] == ft["refetches"]
    assert ft["fetch_timeouts"] == 0 and ft["deferred_ticks"] == 0
    assert swaps1 == swaps0                  # retries invisible to ledgers
    for m in ("a", "b"):
        mf = doc1["models"][m]["faults"]
        assert mf["injected"] > 0            # both tenants saw chaos


@pytest.mark.slow
def test_stuck_tenant_defers_only_its_own_ticks(packed_a, packed_b):
    """Graceful degradation is per tenant: a stuck page + fetch deadline
    on tenant A defers A's ticks (fence times out, pass resumes) while
    tenant B's ticks, tokens, and deadline-miss rate are untouched — and
    A still finishes bit-exact once the stuck fetches land."""
    budget = int((_paged_bytes(packed_a) + _paged_bytes(packed_b)) * 0.6)

    def run(stuck):
        eng_a = ServingEngine(CFG_A, packed_a, batch_slots=2, max_len=64,
                              plan=_half_paged_plan(packed_a), seed=0)
        eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                              plan=_half_paged_plan(packed_b), seed=1)
        ms = MultiScheduler(pool=SharedPagePool(budget))
        if stuck:
            # page 0 of tenant A hangs 0.1 s on EVERY fetch; the tight
            # budget forces that fetch on every pass, and A's 5 ms fence
            # deadline converts each hang into a deferred tick
            ms.add_model("a", eng_a, prefill_chunk=8, fetch_timeout_s=0.005,
                         faults=FaultPlan(seed=0, stuck_pages=(("a", 0),),
                                          stuck_s=0.1, **FAST))
        else:
            ms.add_model("a", eng_a, prefill_chunk=8)
        ms.add_model("b", eng_b, prefill_chunk=8)
        for uid, p in enumerate(_prompts()):
            ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=4))
            ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=4,
                                   deadline_ms=1e6))
        done = ms.run_until_done()
        toks = {m: {r.uid: r.generated for r in rs}
                for m, rs in done.items()}
        fs = {m: ms.model(m).faults_summary() for m in ("a", "b")}
        doc = ms.summary()
        ms.close()
        return toks, fs, doc

    toks0, _, doc0 = run(stuck=False)
    toks1, fs, doc1 = run(stuck=True)
    assert toks1 == toks0                    # degradation never costs tokens
    assert fs["a"]["fetch_timeouts"] > 0
    assert fs["a"]["deferred_ticks"] > 0     # A paid the stuck lane...
    assert fs["b"]["fetch_timeouts"] == 0
    assert fs["b"]["deferred_ticks"] == 0    # ...B never noticed
    for doc in (doc0, doc1):                 # B's miss rate unchanged
        assert doc["models"]["b"]["deadlines"]["miss_rate"] == 0.0
        assert doc["models"]["b"]["deadlines"]["with_deadline"] > 0


# ---------------------------------------------------------------------------
# wire-serve: cold int8 pages skip the host decode, faults still invisible
# ---------------------------------------------------------------------------

CFG_W = ModelConfig(name="tinyFW", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
                    head_dim=12, remat=False)


@pytest.mark.slow
def test_wire_serve_skips_decode_and_survives_faults():
    packed = freeze_for_serving(tfm.init_params(CFG_W, jax.random.PRNGKey(0)),
                                bits=4)
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2, sizes_bits=4,
                           hot=Placement("l1mram", 4, "resident"),
                           cold=Placement("l1mram", 4, "paged", 8))
    prompts = _prompts()

    def serve(wire_serve, faults=None):
        eng = ServingEngine(CFG_W, packed, batch_slots=2, max_len=64,
                            plan=plan)
        eng.attach_paging(wire_serve=wire_serve, faults=faults)
        if wire_serve:
            # the store's wire-served set IS the placement predicate the
            # model's `linear` dispatches on — one source of truth
            wired = {n for n in eng.pager._host
                     if wire_served_bits(eng.plan, n) is not None}
            assert wired and wired == eng.pager.wire_served
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
        toks = {r.uid: r.generated for r in eng.run_until_done()}
        pg, fs = eng.paging_summary(), eng.faults_summary()
        decode_s = eng.pager.decode_s
        eng.pager.close()
        return toks, pg, fs, decode_s

    base, pg0, _, _ = serve(False)
    assert pg0["decode_skipped_bytes"] == 0 and pg0["swap_count"] > 0
    w1, pg1, _, dec1 = serve(True)
    w2, pg2, _, _ = serve(True)
    assert w1 == w2                          # deterministic
    assert pg1["decode_skipped_bytes"] > 0
    assert dec1 == 0.0                       # no fetch decode ran at all
    wf, _, fs, decf = serve(True, faults=FaultPlan(seed=3, fail_rate=0.2,
                                                   bitflip_rate=0.2, **FAST))
    assert wf == w1                          # chaos invisible on this path too
    assert fs["injected"] > 0
    assert fs["checksum_failures"] == fs["refetches"]
    assert decf == 0.0                       # CRC runs, decode still skipped

"""MoE dispatch and selective-scan invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional test dependency (declared as the [test] extra in pyproject.toml):
# without it the property tests are skipped, not a collection error
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import moe, ssm


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@given(t=st.integers(4, 64), e=st.integers(2, 8), k=st.integers(1, 2))
@settings(max_examples=25, deadline=None)
def test_dispatch_combine_conservation(t, e, k):
    """With infinite capacity, dispatch+identity-experts+combine equals
    gate-weighted identity (every token routed to exactly k experts)."""
    k = min(k, e)
    rng = np.random.default_rng(t * 31 + e)
    x = jnp.asarray(rng.normal(size=(t, 8)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(e, 8)), jnp.float32)
    gates, idx = moe.route(x, router, k)
    # gates are a distribution over the chosen experts
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    cap = t * k  # no drops
    buf, combine = moe.dispatch_combine(x, gates, idx, e, cap)
    out = combine(buf)  # identity experts
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


def test_capacity_drops_bounded(rng):
    t, e, k, d = 64, 4, 2, 8
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    gates, idx = moe.route(x, router, k)
    cap = 8  # deliberately tight
    buf, combine = moe.dispatch_combine(x, gates, idx, e, cap)
    out = np.asarray(combine(buf))
    # surviving assignments reproduce <= gate-weighted identity; dropped
    # tokens contribute 0 — norm never exceeds the no-drop case
    full = np.asarray(x)
    assert (np.linalg.norm(out, axis=-1) <= np.linalg.norm(full, axis=-1)
            + 1e-5).all()


def test_moe_apply_shapes_and_shared(rng):
    t, d, e, f = 16, 32, 4, 64
    x = jnp.asarray(rng.normal(size=(2, t, d)), jnp.float32)
    p = dict(
        router=jnp.asarray(rng.normal(size=(e, d)), jnp.float32),
        w_gate=jnp.asarray(rng.normal(size=(e, f, d)) * 0.1, jnp.float32),
        w_up=jnp.asarray(rng.normal(size=(e, f, d)) * 0.1, jnp.float32),
        w_down=jnp.asarray(rng.normal(size=(e, d, f)) * 0.1, jnp.float32),
        shared=dict(
            w_gate=jnp.asarray(rng.normal(size=(f, d)) * 0.1, jnp.float32),
            w_up=jnp.asarray(rng.normal(size=(f, d)) * 0.1, jnp.float32),
            w_down=jnp.asarray(rng.normal(size=(d, f)) * 0.1, jnp.float32)),
    )
    out = moe.moe_apply(x, p, n_experts=e, k=2)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # aux loss is positive and ~1 for uniform routing
    gates, idx = moe.route(x.reshape(-1, d), p["router"], 2)
    aux = moe.router_aux_loss(x, p["router"], idx.reshape(-1, 2), e)
    assert float(aux) > 0


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

def _naive_scan(x, dt, A, B, C, D):
    bsz, s, di = x.shape
    n = A.shape[1]
    h = np.zeros((bsz, di, n), np.float32)
    ys = []
    for t in range(s):
        dA = np.exp(dt[:, t, :, None] * A[None])
        dBx = dt[:, t, :, None] * B[:, t, None, :] * x[:, t, :, None]
        h = dA * h + dBx
        ys.append((h * C[:, t, None, :]).sum(-1))
    y = np.stack(ys, 1) + x * D[None, None]
    return y, h


@pytest.mark.parametrize("chunk", [4, 7, 32])
def test_selective_scan_vs_naive(rng, chunk):
    bsz, s, di, n = 2, 20, 6, 4
    x = rng.normal(size=(bsz, s, di)).astype(np.float32)
    dt = rng.uniform(0.001, 0.1, size=(bsz, s, di)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(di, n)).astype(np.float32)
    B = rng.normal(size=(bsz, s, n)).astype(np.float32)
    C = rng.normal(size=(bsz, s, n)).astype(np.float32)
    D = rng.normal(size=(di,)).astype(np.float32)
    y, h = ssm.selective_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                              jnp.asarray(B), jnp.asarray(C), jnp.asarray(D),
                              chunk=chunk)
    y_ref, h_ref = _naive_scan(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_decode_step_continues_scan(rng):
    """Running the recurrence one token at a time from the scan's final
    state matches running the scan over the concatenated sequence."""
    bsz, s, di, n = 1, 12, 4, 3
    x = rng.normal(size=(bsz, s + 1, di)).astype(np.float32)
    dt = rng.uniform(0.01, 0.1, size=(bsz, s + 1, di)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(di, n)).astype(np.float32)
    B = rng.normal(size=(bsz, s + 1, n)).astype(np.float32)
    C = rng.normal(size=(bsz, s + 1, n)).astype(np.float32)
    D = rng.normal(size=(di,)).astype(np.float32)
    y_full, h_full = ssm.selective_scan(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A), jnp.asarray(B),
        jnp.asarray(C), jnp.asarray(D), chunk=5)
    y_pre, h_pre = ssm.selective_scan(
        jnp.asarray(x[:, :s]), jnp.asarray(dt[:, :s]), jnp.asarray(A),
        jnp.asarray(B[:, :s]), jnp.asarray(C[:, :s]), jnp.asarray(D), chunk=5)
    y_step, h_step = ssm.ssm_decode_step(
        jnp.asarray(x[:, s]), jnp.asarray(dt[:, s]), jnp.asarray(A),
        jnp.asarray(B[:, s]), jnp.asarray(C[:, s]), jnp.asarray(D), h_pre)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, s]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_state_streaming(rng):
    bsz, s, c, k = 2, 10, 4, 4
    x = jnp.asarray(rng.normal(size=(bsz, s, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(c, k)), jnp.float32)
    y_full, state = ssm.causal_conv1d(x, w, None)
    # streaming: one token at a time carrying state
    st_ = jnp.zeros((bsz, k - 1, c), jnp.float32)
    ys = []
    for t in range(s):
        y_t, st_ = ssm.causal_conv1d(x[:, t:t + 1], w, None, st_)
        ys.append(y_t)
    y_stream = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_stream), np.asarray(y_full),
                               rtol=1e-5, atol=1e-5)

"""Continuous batching: per-tick token budgets, mid-request preemption
and admission control (the tentpole of this PR).

Invariants under test:

  * **budget plan math** (pure policy, no jax compute): decode-ready
    slots cost one token off the top, the remainder is dealt to
    mid-prefill slots in admission-key order capped at the chunk, an
    exhausted budget holds the frontier, and exact-length families
    (hybrid / moe) are all-or-nothing;
  * **deterministic admission**: ties on (priority, deadline) break on
    the monotonic submission sequence — never on dict/list order;
  * **admission control**: with a seeded tick cost, a predicted-miss
    request is rejected (never served, never recorded) or degraded to
    the longest completion that still fits its deadline;
  * **preempt/restore bit-exactness**: a request evicted mid-decode or
    mid-prefill and later restored produces EXACTLY the tokens of an
    unpreempted run — dense and vlm and ssm, private KV table and
    shared pool, async and sync (greedy sampling; the engine RNG stream
    makes stochastic sampling legitimately order-dependent);
  * **counter stability**: preemption leaves no orphaned begun pass, no
    leaked pool guard, and the weight/KV paging counters still equal
    their static ``pass_counters`` / ``kv_pass_counters`` predictions;
  * **random preemption points** (seeded sweep; the hypothesis twin
    lives in tests/test_preemption_properties.py): tokens are invariant
    to WHEN the urgent request lands.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.paging import (SharedPagePool, kv_pass_counters,
                               pass_counters)
from repro.core.placement import PlacementPlan, packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MetricsRecorder, MultiScheduler, Request,
                           Scheduler, ServingEngine, validate)

CFG = ModelConfig(name="tinycb", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)


@pytest.fixture(scope="module")
def packed():
    return freeze_for_serving(tfm.init_params(CFG, jax.random.PRNGKey(0)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


# ---------------------------------------------------------------------------
# fast lane: pure policy math on a slot-state stub (no jit, no compute)
# ---------------------------------------------------------------------------

class _SlotStub:
    """Just enough engine surface for the policy-only scheduler paths:
    slot occupancy, the bucketing flag, and the submit-time fit check."""

    def __init__(self, slot_req, bucketed=True):
        self.slot_req = list(slot_req)
        self._bucketed = bucketed
        self.waiting = []

    def _check_fits(self, req):
        pass

    def free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]


def _req(uid, n_prompt, *, pos=0, prio=0, deadline=None, arrival=0.0,
         seq=None, max_new=4):
    r = Request(uid=uid, prompt=np.arange(n_prompt, dtype=np.int32),
                max_new_tokens=max_new)
    r.prefill_pos = pos
    r.priority = prio
    r.deadline_ms = deadline
    r.arrival_s = arrival
    r.seq = seq if seq is not None else uid
    return r


def test_budget_plan_decode_first_then_prefill_by_key():
    decoding = _req(0, 4, pos=4)                        # decode-ready
    low = _req(1, 40, pos=0, prio=0, seq=5)
    high = _req(2, 40, pos=8, prio=2, seq=6)
    s = Scheduler(_SlotStub([decoding, low, high, None]), prefill_chunk=16,
                  token_budget=20, clock=lambda: 0.0)
    plan = s._plan_tick()
    # decode costs 1 off the top; the high-priority prefill takes a full
    # chunk; the low-priority one gets the 3 tokens left
    assert plan == {2: 16, 1: 3}
    assert s._tick_budget_tokens == 20
    assert s._tick_budget_used == 20


def test_budget_plan_exhaustion_holds_frontier_never_starves_decode():
    decoding = _req(0, 4, pos=4)
    prefilling = _req(1, 32, pos=0)
    s = Scheduler(_SlotStub([decoding, prefilling]), prefill_chunk=8,
                  token_budget=1, clock=lambda: 0.0)
    plan = s._plan_tick()
    # the whole budget funds the decode step; the prefill slot is simply
    # absent from the plan (frontier held, resumed when budget returns)
    assert plan == {}
    assert s._tick_budget_used == 1


def test_budget_plan_exact_length_families_all_or_nothing():
    a = _req(0, 40, pos=0, prio=1, seq=0)
    b = _req(1, 24, pos=0, prio=0, seq=1)
    s = Scheduler(_SlotStub([a, b], bucketed=False), token_budget=8,
                  clock=lambda: 0.0)
    plan = s._plan_tick()
    # hybrid/moe prompts cannot be sliced: the scheduled slot absorbs its
    # whole prompt (documented overrun), exhausting the budget for b
    assert plan == {0: 40}
    assert s._tick_budget_used == 40


def test_admission_tie_break_is_submission_sequence():
    s = Scheduler(_SlotStub([None]), clock=lambda: 0.0)
    s.add_stream("xr", priority=1, deadline_ms=10.0)
    # identical (priority, absolute deadline) — only seq can order them;
    # uids are deliberately descending so a uid-ordered sort would differ
    for uid in (9, 5, 7):
        s.submit(Request(uid=uid, prompt=np.arange(3, dtype=np.int32)),
                 stream="xr")
    assert [r.uid for r in s.admission_order()] == [9, 5, 7]
    assert [r.seq for r in s.admission_order()] == [0, 1, 2]


def test_admission_reject_never_serves_predicted_miss():
    s = Scheduler(_SlotStub([None]), prefill_chunk=8, admission="reject",
                  est_tick_s=1e-3, clock=lambda: 0.0)
    s.add_stream("xr", deadline_ms=10.0)
    # 16-token prompt => 2 prefill ticks; +19 decode ticks = 21 needed,
    # but only floor(10ms / 1ms) = 10 ticks of slack: certain miss
    doomed = Request(uid=0, prompt=np.arange(16, dtype=np.int32),
                     max_new_tokens=20)
    fits = Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=2)
    s.submit(doomed, stream="xr")
    s.submit(fits, stream="xr")
    s._admission_control()
    assert [r.uid for r in s.queue] == [1]
    assert s.rejected == [doomed] and doomed.rejected
    assert doomed.finish_s is not None
    assert s.metrics.rejected == 1
    assert s.metrics.records == []         # refused, never "served"


def test_admission_degrade_cuts_to_longest_feasible_completion():
    s = Scheduler(_SlotStub([None]), prefill_chunk=8, admission="degrade",
                  est_tick_s=1e-3, clock=lambda: 0.0)
    s.add_stream("xr", deadline_ms=10.0)
    req = Request(uid=0, prompt=np.arange(16, dtype=np.int32),
                  max_new_tokens=20)
    s.submit(req, stream="xr")
    s._admission_control()
    # slack 10 ticks - 2 prefill ticks + 1 = 9 tokens still fit
    assert s.queue == [req]
    assert req.max_new_tokens == 9 and req.degraded
    assert s.metrics.degraded == 1
    # re-running the controller must not double-count the degrade
    s._admission_control()
    assert s.metrics.degraded == 1


def test_est_tick_s_composes_compute_and_exposed_stall():
    s = Scheduler(_SlotStub([None]), clock=lambda: 0.0)
    assert s.est_tick_s() is None          # no data, no seed: optimistic
    s._compute_ema = 2e-3
    s._swap_ema = 1e-3                     # fully hidden under compute
    assert s.est_tick_s() == pytest.approx(2e-3)
    s._swap_ema = 5e-3                     # 3 ms of the stream exposed
    assert s.est_tick_s() == pytest.approx(5e-3)


# ---------------------------------------------------------------------------
# preempt/restore bit-exactness (real engines, greedy sampling)
# ---------------------------------------------------------------------------

def _mk_reqs(prompts, max_new):
    return [Request(uid=uid, prompt=np.asarray(p, np.int32),
                    max_new_tokens=mn)
            for uid, (p, mn) in enumerate(zip(prompts, max_new))]


def _reference(cfg, packed, prompts, max_new, *, slots=2, max_len=64,
               prefill_chunk=8):
    """Unpreempted tokens: same traffic, plain scheduler, fresh engine."""
    eng = ServingEngine(cfg, packed, batch_slots=slots, max_len=max_len)
    s = Scheduler(eng, prefill_chunk=prefill_chunk)
    for r in _mk_reqs(prompts, max_new):
        s.submit(r)
    return {r.uid: r.generated for r in s.run_until_done()}


def _serve_with_preempt(cfg, packed, prompts, max_new, *, warm_ticks,
                        urgent_uid, slots=1, max_len=64, prefill_chunk=8,
                        async_io=True, plan=None, kv=False, pool=None,
                        kv_block=4):
    """Serve ``prompts[:-1]`` first, inject ``prompts[urgent_uid]`` on a
    priority-2 stream after ``warm_ticks``, and drain."""
    eng = ServingEngine(cfg, packed, batch_slots=slots, max_len=max_len,
                        plan=plan if plan is not None
                        else PlacementPlan.uniform())
    if plan is not None and plan.paged_bytes(packed_sizes(packed)) > 0:
        eng.attach_paging(pool=pool, name="m")
    if kv:
        eng.attach_kv_paging(kv_block, pool=pool, name="m/kv")
    s = Scheduler(eng, prefill_chunk=prefill_chunk, async_io=async_io,
                  preemptive=True)
    s.add_stream("urgent", priority=2)
    reqs = _mk_reqs(prompts, max_new)
    for r in reqs:
        if r.uid != urgent_uid:
            s.submit(r)
    done = []
    for _ in range(warm_ticks):
        done += s.tick()
    s.submit(reqs[urgent_uid], stream="urgent")
    done += s.run_until_done()
    return {r.uid: r.generated for r in done}, s, eng


def _close(eng):
    if eng.pager is not None:
        eng.pager.close()
    if eng.kv_table is not None:
        eng.kv_table.close()


@pytest.mark.parametrize("async_io", [True, False])
def test_preempt_mid_decode_bit_exact_dense(rng, packed, async_io):
    prompts = [rng.integers(0, 256, 6).astype(np.int32),
               rng.integers(0, 256, 5).astype(np.int32)]
    ref = _reference(CFG, packed, prompts, [10, 3], slots=1)
    got, s, eng = _serve_with_preempt(CFG, packed, prompts, [10, 3],
                                      warm_ticks=4, urgent_uid=1,
                                      async_io=async_io)
    assert got == ref
    # the single slot was mid-decode: the victim checkpointed exactly once
    # and resumed exactly once, and the request carries the event
    assert eng.preempt_count == eng.restore_count == 1
    victim = next(r for r in s.finished if r.uid == 0)
    assert victim.preemptions == 1
    assert s.metrics.preemptions == s.metrics.restores == 1
    doc = validate(s.metrics.summary())
    assert doc["scheduler"]["preemptions"] == 1
    assert doc["scheduler"]["restores"] == 1


def test_preempt_mid_prefill_resumes_at_chunk_frontier(rng, packed):
    # 32-token prompt at chunk 4: warm_ticks=3 preempts at frontier 12,
    # long before the first generated token exists
    prompts = [rng.integers(0, 256, 32).astype(np.int32),
               rng.integers(0, 256, 4).astype(np.int32)]
    ref = _reference(CFG, packed, prompts, [4, 2], slots=1,
                     prefill_chunk=4)
    got, s, eng = _serve_with_preempt(CFG, packed, prompts, [4, 2],
                                      warm_ticks=3, urgent_uid=1,
                                      prefill_chunk=4)
    assert got == ref
    assert eng.preempt_count == eng.restore_count == 1
    victim = next(r for r in s.finished if r.uid == 0)
    assert victim.preemptions == 1 and not victim.truncated


def test_preempted_victim_outranks_later_best_effort(rng, packed):
    """The checkpoint re-enters the unified admission pool under its own
    key: an urgent victim must win the slot back ahead of best-effort
    requests that arrived while it was parked."""
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    s = Scheduler(eng, prefill_chunk=8, preemptive=True)
    s.add_stream("mid", priority=1)
    s.add_stream("top", priority=2)
    p = rng.integers(0, 256, 4).astype(np.int32)
    victim = Request(uid=0, prompt=p, max_new_tokens=8)
    s.submit(victim, stream="mid")
    for _ in range(3):
        s.tick()
    s.submit(Request(uid=1, prompt=p, max_new_tokens=2), stream="top")
    s.submit(Request(uid=2, prompt=p, max_new_tokens=2))  # best effort
    done = s.run_until_done()
    # the preempted priority-1 victim resumes before the best-effort one
    assert [r.uid for r in done] == [1, 0, 2]
    assert victim.preemptions == 1


@pytest.mark.slow
def test_preempt_bit_exact_vlm(rng):
    cfg = get_config("llava-next-34b").smoke()
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(2)),
                                bits=8)
    prompts = [rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
               rng.integers(0, cfg.vocab_size, 5).astype(np.int32)]
    ref = _reference(cfg, packed, prompts, [8, 2], slots=1)
    got, _s, eng = _serve_with_preempt(cfg, packed, prompts, [8, 2],
                                       warm_ticks=4, urgent_uid=1)
    assert got == ref
    assert eng.preempt_count == eng.restore_count == 1


@pytest.mark.slow
def test_preempt_bit_exact_ssm_state_checkpoint(rng):
    """SSM victims carry recurrent state, not KV rows: the checkpoint
    must round-trip h/conv exactly through preempt -> restore."""
    cfg = get_config("falcon-mamba-7b").smoke()
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(3)),
                                bits=8)
    prompts = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
               rng.integers(0, cfg.vocab_size, 4).astype(np.int32)]
    ref = _reference(cfg, packed, prompts, [8, 2], slots=1)
    got, _s, eng = _serve_with_preempt(cfg, packed, prompts, [8, 2],
                                       warm_ticks=4, urgent_uid=1)
    assert got == ref
    assert eng.preempt_count == eng.restore_count == 1


@pytest.mark.slow
@pytest.mark.parametrize("pooled", [True, False])
def test_preempt_kv_paged_tokens_and_counter_replay(rng, packed, pooled):
    """Preemption drops the victim's pooled KV blocks and the restore
    re-writes them back through fresh sync events — so the event-log
    replay (``kv_pass_counters``) must still predict every counter, and
    the weight stream must stay on its ticks x pass_counters line."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 10).astype(np.int32),
               rng.integers(0, 256, 5).astype(np.int32)]
    ref = _reference(CFG, packed, prompts, [8, 2], slots=1)
    pool = SharedPagePool(1 << 30) if pooled else None
    got, s, eng = _serve_with_preempt(CFG, packed, prompts, [8, 2],
                                      warm_ticks=5, urgent_uid=1,
                                      plan=plan, kv=True, pool=pool)
    assert got == ref
    assert eng.preempt_count == eng.restore_count == 1
    # preempt_drops counts preemption EVENTS; dropped counts pooled
    # blocks actually invalidated (private tables never pool, so it
    # stays 0 there)
    assert eng.kv_table.preempt_drops >= 1
    if pooled:
        pred = kv_pass_counters(
            {"m": [p.nbytes for p in eng.pager.pages]},
            pool.budget_bytes, pool.events)
        summ = pool.summary()
        for m in ("m", "m/kv"):
            for k in ("swaps", "misses", "pool_hits", "evicted"):
                assert summ["models"][m][k] == pred[m][k], (m, k)
        assert not pool._active_fetch      # no leaked eviction guard
    else:
        pred = kv_pass_counters({}, None, eng.kv_table.events)
        assert pred["m/kv"]["swaps"] == eng.kv_table.swap_count
        # private pager: every pass re-streams every page, so the weight
        # counters sit on the static per-tick line (a pooled run retains
        # pages across passes — its prediction is the event replay above)
        per_pass = pass_counters(len(eng.pager.pages),
                                 eng.page_resident_slots)
        assert eng.swap_count == s.ticks * per_pass["swaps"]
        assert eng.miss_count == s.ticks * per_pass["misses"]
    doc = validate(s.metrics.summary(paging=eng.paging_summary()))
    assert doc["paging"]["kv_preempt_drops"] == eng.kv_table.preempt_drops
    if pooled:
        pool.close()
    else:
        _close(eng)


def test_preempt_counter_stability_no_orphaned_pass(rng, packed):
    """A preemptive paged run must drain clean: no begun-but-unfenced
    weight pass, every checkpoint restored, every slot empty."""
    plan = _half_paged_plan(packed)
    prompts = [rng.integers(0, 256, 6).astype(np.int32),
               rng.integers(0, 256, 4).astype(np.int32)]
    _got, s, eng = _serve_with_preempt(CFG, packed, prompts, [8, 2],
                                       warm_ticks=4, urgent_uid=1,
                                       plan=plan)
    assert eng._inflight_pass is None
    assert s.preempted == [] and s.queue == []
    assert all(r is None for r in eng.slot_req)
    assert eng.preempt_count == eng.restore_count
    per_pass = pass_counters(len(eng.pager.pages), eng.page_resident_slots)
    assert eng.swap_count == s.ticks * per_pass["swaps"]
    eng.pager.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_preemption_point_bit_exact(packed, seed):
    """Tokens must be invariant to WHEN the urgent request lands — the
    seeded sweep over (prompt lengths, decode lengths, injection tick)
    that the hypothesis twin widens."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, int(rng.integers(2, 28)))
               .astype(np.int32) for _ in range(4)]
    max_new = [int(rng.integers(2, 8)) for _ in range(4)]
    warm = int(rng.integers(0, 10))
    ref = _reference(CFG, packed, prompts, max_new, slots=2,
                     prefill_chunk=4)
    got, s, eng = _serve_with_preempt(CFG, packed, prompts, max_new,
                                      warm_ticks=warm, urgent_uid=3,
                                      slots=2, prefill_chunk=4)
    assert got == ref, f"seed {seed} warm {warm}"
    assert eng.preempt_count == eng.restore_count
    assert s.metrics.preemptions == s.metrics.restores


# ---------------------------------------------------------------------------
# continuous batching end-to-end (budget + preemption + admission live)
# ---------------------------------------------------------------------------

def test_budgeted_serving_bit_exact_and_utilization(rng, packed):
    prompts = [rng.integers(0, 256, n).astype(np.int32)
               for n in (20, 12, 5)]
    ref = _reference(CFG, packed, prompts, [4] * 3, slots=2,
                     prefill_chunk=4)
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    s = Scheduler(eng, prefill_chunk=4, token_budget=6)
    for r in _mk_reqs(prompts, [4] * 3):
        s.submit(r)
    got = {r.uid: r.generated for r in s.run_until_done()}
    assert got == ref
    doc = validate(s.metrics.summary())
    sched = doc["scheduler"]
    assert sched["budget_tokens_per_tick"] == 6
    assert 0.0 < sched["budget_utilization"] <= 1.0
    # the budget genuinely paced prefill: with 2 slots at chunk 4 plus
    # decodes, an unbudgeted tick would spend up to 8+ tokens
    assert max(s.metrics.tick_budget_used) <= 6


def test_multischeduler_global_budget_and_preemption(rng, packed):
    """Two tenants under ONE token budget and preemptive admission:
    tokens bit-exact vs solo, counters aggregated into the v5 totals."""
    prompts = {"a": [rng.integers(0, 256, n).astype(np.int32)
                     for n in (14, 6)],
               "b": [rng.integers(0, 256, n).astype(np.int32)
                     for n in (10, 4)]}
    solo = {name: _reference(CFG, packed, ps, [5, 2], slots=1,
                             prefill_chunk=4)
            for name, ps in prompts.items()}
    ms = MultiScheduler(token_budget=8, preemptive=True)
    for name in ("a", "b"):
        eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
        ms.add_model(name, eng, prefill_chunk=4)
        ms.add_stream(name, "urgent", priority=2)
    for name, ps in prompts.items():
        reqs = _mk_reqs(ps, [5, 2])
        ms.submit(name, reqs[0])
    done = {}
    for _ in range(4):
        for n, rs in ms.tick().items():
            done.setdefault(n, []).extend(rs)
    for name, ps in prompts.items():
        ms.submit(name, _mk_reqs(ps, [5, 2])[1], stream="urgent")
    for n, rs in ms.run_until_done().items():
        done.setdefault(n, []).extend(rs)
    for name in ("a", "b"):
        got = {r.uid: r.generated for r in done[name]}
        assert got == solo[name], name
    doc = validate(ms.summary())
    assert doc["totals"]["preemptions"] >= 1
    assert doc["totals"]["preemptions"] == doc["totals"]["restores"]
    for name in ("a", "b"):
        assert (doc["models"][name]["scheduler"]["budget_tokens_per_tick"]
                == 8)
    ms.close()


def test_degraded_request_truncates_generation_not_tokens(rng, packed):
    """A degraded request serves its shortened completion and its tokens
    are a PREFIX of the undegraded generation (same greedy path)."""
    prompts = [rng.integers(0, 256, 6).astype(np.int32)]
    ref = _reference(CFG, packed, prompts, [8], slots=1)
    eng = ServingEngine(CFG, packed, batch_slots=1, max_len=64)
    clock = iter(np.arange(0.0, 10.0, 1e-3))
    s = Scheduler(eng, prefill_chunk=8, admission="degrade",
                  est_tick_s=1e-3, clock=lambda: next(clock))
    s.add_stream("xr", deadline_ms=4.0)
    s.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=8),
             stream="xr")
    done = s.run_until_done()
    assert len(done) == 1 and done[0].degraded
    n = len(done[0].generated)
    assert 1 <= n < 8
    assert done[0].generated == ref[0][:n]
    assert s.metrics.degraded == 1

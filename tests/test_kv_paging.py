"""KV-cache paging through the shared pool (the tentpole of the test PR).

The paper's one-memory-hierarchy constraint (§V): long-context KV state
must flow through the SAME budgeted, overlap-hidden page stream the
weights use.  Invariants under test:

  * decode tokens bit-exact vs the resident-KV engine — dense and vlm,
    private table and shared pool, roomy and tight budgets, solo and
    two-tenant, async and sync;
  * kv_swaps / kv_pool_hits / evicted match the static
    ``kv_pass_counters`` replay of the pool event log, while the weights
    keep their ``ticks x pass_counters`` equality;
  * prefill jit cache keyed by (bucket, kv_span) stays O(log^2 max_len);
  * the per-tick exposed/hidden split obeys ``memsys.overlap_stall``
    with KV pages in flight;
  * early close / cancel / slot reuse leak regressions.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memsys import kv_stream_bytes, overlap_stall
from repro.core.paging import (KVPageTable, SharedPagePool,
                               kv_pass_counters, page_sizes, pass_counters,
                               shared_pass_counters)
from repro.core.placement import PlacementPlan, packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MultiScheduler, Request, Scheduler,
                           ServingEngine, validate)

CFG = ModelConfig(name="tinykv", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)
CFG_B = ModelConfig(name="tinykvB", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
                    head_dim=12, remat=False)


@pytest.fixture(scope="module")
def packed():
    return freeze_for_serving(tfm.init_params(CFG, jax.random.PRNGKey(0)),
                              bits=8)


# canonical traffic shared by the bit-exactness tests, so the resident-KV
# reference is served ONCE per module instead of once per test
CANON = [np.random.default_rng(7).integers(0, 256, 3 + 7 * u)
         .astype(np.int32) for u in range(4)]


@pytest.fixture(scope="module")
def ref_tokens(packed):
    toks, _s, _e = _serve(CFG, packed, CANON)
    return toks


@pytest.fixture(scope="module")
def packed_b():
    return freeze_for_serving(tfm.init_params(CFG_B, jax.random.PRNGKey(1)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


def _prompts(rng, n=4, base=3, step=7):
    return [rng.integers(0, 256, base + step * u).astype(np.int32)
            for u in range(n)]


def _serve(cfg, packed, prompts, *, plan=None, paged=False, kv=False,
           pool=None, async_io=True, kv_block=4, max_new=6, slots=2,
           max_len=64, prefill_chunk=8, name="m"):
    eng = ServingEngine(cfg, packed, batch_slots=slots, max_len=max_len,
                        plan=plan if plan is not None
                        else PlacementPlan.uniform())
    if paged:
        eng.attach_paging(pool=pool, name=name)
    if kv:
        eng.attach_kv_paging(kv_block, pool=pool, name=f"{name}/kv")
    s = Scheduler(eng, prefill_chunk=prefill_chunk, async_io=async_io)
    for uid, p in enumerate(prompts):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    done = s.run_until_done()
    return {r.uid: r.generated for r in done}, s, eng


def _close(eng):
    if eng.pager is not None:
        eng.pager.close()
    if eng.kv_table is not None:
        eng.kv_table.close()


def _fake_cache(rng, n_layers=2, slots=2, heads=2, max_len=16, hd=4):
    shape = (n_layers, slots, heads, max_len, hd)
    return dict(k=jnp.asarray(rng.normal(size=shape), jnp.float32),
                v=jnp.asarray(rng.normal(size=shape), jnp.float32))


# ---------------------------------------------------------------------------
# KVPageTable mechanics
# ---------------------------------------------------------------------------

def test_kv_page_table_geometry(rng):
    cache = _fake_cache(rng, n_layers=3, slots=2, heads=2, max_len=20, hd=4)
    t = KVPageTable(cache, block_rows=8)
    assert t.n_blocks == 3                     # ceil(20 / 8)
    assert len(t.pages) == 2 * 3
    # one row across all layers + k + v
    assert t.row_nbytes == 2 * 3 * 2 * 4 * 4   # kv * L * H * hd * f32
    assert t.page_nbytes == 8 * t.row_nbytes
    # one tick's traffic for a 17-row span: two full blocks, frontier held
    assert kv_stream_bytes(17, 8, t.row_nbytes) == 2 * 8 * t.row_nbytes
    t.close()


def test_kv_stream_bytes_closed_form():
    assert kv_stream_bytes(0, 4, 100) == 0
    assert kv_stream_bytes(3, 4, 100) == 0        # frontier only: no stream
    assert kv_stream_bytes(4, 4, 100) == 400
    assert kv_stream_bytes(11, 4, 100) == 800
    with pytest.raises(ValueError):
        kv_stream_bytes(4, 0, 100)
    with pytest.raises(ValueError):
        kv_stream_bytes(-1, 4, 100)


def test_kv_writeback_fetch_roundtrip(rng):
    """Rows written back at block completion come back bit-identical from
    a begin/fence pass — the host round trip is lossless."""
    cache = _fake_cache(rng, max_len=16)
    t = KVPageTable(cache, block_rows=4)
    t.writeback(0, 0, 3, cache)                # blocks 0..2 of slot 0
    ps = t.begin_pass({0: 3})
    blocks = ps.fence({0: 3})
    assert sorted(blocks) == [0, 1, 2]
    for blk in range(3):
        a, b = blk * 4, (blk + 1) * 4
        np.testing.assert_array_equal(
            np.asarray(blocks[blk]["k"]),
            np.asarray(cache["k"][:, 0, :, a:b]))
    assert t.swap_count == 3 and t.miss_count == 3
    assert t.writebacks == 3
    t.close()


def test_kv_pool_hit_skips_swap(rng):
    cache = _fake_cache(rng, max_len=16)
    pool = SharedPagePool(1 << 20)
    t = KVPageTable(cache, block_rows=4, pool=pool, name="m/kv")
    t.writeback(0, 0, 2, cache)
    t.begin_pass({0: 2}).fence({0: 2})
    assert t.swap_count == 2 and t.pool_hits == 0
    t.begin_pass({0: 2}).fence({0: 2})         # second pass: all pooled
    assert t.swap_count == 2 and t.pool_hits == 2
    assert pool.counters["m/kv"]["pool_hits"] == 2
    pool.close()


def test_kv_fence_idempotent_and_close(rng):
    t = KVPageTable(_fake_cache(rng), block_rows=4)
    t.writeback(0, 0, 2, _fake_cache(rng))
    ps = t.begin_pass({0: 2})
    first = ps.fence({0: 2})
    assert ps.fence({0: 2}) is first           # no re-wait, no re-count
    swaps = t.swap_count
    ps.close()                                 # no-op on a fenced pass
    assert t.swap_count == swaps
    ps2 = t.begin_pass({0: 2})
    ps2.close()
    with pytest.raises(RuntimeError, match="close"):
        ps2.fence({0: 2})
    t.close()


def test_kv_early_close_releases_pool_guard(rng):
    pool = SharedPagePool(1 << 20)
    t = KVPageTable(_fake_cache(rng), block_rows=4, pool=pool, name="m/kv")
    t.writeback(0, 0, 2, _fake_cache(rng))
    ps = t.begin_pass({0: 2})
    ps.close()
    assert not pool._active_fetch              # guard released, not leaked
    # table stays usable after the cancel
    blocks = t.begin_pass({0: 2}).fence({0: 2})
    assert sorted(blocks) == [0, 1]
    pool.close()


def test_kv_drop_invalidates_and_zeroes(rng):
    """flush_drops removes the slot's pooled pages (counted as dropped,
    NOT as pressure evictions) and zeroes its host rows, so a later fetch
    swaps fresh data instead of serving a stale tenant's."""
    cache = _fake_cache(rng, max_len=16)
    pool = SharedPagePool(1 << 20)
    t = KVPageTable(cache, block_rows=4, pool=pool, name="m/kv")
    t.writeback(0, 0, 2, cache)
    t.begin_pass({0: 2}).fence({0: 2})
    assert pool.lookup("m/kv", 0) is not None
    t.queue_drop(0)
    t.flush_drops()
    assert t.dropped == 2
    assert pool.counters["m/kv"]["evicted"] == 0
    assert pool.lookup("m/kv", 0) is None
    assert not t.host["k"][:, 0].any()         # stale rows zeroed
    swaps = t.swap_count
    t.begin_pass({0: 1}).fence({0: 1})         # re-fetch must swap again
    assert t.swap_count == swaps + 1
    # the drop rides the event log, so the replay stays exact
    pred = kv_pass_counters({}, pool.budget_bytes, pool.events)
    assert pred["m/kv"]["dropped"] == 2
    assert pred["m/kv"]["swaps"] == t.swap_count
    pool.close()


def test_kv_fetch_bytes_follow_memsys_closed_form(rng):
    """Total bytes a pass moves equal the memsys closed form over its
    spans — completed blocks stream, the frontier stays device-side."""
    cache = _fake_cache(rng, slots=2, max_len=16)
    t = KVPageTable(cache, block_rows=4)
    t.writeback(0, 0, 3, cache)
    t.writeback(1, 0, 1, cache)
    spans = {0: 13, 1: 6}                      # valid rows per slot
    full = {s: v // 4 for s, v in spans.items()}
    t.begin_pass(full).fence(full)
    want = sum(kv_stream_bytes(v, 4, t.row_nbytes) for v in spans.values())
    assert t.swap_count * t.page_nbytes == want
    t.close()


# ---------------------------------------------------------------------------
# bit-exactness: paged KV vs the resident-KV engine
# ---------------------------------------------------------------------------

def test_kv_paged_decode_bit_exact_dense(packed, ref_tokens):
    got, _, eng = _serve(CFG, packed, CANON, kv=True)
    assert got == ref_tokens
    assert eng.kv_table.swap_count > 0 and eng.kv_table.writebacks > 0
    _close(eng)


@pytest.mark.slow
def test_kv_paged_decode_bit_exact_vlm(rng):
    from repro.configs import get_config

    cfg = get_config("llava-next-34b").smoke()
    packed = freeze_for_serving(tfm.init_params(cfg, jax.random.PRNGKey(2)),
                                bits=8)
    prompts = [rng.integers(0, cfg.vocab_size, 4 + 5 * u).astype(np.int32)
               for u in range(3)]
    ref, _, _ = _serve(cfg, packed, prompts)
    got, _, eng = _serve(cfg, packed, prompts, kv=True)
    assert got == ref
    assert eng.kv_table.swap_count > 0
    _close(eng)


@pytest.mark.slow
@pytest.mark.parametrize("budget_kind", ["roomy", "tight"])
def test_kv_paged_bit_exact_with_shared_pool(packed, ref_tokens,
                                             budget_kind):
    """Weights AND KV blocks contend for ONE pool budget; tokens must
    stay bit-exact whether the pool is roomy (blocks pool-hit) or tight
    (cross-eviction churn)."""
    plan = _half_paged_plan(packed)
    sizes = packed_sizes(packed)
    cold = plan.paged_bytes(sizes)
    budget = (1 << 30) if budget_kind == "roomy" else max(cold // 2, 1)
    pool = SharedPagePool(budget)
    got, _s, eng = _serve(CFG, packed, CANON, plan=plan, paged=True,
                          kv=True, pool=pool)
    assert got == ref_tokens
    summ = pool.summary()
    assert set(summ["models"]) == {"m", "m/kv"}
    if budget_kind == "roomy":
        assert summ["evictions"] == 0
        assert eng.kv_table.pool_hits > 0      # immutable blocks re-used
    else:
        assert summ["evictions"] > 0           # the budget genuinely binds
    pool.close()


@pytest.mark.slow
def test_kv_paged_sync_mode_bit_exact_zero_hidden(packed, ref_tokens):
    got, s, eng = _serve(CFG, packed, CANON, kv=True, async_io=False)
    assert got == ref_tokens
    assert eng.kv_hidden_s == 0.0
    ps = eng.paging_summary()
    assert ps["kv_hidden_s"] == 0.0 and ps["kv_exposed_s"] > 0.0
    _close(eng)


def test_kv_truncated_request_bit_exact(rng, packed):
    """Cache exhaustion under KV paging: the request truncates at the
    same token with the same flag as on the resident engine."""
    prompts = [rng.integers(0, 256, 8).astype(np.int32)]
    ref, _, _ = _serve(CFG, packed, prompts, max_len=16, max_new=32,
                       slots=1)
    got, s, eng = _serve(CFG, packed, prompts, kv=True, max_len=16,
                         max_new=32, slots=1, kv_block=4)
    assert got == ref
    req = s.finished[0]
    assert req.truncated
    _close(eng)


@pytest.mark.slow
def test_kv_slot_reuse_no_stale_pool_pages(rng, packed):
    """Sequential tenants of ONE batch slot: the retired request's pooled
    blocks must be dropped before the slot's new tenant can pool-hit them
    (the stale-page regression the deferred flush exists for)."""
    prompts = _prompts(rng, n=3, base=4, step=6)
    pool = SharedPagePool(1 << 30)
    got, _s, eng = _serve(CFG, packed, prompts, kv=True, pool=pool,
                          slots=1)
    ref, _, _ = _serve(CFG, packed, prompts, slots=1)
    assert got == ref
    assert eng.kv_table.dropped > 0            # reuse actually dropped
    pool.close()


# ---------------------------------------------------------------------------
# counters vs the static kv_pass_counters prediction
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kv_counters_private_table_prediction(packed, ref_tokens):
    """Pool-less KV paging: every listed block swaps (no cache), the
    event-log replay predicts swaps exactly, and the WEIGHTS keep their
    ticks x pass_counters equality — KV paging must not add or drop a
    single weight pass."""
    plan = _half_paged_plan(packed)
    got, s, eng = _serve(CFG, packed, CANON, plan=plan, paged=True,
                         kv=True)
    assert got == ref_tokens
    pred = kv_pass_counters({}, None, eng.kv_table.events)
    assert pred["m/kv"]["swaps"] == eng.kv_table.swap_count
    assert pred["m/kv"]["pool_hits"] == 0 == eng.kv_table.pool_hits
    total_blocks = sum(len(ev[2]) for ev in eng.kv_table.events
                       if ev[0] == "kv")
    assert eng.kv_table.swap_count == total_blocks
    per_pass = pass_counters(len(eng.pager.pages), eng.page_resident_slots)
    assert eng.swap_count == s.ticks * per_pass["swaps"]
    assert eng.miss_count == s.ticks * per_pass["misses"]
    _close(eng)


@pytest.mark.parametrize("budget_kind", ["roomy", "tight"])
def test_kv_counters_pooled_prediction(rng, packed, budget_kind):
    """Shared pool, weights + KV: every member's runtime counters equal
    the kv_pass_counters replay of the pool's event log."""
    plan = _half_paged_plan(packed)
    prompts = _prompts(rng)
    cold = plan.paged_bytes(packed_sizes(packed))
    budget = (1 << 30) if budget_kind == "roomy" else max(cold // 2, 1)
    pool = SharedPagePool(budget)
    _got, _s, eng = _serve(CFG, packed, prompts, plan=plan, paged=True,
                           kv=True, pool=pool)
    summ = pool.summary()
    pred = kv_pass_counters({"m": page_sizes(eng.pager.pages)},
                            pool.budget_bytes, pool.events)
    for m in ("m", "m/kv"):
        got = {k: summ["models"][m][k]
               for k in ("swaps", "misses", "pool_hits", "evicted")}
        want = {k: pred[m][k]
                for k in ("swaps", "misses", "pool_hits", "evicted")}
        assert got == want, (m, got, want)
        # the unified replay predicts the streamed-bytes ledger of both
        # member kinds exactly — weights in wire bytes, KV at ratio 1.0
        assert summ["models"][m]["bytes_streamed_wire"] == pred[m]["bytes_wire"]
        assert summ["models"][m]["bytes_streamed_raw"] == pred[m]["bytes_raw"]
    pool.close()


def test_kv_pass_counters_weights_only_agrees_with_shared(rng, packed):
    """On a weights-only event stream the unified replay reduces to
    shared_pass_counters member for member."""
    plan = _half_paged_plan(packed)
    prompts = _prompts(rng, n=3)
    pool = SharedPagePool(1 << 30)
    _got, _s, eng = _serve(CFG, packed, prompts, plan=plan, paged=True,
                           pool=pool)
    sizes = {"m": [p.nbytes for p in eng.pager.pages]}
    uni = kv_pass_counters(sizes, pool.budget_bytes, pool.events)
    old = shared_pass_counters(sizes, pool.budget_bytes,
                               passes=pool.pass_log)
    for k in ("swaps", "misses", "pool_hits", "evicted"):
        assert uni["m"][k] == old["m"][k]
    pool.close()


@pytest.mark.slow
def test_weight_and_kv_cross_eviction_one_domain(rng, packed):
    """One eviction domain: under pressure, weight admissions evict KV
    blocks and KV admissions evict weight pages — and the replay still
    predicts both sides exactly."""
    plan = _half_paged_plan(packed)
    prompts = _prompts(rng, n=4, base=6, step=8)
    cold = plan.paged_bytes(packed_sizes(packed))
    pool = SharedPagePool(max(cold // 2, 1))
    _got, _s, eng = _serve(CFG, packed, prompts, plan=plan, paged=True,
                           kv=True, pool=pool, max_new=10)
    summ = pool.summary()
    assert summ["models"]["m"]["evicted"] > 0
    assert summ["models"]["m/kv"]["evicted"] > 0
    pred = kv_pass_counters({"m": [p.nbytes for p in eng.pager.pages]},
                            pool.budget_bytes, pool.events)
    for m in ("m", "m/kv"):
        assert summ["models"][m]["evicted"] == pred[m]["evicted"]
    pool.close()


# ---------------------------------------------------------------------------
# two-tenant KV paging through one pool
# ---------------------------------------------------------------------------

def _serve_tenants(packed_a, packed_b, prompts, budget, *, async_io=True):
    eng_a = ServingEngine(CFG, packed_a, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_a), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(budget), async_io=async_io)
    ms.add_model("a", eng_a, prefill_chunk=8, kv_paged=True,
                 kv_block_rows=4)
    ms.add_model("b", eng_b, prefill_chunk=8, kv_paged=True,
                 kv_block_rows=4)
    for uid, p in enumerate(prompts):
        ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=4))
        ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=4))
    done = ms.run_until_done()
    return ms, done


@pytest.mark.slow
@pytest.mark.parametrize("budget_kind", ["roomy", "tight"])
def test_kv_two_tenant_bit_exact_and_predicted(rng, packed, packed_b,
                                               budget_kind):
    """Two tenants' weights AND KV caches through one pool: tokens
    bit-exact vs each model served alone fully resident, every member's
    counters on the unified replay."""
    prompts = _prompts(rng, n=3, base=3, step=4)
    if budget_kind == "roomy":
        budget = 1 << 30
    else:
        budget = max((_half_paged_plan(packed).paged_bytes(
            packed_sizes(packed))
            + _half_paged_plan(packed_b).paged_bytes(
                packed_sizes(packed_b))) // 2, 1)
    ms, done = _serve_tenants(packed, packed_b, prompts, budget)
    ref_a, _, _ = _serve(CFG, packed, prompts, max_new=4)
    ref_b, _, _ = _serve(CFG_B, packed_b, prompts, max_new=4)
    assert {r.uid: r.generated for r in done["a"]} == ref_a
    assert {r.uid: r.generated for r in done["b"]} == ref_b
    summ = ms.pool.summary()
    assert set(summ["models"]) == {"a", "a/kv", "b", "b/kv"}
    pred = kv_pass_counters(
        {m: [p.nbytes for p in ms.model(m).engine.pager.pages]
         for m in ("a", "b")}, budget, ms.pool.events)
    for m in pred:
        got = {k: summ["models"][m][k]
               for k in ("swaps", "misses", "pool_hits", "evicted")}
        want = {k: pred[m][k]
                for k in ("swaps", "misses", "pool_hits", "evicted")}
        assert got == want, (m, got, want)
    doc = validate(ms.summary())
    assert doc["models"]["a"]["paging"]["kv_swaps"] > 0
    ms.close()


def test_multischeduler_close_cancels_kv_passes(rng, packed, packed_b):
    prompts = _prompts(rng, n=3, base=6, step=4)
    ms, _ = None, None
    eng_a = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(1 << 30), async_io=True)
    ms.add_model("a", eng_a, prefill_chunk=8, kv_paged=True)
    ms.add_model("b", eng_b, prefill_chunk=8, kv_paged=True)
    for uid, p in enumerate(prompts):
        ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=8))
        ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=8))
    ms.tick()
    ms.tick()
    assert (eng_a._inflight_kv is not None
            or eng_b._inflight_kv is not None)
    ms.close()
    assert eng_a._inflight_kv is None and eng_b._inflight_kv is None
    assert not ms.pool._active_fetch


# ---------------------------------------------------------------------------
# async overlap with KV pages in flight
# ---------------------------------------------------------------------------

def test_kv_overlap_identity_per_tick(rng, packed):
    """Per tick, the KV stream's measured (swap_s, window_s, exposed_s,
    hidden_s) satisfy memsys.overlap_stall's closed form — the same
    identity the weight pass obeys, now with KV pages in flight."""
    plan = _half_paged_plan(packed)
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64, plan=plan)
    eng.attach_paging()
    eng.attach_kv_paging(4)
    s = Scheduler(eng, prefill_chunk=8, async_io=True)
    for uid in range(3):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256, 8).astype(np.int32),
                         max_new_tokens=6))
    checked = 0
    while s.pending:
        s.tick()
        for ov in (eng.last_overlap, eng.last_kv_overlap):
            assert ov is not None
            pred = overlap_stall(ov["swap_s"], ov["window_s"])
            assert ov["exposed_s"] == pytest.approx(pred["exposed_s"],
                                                    abs=5e-3)
            assert ov["hidden_s"] == pytest.approx(pred["hidden_s"],
                                                   abs=5e-3)
        checked += 1
    assert checked == s.ticks > 1
    # tick metrics fold BOTH streams into the exposed/hidden totals
    assert eng.paging_stall_s == pytest.approx(
        sum(s.metrics.tick_exposed_s))
    assert eng.paging_hidden_s == pytest.approx(
        sum(s.metrics.tick_hidden_s))
    # the engine-level split books the kv share separately
    assert eng.kv_stall_s <= eng.paging_stall_s + 1e-9
    _close(eng)


@pytest.mark.slow
def test_kv_async_overlap_hides_stream_time(rng, packed):
    """overlap_frac > 0 with KV pages pooled — the CI acceptance gate."""
    plan = _half_paged_plan(packed)
    pool = SharedPagePool(1 << 30)
    prompts = _prompts(rng, n=4, base=8, step=6)
    _got, _s, eng = _serve(CFG, packed, prompts, plan=plan, paged=True,
                           kv=True, pool=pool, max_new=10)
    ps = eng.paging_summary()
    assert ps["overlap_frac"] > 0.0
    assert ps["hidden_s"] > 0.0
    assert ps["kv_swaps"] > 0
    pool.close()


def test_scheduler_close_cancels_inflight_kv(rng, packed):
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        plan=PlacementPlan.uniform())
    eng.attach_kv_paging(4)
    s = Scheduler(eng, prefill_chunk=8, async_io=True)
    for uid in range(3):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256, 6).astype(np.int32),
                         max_new_tokens=8))
    s.tick()
    s.tick()
    assert eng._inflight_kv is not None
    s.close()
    assert eng._inflight_kv is None
    rest = s.run_until_done()                  # still serviceable
    assert {r.uid for r in rest} == {0, 1, 2}
    _close(eng)


# ---------------------------------------------------------------------------
# prefill jit cache: (bucket, kv_span) O(log^2) bound
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefill_jit_cache_kv_span_log2_squared(rng, packed):
    """Chunked prefill over varied prompt lengths and cache offsets keys
    the jit cache by (bucket, kv_span): program count stays within
    log2(max_len)^2 and every span is a power of two."""
    max_len = 128
    eng = ServingEngine(CFG, packed, batch_slots=4, max_len=max_len)
    s = Scheduler(eng, prefill_chunk=16)
    lengths = rng.integers(1, 100, 12)
    for uid, n in enumerate(lengths):
        s.submit(Request(uid=uid,
                         prompt=rng.integers(0, 256,
                                             int(n)).astype(np.int32),
                         max_new_tokens=2))
    done = s.run_until_done()
    assert len(done) == len(lengths)
    keys = list(eng._prefill_cache)
    assert len(keys) <= math.log2(max_len) ** 2
    spans = {span for _b, _pfx, span in keys}
    assert len(spans) > 1                      # slicing genuinely varied
    for bucket, _pfx, span in keys:
        assert bucket & (bucket - 1) == 0
        assert span & (span - 1) == 0
        assert bucket <= span <= max_len


@pytest.mark.slow
def test_kv_span_slicing_matches_offline_forward(rng, packed):
    """Span-sliced chunked prefill equals offline full-prompt generation
    token for token (masked keys beyond the span are exact no-ops)."""
    prompts = [rng.integers(0, 256, n).astype(np.int32)
               for n in (9, 26)]
    got, _, eng = _serve(CFG, packed, prompts, max_new=2,
                         prefill_chunk=8)
    for uid, p in enumerate(prompts):
        toks = jnp.asarray(p)[None]
        for t in range(2):
            lg = tfm.forward(packed, toks, CFG,
                             engine=PlacementPlan.uniform())
            nt = jnp.argmax(lg[:, -1], -1)
            assert got[uid][t] == int(nt[0]), (uid, t)
            toks = jnp.concatenate([toks, nt[:, None]], 1)


# ---------------------------------------------------------------------------
# attach validation + metrics v4
# ---------------------------------------------------------------------------

def test_attach_kv_paging_validation(rng, packed):
    from repro.configs import get_config

    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    with pytest.raises(ValueError, match="block_rows"):
        KVPageTable(eng.cache["kv"], block_rows=0)
    eng.attach_kv_paging(4)
    with pytest.raises(ValueError, match="already"):
        eng.attach_kv_paging(4)
    _close(eng)
    # mid-serving attach rejected: the host image must snapshot idle state
    eng2 = ServingEngine(CFG, packed, batch_slots=2, max_len=64)
    eng2.submit(Request(uid=0, prompt=rng.integers(0, 256, 4)
                        .astype(np.int32)))
    with pytest.raises(ValueError, match="before submitting"):
        eng2.attach_kv_paging(4)
    # SSM recurrent state is not a KV cache
    cfg = get_config("falcon-mamba-7b").smoke()
    ssm_packed = freeze_for_serving(
        tfm.init_params(cfg, jax.random.PRNGKey(3)), bits=8)
    ssm_eng = ServingEngine(cfg, ssm_packed, batch_slots=1, max_len=64)
    with pytest.raises(ValueError, match="no KV cache"):
        ssm_eng.attach_kv_paging(4)


def test_metrics_v4_kv_fields_round_trip(rng, packed):
    import json

    prompts = _prompts(rng, n=2)
    _got, s, eng = _serve(CFG, packed, prompts, kv=True)
    doc = validate(s.metrics.summary(paging=eng.paging_summary()))
    pg = doc["paging"]
    assert pg["kv_swaps"] == eng.kv_table.swap_count > 0
    assert pg["kv_writebacks"] == eng.kv_table.writebacks > 0
    assert pg["kv_block_rows"] == 4
    validate(json.loads(json.dumps(doc)))      # survives a JSON round trip
    # a recorder without paging info emits the same shape with zeroed
    # kv_* fields (what a resident run reports)
    from repro.serving import MetricsRecorder
    doc2 = validate(MetricsRecorder(clock=lambda: 0.0).summary())
    assert doc2["paging"]["kv_swaps"] == 0
    _close(eng)

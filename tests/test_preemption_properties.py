"""Property tests over the continuous-batching policy algebra
(hypothesis; skipped, not failed, when the optional [test] extra is
absent — the seeded twins in tests/test_continuous_batching.py always
run).

Pure policy level — a slot-state stub stands in for the engine, so
thousands of random schedules cost no jit.  Properties:

  * the budgeted tick plan never over-allocates (bucketed families),
    never starves decode, respects the chunk cap, and deals prefill
    budget in admission-key order;
  * preemptive admission converges (the handover chain terminates) to a
    state where no waiting candidate has STRICTLY higher priority than
    any occupant, preserving every request exactly once across
    {queue, preempted, slots};
  * random preemption points never corrupt the bookkeeping:
    preempt/restore events balance and no request is lost or duplicated.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving import Request, Scheduler
from repro.serving.engine import SlotCheckpoint


class _SlotStub:
    """Policy-only engine: slot occupancy plus assign/preempt/restore
    bookkeeping, no compute."""

    def __init__(self, n_slots, bucketed=True):
        self.slot_req = [None] * n_slots
        self._bucketed = bucketed
        self.waiting = []
        self.preempt_count = 0
        self.restore_count = 0

    def _check_fits(self, req):
        pass

    def free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def assign(self, req, slot):
        assert self.slot_req[slot] is None
        self.slot_req[slot] = req

    def preempt(self, slot):
        req = self.slot_req[slot]
        assert req is not None
        self.slot_req[slot] = None
        self.preempt_count += 1
        req.preemptions += 1
        return SlotCheckpoint(req=req, slot_pos=0, valid=0)

    def restore(self, ckpt, slot):
        assert self.slot_req[slot] is None
        self.slot_req[slot] = ckpt.req
        self.restore_count += 1


def _req(uid, n_prompt, *, pos=0, prio=0, seq=None, max_new=4):
    r = Request(uid=uid, prompt=np.arange(max(n_prompt, 1), dtype=np.int32),
                max_new_tokens=max_new)
    r.prefill_pos = min(pos, n_prompt)
    r.priority = prio
    r.arrival_s = 0.0
    r.seq = seq if seq is not None else uid
    return r


slot_states = st.lists(
    st.one_of(st.none(),
              st.tuples(st.integers(1, 64),          # prompt length
                        st.integers(0, 64),          # prefill_pos
                        st.integers(0, 3))),         # priority
    min_size=1, max_size=6)


@given(slots=slot_states,
       budget=st.integers(1, 64),
       chunk=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=200, deadline=None)
def test_budget_plan_invariants(slots, budget, chunk):
    eng = _SlotStub(len(slots))
    for i, spec in enumerate(slots):
        if spec is not None:
            n, pos, prio = spec
            eng.slot_req[i] = _req(i, n, pos=pos, prio=prio)
    s = Scheduler(eng, prefill_chunk=chunk, token_budget=budget,
                  clock=lambda: 0.0)
    plan = s._plan_tick()
    occ = [(i, r) for i, r in enumerate(eng.slot_req) if r is not None]
    decoding = [i for i, r in occ if r.prefill_pos >= len(r.prompt)]
    prefilling = [(i, r) for i, r in occ if r.prefill_pos < len(r.prompt)]
    # decode is always funded, never planned (plan covers prefill only)
    assert all(i not in plan for i in decoding)
    # every alloc targets a mid-prefill slot, within chunk and remainder
    for i, alloc in plan.items():
        r = eng.slot_req[i]
        assert r is not None and r.prefill_pos < len(r.prompt)
        assert 1 <= alloc <= s.prefill_chunk
        assert alloc <= len(r.prompt) - r.prefill_pos
    # bucketed plans never overspend the budget (decode is funded even
    # when decode-ready slots alone exceed it — starving decode would
    # stall every live stream)
    assert s._tick_budget_used <= max(budget, len(decoding))
    if len(decoding) >= budget:
        assert plan == {}                   # nothing left for prefill
    assert s._tick_budget_used == len(decoding) + sum(plan.values())
    # budget is dealt in admission-key order: once a slot got less than
    # its full ask, every worse-ranked slot got nothing
    order = sorted(prefilling, key=lambda t: s._admission_key(t[1]))
    starved = False
    for i, r in order:
        ask = min(s.prefill_chunk, len(r.prompt) - r.prefill_pos)
        got = plan.get(i, 0)
        if starved:
            assert got == 0
        elif got < ask:
            starved = True


@given(prios=st.lists(st.integers(0, 3), min_size=1, max_size=12),
       n_slots=st.integers(1, 4),
       preseed=st.lists(st.integers(0, 3), min_size=0, max_size=4))
@settings(max_examples=200, deadline=None)
def test_preemptive_admission_converges_and_conserves(prios, n_slots,
                                                      preseed):
    """After _admit: no waiting candidate strictly outranks (by priority
    class) any occupant, every request survives exactly once, and the
    preempt/restore ledger balances."""
    eng = _SlotStub(n_slots)
    s = Scheduler(eng, preemptive=True, clock=lambda: 0.0)
    uid = 0
    for prio in preseed[:n_slots]:          # some slots already occupied
        eng.slot_req[uid % n_slots] = _req(uid, 4, pos=1, prio=prio)
        uid += 1
    all_uids = {r.uid for r in eng.slot_req if r is not None}
    for prio in prios:
        r = _req(uid, 4, prio=prio)
        s.submit(r)
        all_uids.add(uid)
        uid += 1
    s._admit()
    occupants = [r for r in eng.slot_req if r is not None]
    waiting = [r for r in s.queue] + [c.req for c in s.preempted]
    if occupants and waiting:
        assert (max((r.priority or 0) for r in waiting)
                <= min((r.priority or 0) for r in occupants))
    # conservation: every request exactly once across the three places
    seen = [r.uid for r in occupants] + [r.uid for r in waiting]
    assert sorted(seen) == sorted(all_uids)
    assert eng.preempt_count == len(s.preempted) + eng.restore_count
    # slots are full whenever anyone is waiting
    if waiting:
        assert not eng.free_slots()

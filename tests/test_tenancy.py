"""Multi-model tenancy (serving/tenancy + core/paging.SharedPagePool)
plus the scheduler/paging bugfix sweep that rode along in the same PR:
single-slot schedules, per-call run loops, truncated-request accounting,
and non-positive prefill pacing.
"""

import jax
import numpy as np
import pytest

from repro.core.paging import SharedPagePool, page_sizes, pass_counters, \
    shared_pass_counters
from repro.core.placement import PlacementPlan, packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MultiScheduler, Request, Scheduler,
                           ServingEngine, validate)

CFG_A = ModelConfig(name="tinyA", family="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                    head_dim=16, remat=False)
CFG_B = ModelConfig(name="tinyB", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
                    head_dim=12, remat=False)


@pytest.fixture(scope="module")
def packed_a():
    return freeze_for_serving(tfm.init_params(CFG_A, jax.random.PRNGKey(0)),
                              bits=8)


@pytest.fixture(scope="module")
def packed_b():
    return freeze_for_serving(tfm.init_params(CFG_B, jax.random.PRNGKey(1)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


def _prompts(rng, n=4):
    return [rng.integers(0, 256, 3 + 4 * i).astype(np.int32)
            for i in range(n)]


def _serve_solo(cfg, packed, prompts, *, seed=0, max_new=5):
    eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64,
                        plan=_half_paged_plan(packed), seed=seed)
    eng.attach_paging()
    s = Scheduler(eng, prefill_chunk=8)
    for uid, p in enumerate(prompts):
        s.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    done = s.run_until_done()
    out = {r.uid: r.generated for r in done}
    eng.pager.close()
    return out


def _serve_tenants(packed_a, packed_b, prompts, budget_bytes, *, max_new=5):
    eng_a = ServingEngine(CFG_A, packed_a, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_a), seed=0)
    eng_b = ServingEngine(CFG_B, packed_b, batch_slots=2, max_len=64,
                          plan=_half_paged_plan(packed_b), seed=1)
    ms = MultiScheduler(pool=SharedPagePool(budget_bytes))
    ms.add_model("a", eng_a, prefill_chunk=8)
    ms.add_model("b", eng_b, prefill_chunk=8)
    for uid, p in enumerate(prompts):
        ms.submit("a", Request(uid=uid, prompt=p, max_new_tokens=max_new))
        ms.submit("b", Request(uid=uid, prompt=p, max_new_tokens=max_new))
    done = ms.run_until_done()
    return ms, done


def _paged_bytes(packed):
    sizes = packed_sizes(packed)
    plan = _half_paged_plan(packed)
    return sum(v for k, v in sizes.items() if plan.placement_for(k).paged)


# ---------------------------------------------------------------------------
# tentpole: MultiScheduler over a SharedPagePool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", ["roomy", "tight"])
@pytest.mark.slow
def test_tenants_bit_exact_vs_solo_and_counters(rng, packed_a, packed_b,
                                                budget):
    """Two ServingEngines under one MultiScheduler and one SharedPagePool
    budget produce tokens bit-exact vs each model served alone on a
    private pager, and the per-model pool counters match the static
    shared_pass_counters prediction — under both a roomy budget (pool
    hits after the first tick) and a tight one (cross-model eviction
    churn)."""
    prompts = _prompts(rng)
    solo_a = _serve_solo(CFG_A, packed_a, prompts, seed=0)
    solo_b = _serve_solo(CFG_B, packed_b, prompts, seed=1)

    cold = _paged_bytes(packed_a) + _paged_bytes(packed_b)
    budget_bytes = (1 << 30) if budget == "roomy" else int(cold * 0.6)
    ms, done = _serve_tenants(packed_a, packed_b, prompts, budget_bytes)

    assert {r.uid: r.generated for r in done["a"]} == solo_a
    assert {r.uid: r.generated for r in done["b"]} == solo_b

    pred = shared_pass_counters(
        {"a": page_sizes(ms.model("a").engine.pager.pages),
         "b": page_sizes(ms.model("b").engine.pager.pages)},
        budget_bytes, resident_slots=2, passes=ms.pass_log)
    summ = ms.pool.summary()
    for m in ("a", "b"):
        got = {k: summ["models"][m][k]
               for k in ("swaps", "misses", "pool_hits", "evicted")}
        assert got == {k: pred[m][k] for k in got}, (m, got, pred[m])
        # the streamed-bytes ledger follows the same replay, exactly
        assert summ["models"][m]["bytes_streamed_wire"] == pred[m]["bytes_wire"]
        assert summ["models"][m]["bytes_streamed_raw"] == pred[m]["bytes_raw"]
    if budget == "tight":
        assert summ["evictions"] > 0        # contention actually happened
        assert summ["live_bytes"] <= budget_bytes
    else:
        assert summ["evictions"] == 0
        # after tick 1 every pass rides the pool: swaps stop at one fetch
        # per page per model
        assert summ["models"]["a"]["swaps"] == len(
            ms.model("a").engine.pager.pages)
    ms.close()


def test_pool_rejects_private_pager_and_duplicates(packed_a):
    eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64,
                        plan=_half_paged_plan(packed_a))
    eng.attach_paging()                     # private pager
    ms = MultiScheduler(shared_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="private pager"):
        ms.add_model("a", eng)
    eng.pager.close()
    eng2 = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64,
                         plan=_half_paged_plan(packed_a))
    ms.add_model("a", eng2)
    with pytest.raises(ValueError, match="already registered"):
        ms.add_model("a", eng2)
    ms.close()


def test_fully_resident_tenant_skips_paging(packed_a, rng):
    """A tenant whose plan pages nothing serves resident — no pager, no
    pool membership — alongside a paged co-tenant."""
    eng_res = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64,
                            plan=PlacementPlan.uniform())
    eng_paged = ServingEngine(CFG_B,
                              freeze_for_serving(
                                  tfm.init_params(CFG_B,
                                                  jax.random.PRNGKey(1)),
                                  bits=8),
                              batch_slots=1, max_len=64, seed=1,
                              plan=_half_paged_plan(freeze_for_serving(
                                  tfm.init_params(CFG_B,
                                                  jax.random.PRNGKey(1)),
                                  bits=8)))
    ms = MultiScheduler(shared_budget_bytes=1 << 20)
    ms.add_model("res", eng_res)
    ms.add_model("paged", eng_paged)
    assert eng_res.pager is None and eng_paged.pager is not None
    p = rng.integers(0, 256, 5).astype(np.int32)
    ms.submit("res", Request(uid=0, prompt=p, max_new_tokens=2))
    ms.submit("paged", Request(uid=0, prompt=p, max_new_tokens=2))
    done = ms.run_until_done()
    assert len(done["res"]) == 1 and len(done["paged"]) == 1
    assert ms.pass_log and all(m == "paged" for m in ms.pass_log)
    ms.close()


def test_global_edf_admission_order(packed_a, packed_b):
    """One admission loop across tenants: priority class first, EDF
    within a class, regardless of which model a request belongs to."""
    clock = [0.0]
    ms = MultiScheduler(clock=lambda: clock[0])
    ms.add_model("a", ServingEngine(CFG_A, packed_a, batch_slots=1,
                                    max_len=64))
    ms.add_model("b", ServingEngine(CFG_B, packed_b, batch_slots=1,
                                    max_len=64))
    ms.add_stream("a", "assistant", priority=0)
    ms.add_stream("b", "tracker", priority=2, deadline_ms=50.0)
    p = np.arange(4, dtype=np.int32)
    ms.submit("a", Request(uid=0, prompt=p), stream="assistant")
    ms.submit("b", Request(uid=1, prompt=p), stream="tracker")
    ms.submit("b", Request(uid=2, prompt=p, deadline_ms=5.0, priority=2),
              stream="tracker")
    ms.submit("a", Request(uid=3, prompt=p, priority=1), stream="assistant")
    order = [(m, r.uid) for m, r in ms.admission_order()]
    assert order == [("b", 2), ("b", 1), ("a", 3), ("a", 0)]
    ms.close()


def test_global_admission_survives_duplicate_uids(rng, packed_a):
    """uid uniqueness is never enforced; global admission must remove the
    admitted request by IDENTITY (Request's dataclass __eq__ compares the
    ndarray prompt, so list.remove would raise on a uid tie)."""
    ms = MultiScheduler()
    ms.add_model("a", ServingEngine(CFG_A, packed_a, batch_slots=1,
                                    max_len=64))
    for p in (rng.integers(0, 256, 3).astype(np.int32),
              rng.integers(0, 256, 3).astype(np.int32)):
        ms.submit("a", Request(uid=0, prompt=p, max_new_tokens=2))
    done = ms.run_until_done()
    assert len(done["a"]) == 2
    ms.close()


def test_pool_never_fit_page_does_not_flush_cotenants():
    """A page larger than the whole budget can never be cached — admitting
    it must not evict co-tenants' pool entries for zero benefit."""
    pred = shared_pass_counters({"small": [40, 40], "huge": [200]},
                                budget_bytes=100, ticks=2)
    # 'small' keeps its pool hits on tick 2; 'huge' never evicts anyone
    assert pred["small"] == dict(swaps=2, misses=2, pool_hits=2, evicted=0,
                                 bytes_wire=80, bytes_raw=80)
    assert pred["huge"] == dict(swaps=2, misses=2, pool_hits=0, evicted=0,
                                bytes_wire=400, bytes_raw=400)
    pool = SharedPagePool(100)

    class _Stub:
        pages = []
        swap_count = miss_count = 0
    pool.register("small", _Stub())
    pool.register("huge", _Stub())
    pool.admit("small", 0, 40, {})
    pool.admit("small", 1, 40, {})
    pool.admit("huge", 0, 200, {})          # never fits: no eviction
    assert pool.live_bytes == 80
    assert pool.lookup("small", 0) is not None
    assert pool.counters["small"]["evicted"] == 0


def test_multi_metrics_v2_document(rng, packed_a, packed_b):
    prompts = _prompts(rng, n=2)
    cold = _paged_bytes(packed_a) + _paged_bytes(packed_b)
    ms, done = _serve_tenants(packed_a, packed_b, prompts, int(cold * 0.6),
                              max_new=3)
    doc = validate(ms.summary())
    assert set(doc["models"]) == {"a", "b"}
    for m in ("a", "b"):
        assert doc["models"][m]["requests"]["count"] == len(prompts)
        assert doc["models"][m]["paging"]["swap_count"] > 0
        assert doc["shared_pool"]["models"][m]["n_pages"] >= 1
    assert doc["totals"]["requests"] == 2 * len(prompts)
    assert doc["totals"]["tokens_out"] == sum(
        len(r.generated) for rs in done.values() for r in rs)
    assert doc["ticks"]["count"] == ms.ticks
    import json
    json.loads(ms.to_json())
    ms.close()


@pytest.mark.slow
def test_single_slot_paged_serving_bit_exact(rng, packed_a):
    """attach_paging(resident_slots=1) streams a VALID schedule (the old
    make_schedule emitted evicts==page and validate_schedule rejected
    it): demand-fetch every page, tokens bit-exact vs the resident plan,
    counters == ticks x the single-slot pass prediction."""
    prompts = _prompts(rng, n=3)

    def serve(plan, paged):
        eng = ServingEngine(CFG_A, packed_a, batch_slots=2, max_len=64,
                            plan=plan)
        if paged:
            eng.attach_paging(resident_slots=1)
        s = Scheduler(eng, prefill_chunk=8)
        for uid, p in enumerate(prompts):
            s.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        done = s.run_until_done()
        return {r.uid: r.generated for r in done}, s, eng

    mixed, s, eng = serve(_half_paged_plan(packed_a), paged=True)
    resident, _, _ = serve(PlacementPlan.uniform(), paged=False)
    assert mixed == resident
    n_pages = len(eng.pager.pages)
    per_pass = pass_counters(n_pages, resident_slots=1)
    assert per_pass == dict(swaps=n_pages, misses=n_pages)
    assert eng.swap_count == s.ticks * per_pass["swaps"]
    assert eng.miss_count == s.ticks * per_pass["misses"]
    eng.pager.close()


# ---------------------------------------------------------------------------
# bugfix sweep: scheduler reuse, truncation, pacing validation
# ---------------------------------------------------------------------------

def test_scheduler_reuse_counts_ticks_per_call(rng, packed_a):
    """A reused scheduler must not trip "did not converge" because the
    cumulative self.ticks crossed max_ticks, and each run returns only
    the requests completed by THAT call."""
    eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64)
    s = Scheduler(eng)
    p = rng.integers(0, 256, 4).astype(np.int32)
    s.submit(Request(uid=0, prompt=p, max_new_tokens=8))
    first = s.run_until_done(max_ticks=10)
    assert [r.uid for r in first] == [0]
    assert s.ticks >= 7                       # cumulative > next call's cap
    s.submit(Request(uid=1, prompt=p, max_new_tokens=2))
    second = s.run_until_done(max_ticks=5)    # old code: spurious failure
    assert [r.uid for r in second] == [1]     # per-call, not all-time
    assert [r.uid for r in s.finished] == [0, 1]   # all-time list intact


def test_run_for_returns_per_call_completions(rng, packed_a):
    eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64)
    s = Scheduler(eng)
    p = rng.integers(0, 256, 3).astype(np.int32)
    s.submit(Request(uid=0, prompt=p, max_new_tokens=2))
    first = s.run_for(seconds=60.0)
    assert [r.uid for r in first] == [0]
    s.submit(Request(uid=1, prompt=p, max_new_tokens=2))
    second = s.run_for(seconds=60.0)
    assert [r.uid for r in second] == [1]


def test_engine_run_until_done_per_call(rng, packed_a):
    eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64)
    p = rng.integers(0, 256, 3).astype(np.int32)
    eng.submit(Request(uid=0, prompt=p, max_new_tokens=2))
    assert [r.uid for r in eng.run_until_done()] == [0]
    eng.submit(Request(uid=1, prompt=p, max_new_tokens=2))
    assert [r.uid for r in eng.run_until_done()] == [1]
    assert [r.uid for r in eng.finished] == [0, 1]


def test_cache_exhaustion_sets_truncated(rng, packed_a):
    """A request whose KV cache runs out before max_new_tokens is flagged
    truncated; a naturally completed one is not."""
    eng = ServingEngine(CFG_A, packed_a, batch_slots=2, max_len=16)
    cut = Request(uid=0, prompt=rng.integers(0, 256, 8).astype(np.int32),
                  max_new_tokens=1000)      # cannot fit: must truncate
    ok = Request(uid=1, prompt=rng.integers(0, 256, 4).astype(np.int32),
                 max_new_tokens=2)
    eng.submit(cut)
    eng.submit(ok)
    done = {r.uid: r for r in eng.run_until_done()}
    assert done[0].truncated and len(done[0].generated) < 1000
    assert not done[1].truncated and len(done[1].generated) == 2


def test_truncated_propagates_through_scheduler_metrics(rng, packed_a):
    eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=16)
    s = Scheduler(eng)
    s.add_stream("xr", priority=1, deadline_ms=1e6)
    s.submit(Request(uid=0, prompt=rng.integers(0, 256, 8).astype(np.int32),
                     max_new_tokens=1000), stream="xr")
    done = s.run_until_done()
    assert done[0].truncated
    doc = s.metrics.summary()
    assert doc["requests"]["truncated"] == 1
    # the (generous) deadline would have been met, but partial service is
    # excluded from the rate and labeled instead
    assert doc["deadlines"] == dict(with_deadline=0, missed=0,
                                    miss_rate=0.0, truncated=1)
    assert doc["streams"]["xr"]["truncated"] == 1


def test_nonpositive_prefill_chunk_rejected(packed_a):
    for bad in (0, -4):
        with pytest.raises(ValueError, match="prefill_chunk"):
            ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64,
                          prefill_chunk=bad)
        eng = ServingEngine(CFG_A, packed_a, batch_slots=1, max_len=64)
        with pytest.raises(ValueError, match="prefill_chunk"):
            Scheduler(eng, prefill_chunk=bad)
    # None still means "engine default pacing"
    assert Scheduler(ServingEngine(CFG_A, packed_a, batch_slots=1,
                                   max_len=64)).prefill_chunk is None


def test_shared_pass_counters_roomy_budget_closed_form():
    """With a budget that fits everything, the prediction reduces to the
    closed form: per model, first tick swaps == n_pages, later ticks ride
    the pool (pool_hits == n_pages per pass), misses == passes."""
    pages = {"a": [100, 100, 100], "b": [80, 80]}
    pred = shared_pass_counters(pages, budget_bytes=10_000, ticks=3)
    for m, n in (("a", 3), ("b", 2)):
        assert pred[m]["swaps"] == n
        assert pred[m]["misses"] == 3            # one demand miss per pass
        assert pred[m]["pool_hits"] == 2 * n     # ticks 2..3 fully pooled
        assert pred[m]["evicted"] == 0


def test_shared_pass_counters_starved_budget_closed_form():
    """A budget smaller than any single page can never cache: every fetch
    is a host->device swap, no pool hits, no evictions."""
    pages = {"a": [100, 100], "b": [100]}
    pred = shared_pass_counters(pages, budget_bytes=50, ticks=2)
    assert pred["a"] == dict(swaps=4, misses=2, pool_hits=0, evicted=0,
                             bytes_wire=400, bytes_raw=400)
    assert pred["b"] == dict(swaps=2, misses=2, pool_hits=0, evicted=0,
                             bytes_wire=200, bytes_raw=200)

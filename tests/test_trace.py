"""Chrome-trace span instrumentation (repro.serving.trace) and its
wiring through the serving tick pipeline.

Three layers:

* the Tracer primitive itself — event-format validity (every ``B`` has
  an ``E``, per-track timestamps monotonic, JSON round-trips through
  ``validate``), the shared no-op span, and the zero-allocation
  guarantee of the disabled fast path the hot tick takes on every
  untraced run;
* the instrumented pipeline — a traced paged serve whose per-tick
  fence/admit/begin/compute spans, per-page I/O spans and
  preempt/restore instants must RECONCILE with the metrics the same
  run records (summed ``exposed:*``/``hidden:*`` span durations equal
  ``paging.exposed_s``/``hidden_s`` within 10%, preempt instants equal
  ``scheduler.preemptions``) and carry the predicted-stall overlay
  track;
* the v6 metrics schema — every summary now carries a ``trace``
  section and ``validate`` rejects v5 payloads without one — and the
  StragglerMonitor, whose step timing rides the same span primitive.
"""

import gc
import json
import sys
import threading

import jax
import numpy as np
import pytest

from repro.core.placement import packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.parallel.sharding import freeze_for_serving
from repro.runtime.monitor import StragglerMonitor
from repro.serving import (Request, Scheduler, ServingEngine, Stopwatch,
                           Tracer, validate)
from repro.serving.trace import (doc_tracks, instant_count, span_durations,
                                 validate as validate_trace)

CFG = ModelConfig(name="tinyT", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, remat=False)


@pytest.fixture(scope="module")
def packed():
    return freeze_for_serving(tfm.init_params(CFG, jax.random.PRNGKey(0)),
                              bits=8)


def _half_paged_plan(packed):
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, sum(sizes.values()) // 2)
    assert plan.paged_bytes(sizes) > 0
    return plan


# ---------------------------------------------------------------------------
# the Tracer primitive
# ---------------------------------------------------------------------------

def test_span_nesting_instants_counters_roundtrip():
    tr = Tracer()
    with tr.span("tick", track="main", tick=0):
        with tr.span("admit", track="main"):
            tr.instant("reject", track="main", uid=3)
        tr.counter("pool_bytes", track="io", bytes=4096)
    tr.complete("page", 1e-3, track="io", page=7)
    doc = json.loads(tr.to_json())          # round-trip through JSON
    validate_trace(doc)
    assert doc["displayTimeUnit"] == "ms"
    assert tr.event_count == 7              # 2x(B+E) + i + C + X, no M
    assert doc_tracks(doc) == ["main", "io"]
    assert instant_count(doc, "reject") == 1
    (dur,) = span_durations(doc, "page", track="io")
    assert dur == pytest.approx(1e-3)
    # nesting: the inner admit span lies within the outer tick span
    tick, = span_durations(doc, "tick")
    admit, = span_durations(doc, "admit")
    assert admit <= tick


def test_span_args_and_timestamps_are_relative_microseconds():
    tr = Tracer()
    with tr.span("a", track="t", uid=1):
        pass
    doc = tr.to_dict()
    ev = [e for e in doc["traceEvents"] if e["ph"] == "B"][0]
    assert ev["args"] == {"uid": 1}
    assert 0.0 <= ev["ts"] < 1e6            # relative to tracer birth


def test_unclosed_begin_rejected():
    tr = Tracer()
    span = tr.span("open", track="main")
    span.__enter__()
    with pytest.raises(ValueError, match="unclosed"):
        validate_trace(tr.to_dict())
    span.__exit__(None, None, None)
    validate_trace(tr.to_dict())            # closed: valid again


def test_validate_rejects_malformed_docs():
    with pytest.raises(ValueError):
        validate_trace({})                  # no traceEvents
    base = dict(pid=0, tid=0, ts=0.0, name="x")
    with pytest.raises(ValueError, match="ph"):
        validate_trace({"traceEvents": [dict(base, ph="Q")]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [dict(base, ph="X", dur=-1.0)]})
    with pytest.raises(ValueError, match="backwards"):
        validate_trace({"traceEvents": [
            dict(base, ph="B", ts=5.0), dict(base, ph="E", ts=6.0),
            dict(base, ph="B", ts=1.0), dict(base, ph="E", ts=2.0)]})


def test_cross_thread_tracks_get_distinct_tids():
    tr = Tracer()

    def worker():
        tr.complete("fetch", 1e-4, track="io")

    t = threading.Thread(target=worker)
    with tr.span("tick", track="main"):
        t.start()
        t.join()
    doc = tr.to_dict()
    validate_trace(doc)
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert len(tids) == 2                   # one lane per track, not thread


def test_disabled_tracer_is_noop_and_shared_span():
    tr = Tracer(enabled=False)
    s1 = tr.span("a", track="x", big_arg=list(range(100)))
    s2 = tr.span("b")
    assert s1 is s2                         # the module-wide null span
    with s1:
        pass
    tr.instant("i")
    tr.counter("c", v=1)
    tr.complete("x", 1.0)
    assert tr.event_count == 0
    assert tr.track_names == []
    validate_trace(tr.to_dict())            # empty doc is a valid doc


def test_disabled_tracer_zero_allocation_steady_state():
    """The untraced hot path must not allocate per call: 15k
    span/instant/counter calls leave the interpreter's allocated-block
    count within pymalloc free-list noise (any per-call retention would
    show up as >= 15000 blocks)."""
    tr = Tracer(enabled=False)

    def one_pass(n):
        for _ in range(n):
            with tr.span("tick", track="t"):
                pass
            tr.instant("i", track="t")
            tr.counter("c", track="t", v=1)

    one_pass(100)                           # warm up caches
    gc.collect()
    before = sys.getallocatedblocks()
    one_pass(5000)
    assert sys.getallocatedblocks() - before < 16
    assert tr.event_count == 0


def test_stopwatch_brackets_and_injectable_clock():
    ticks = iter([1.0, 3.5])
    sw = Stopwatch(clock=lambda: next(ticks))
    with sw:
        pass
    assert sw.elapsed_s == pytest.approx(2.5)
    sw2 = Stopwatch()
    sw2.start()
    assert sw2.stop() >= 0.0


# ---------------------------------------------------------------------------
# the instrumented pipeline: spans reconcile with metrics
# ---------------------------------------------------------------------------

def _traced_serve(packed, rng, *, preempt=False):
    tr = Tracer()
    eng = ServingEngine(CFG, packed, batch_slots=1 if preempt else 2,
                        max_len=64, plan=_half_paged_plan(packed))
    eng.attach_paging()
    s = Scheduler(eng, prefill_chunk=8, async_io=True,
                  preemptive=preempt, tracer=tr, trace_track="m")
    if preempt:
        s.add_stream("urgent", priority=2)
        long_req = Request(uid=0, prompt=rng.integers(0, 256, 6)
                           .astype(np.int32), max_new_tokens=10)
        s.submit(long_req)
        for _ in range(4):
            s.tick()
        s.submit(Request(uid=1, prompt=rng.integers(0, 256, 5)
                         .astype(np.int32), max_new_tokens=3),
                 stream="urgent")
    else:
        for uid in range(3):
            s.submit(Request(uid=uid, prompt=rng.integers(0, 256, 6 + uid)
                             .astype(np.int32), max_new_tokens=5))
    s.run_until_done()
    doc = tr.to_dict()
    validate_trace(doc)
    eng.pager.close()
    return tr, doc, s, eng


def test_traced_run_phases_and_io_spans(packed, rng):
    tr, doc, s, eng = _traced_serve(packed, rng)
    # one fence + one compute + one admit span per tick, on the
    # tenant's track; begin skips ticks with no successor pass to kick
    for name in ("fence", "admit", "compute"):
        assert len(span_durations(doc, name, track="m")) == s.ticks, name
    assert (s.ticks - 1 <= len(span_durations(doc, "begin", track="m"))
            <= s.ticks)
    # every host->device page fetch is a span on the io track (demand
    # misses ride through the same fetch path, so swaps count them)
    pages = span_durations(doc, "page", track="io")
    assert len(pages) == eng.swap_count
    assert all(d >= 0.0 for d in pages)
    # the async pipeline kicked passes -> begin_pass instants
    assert instant_count(doc, "begin_pass", track="m") > 0
    # compute dominates the tick (sanity that spans carry real time)
    assert sum(span_durations(doc, "compute", track="m")) > 0.0


def test_trace_reconciles_with_paging_metrics(packed, rng):
    """The acceptance bar: summed stall-span durations equal the
    exposed/hidden stall the SAME run's metrics recorded, within 10%."""
    tr, doc, s, eng = _traced_serve(packed, rng)
    summary = validate(s.metrics.summary(paging=eng.paging_summary(),
                                         trace=s.trace_summary()))
    pg = summary["paging"]
    span_exposed = sum(span_durations(doc, "exposed:weights",
                                      track="m:stall"))
    span_hidden = sum(span_durations(doc, "hidden:weights",
                                     track="m:stall"))
    assert span_exposed == pytest.approx(pg["exposed_s"], rel=0.10)
    assert span_hidden == pytest.approx(pg["hidden_s"], rel=0.10)


def test_predicted_overlay_track_and_drift_ratio(packed, rng):
    tr, doc, s, eng = _traced_serve(packed, rng)
    assert "m (predicted)" in doc_tracks(doc)
    preds = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "stall(pred)"]
    assert preds and all(
        set(e["args"]) >= {"predicted_exposed_ms", "measured_exposed_ms",
                           "predicted_swaps_per_pass"} for e in preds)
    ts = s.trace_summary()
    assert ts["events"] == tr.event_count > 0
    assert "m (predicted)" in ts["tracks"]
    assert ts["predicted_vs_measured_stall_ratio"] >= 0.0


def test_preempt_restore_instants_match_scheduler_counters(packed, rng):
    tr, doc, s, eng = _traced_serve(packed, rng, preempt=True)
    assert s.metrics.preemptions >= 1
    assert instant_count(doc, "preempt", track="m") == s.metrics.preemptions
    assert instant_count(doc, "restore", track="m") == s.metrics.restores
    assert instant_count(doc, "admit", track="m") >= 2  # both requests


def test_untraced_scheduler_stays_untraced(packed, rng):
    eng = ServingEngine(CFG, packed, batch_slots=2, max_len=64,
                        plan=_half_paged_plan(packed))
    eng.attach_paging()
    s = Scheduler(eng, prefill_chunk=8)
    assert s.tracer is None and eng.tracer is None
    assert eng.pager.tracer is None
    s.submit(Request(uid=0, prompt=rng.integers(0, 256, 6)
                     .astype(np.int32), max_new_tokens=3))
    s.run_until_done()
    ts = s.trace_summary()
    assert ts["events"] == 0 and ts["tracks"] == []
    # the predicted-vs-measured drift is tracked tracer-independently,
    # so even an untraced paged run reports a meaningful ratio
    assert ts["predicted_vs_measured_stall_ratio"] > 0.0
    eng.pager.close()


# ---------------------------------------------------------------------------
# metrics schema v6 + StragglerMonitor on the span primitive
# ---------------------------------------------------------------------------

def test_metrics_v6_carries_trace_section_and_rejects_v5(packed, rng):
    _tr, _doc, s, eng = _traced_serve(packed, rng)
    doc = validate(s.metrics.summary(trace=s.trace_summary()))
    assert doc["trace"]["events"] > 0
    bare = validate(s.metrics.summary())    # no trace kwarg: zero section
    assert bare["trace"] == dict(events=0, tracks=[],
                                 predicted_vs_measured_stall_ratio=1.0)
    stale = s.metrics.summary()
    del stale["trace"]                      # a v5 payload
    with pytest.raises(ValueError):
        validate(stale)


def test_straggler_monitor_rides_the_tracer():
    t = [0.0]

    def clock():
        return t[0]

    mon = StragglerMonitor(warmup=2, threshold=2.0,
                           tracer=Tracer(clock=clock))
    durs = [0.1, 0.1, 0.1, 0.1, 0.5, 0.1]   # step 4 is the straggler
    for d in durs:
        mon.step_start()
        t[0] += d
        assert mon.step_end() == (d == 0.5)
    assert mon.flagged == [4]
    doc = mon.tracer.to_dict()
    validate_trace(doc)
    steps = span_durations(doc, "step", track="train")
    assert len(steps) == len(durs)
    assert steps == pytest.approx(durs)
    assert instant_count(doc, "straggler", track="train") == 1

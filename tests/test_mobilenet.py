"""Int8 MobileNet-V2 on the N-EUREKA path (the paper's workload)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mobilenet_v2 as mnv2
from repro.core.perf_model import mobilenet_v2_jobs


@pytest.mark.parametrize("bits", [8, 4])
def test_mnv2_small_image_runs(rng, bits):
    """Reduced 32x32 input (same network family) through the full int8
    pipeline in xla mode; asserts shape + usable dynamic range."""
    params = mnv2.init_params(jax.random.PRNGKey(0), weight_bits=bits, img=32)
    packed = mnv2.freeze_packed(params, weight_bits=bits, img=32)
    img = jnp.asarray(rng.integers(0, 255, (32, 32, 3)), jnp.uint8)
    logits = mnv2.apply(packed, img, weight_bits=bits, mode="xla", img=32)
    assert logits.shape == (1000,)
    assert int(logits.max()) > int(logits.min())    # not collapsed


def test_mnv2_jobs_match_model_structure():
    jobs = mobilenet_v2_jobs(8, 224)
    kinds = [j.op_kind for j in jobs]
    # 1 stem conv + 17 blocks (16 with expand) + head convs
    assert kinds[0] == "dense3x3"
    assert kinds.count("dw3x3") == 17
    assert kinds.count("pw1x1") == 2 + 16 * 2 + 1   # expands+projects+head+fc
    # stride-2 where the architecture downsamples
    strides = [j.stride for j in jobs if j.op_kind == "dw3x3"]
    assert strides.count(2) == 4


def test_mnv2_kernel_mode_agreement(rng):
    """interpret (real Pallas kernels) == xla path on a small image."""
    params = mnv2.init_params(jax.random.PRNGKey(0), weight_bits=8, img=32)
    packed = mnv2.freeze_packed(params, weight_bits=8, img=32)
    img = jnp.asarray(rng.integers(0, 255, (32, 32, 3)), jnp.uint8)
    a = mnv2.apply(packed, img, weight_bits=8, mode="xla", img=32)
    b = mnv2.apply(packed, img, weight_bits=8, mode="interpret", img=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

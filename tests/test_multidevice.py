"""Multi-device behaviour (sharding rules, compressed collectives, pipeline
parallelism, elastic checkpoint restore) — each case runs in a subprocess
with xla_force_host_platform_device_count so the main test process keeps
its single CPU device.

These passed again once launch/mesh.py stopped requiring
``jax.sharding.AxisType`` (absent from older jax releases, where every
mesh axis is Auto anyway); ``_mesh_supported`` keeps them a *named* skip
— not a silent deselect — on environments where the forced-device
subprocess cannot build a mesh at all, and
``test_param_shardings_single_device_equivalence`` covers the sharding
rules in-process on one device so the path is never untested."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_supported() -> bool:
    import jax
    return hasattr(jax, "make_mesh")


needs_mesh = pytest.mark.skipif(
    not _mesh_supported(),
    reason="this jax has no jax.make_mesh; the subprocess mesh tests "
           "cannot run (single-device sharding equivalence still does)")


def run_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_param_shardings_single_device_equivalence():
    """In-process, one device: every arch's sharding specs divide the
    leaf shapes, and device_put under a 1x1 mesh is a value no-op — the
    rule set stays exercised even where the 8-device subprocess override
    is unavailable."""
    import jax
    import numpy as np
    from repro.configs import ARCHS
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import param_specs, serve_param_specs
    from repro.models import transformer as tfm
    from repro.parallel import sharding as shd

    mesh = make_test_mesh((1, 1), ("data", "model"))
    for name, cfg in list(ARCHS.items())[:4]:
        for tree in (param_specs(cfg), serve_param_specs(cfg, 8)):
            shards = shd.param_shardings(tree, mesh)
            flat = jax.tree_util.tree_flatten_with_path(tree)[0]
            sflat = jax.tree_util.tree_leaves(shards)
            for (path, leaf), s in zip(flat, sflat):
                for dim, ax in zip(leaf.shape, s.spec):
                    if ax is None:
                        continue
                    size = mesh.shape[ax] if isinstance(ax, str) else 1
                    assert dim % size == 0, (name, path, leaf.shape, s.spec)
    cfg = list(ARCHS.values())[0].smoke()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    placed = jax.device_put(params, shd.param_shardings(params, mesh))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@needs_mesh
def test_param_sharding_rules_all_archs():
    """Every leaf's PartitionSpec divides its dimensions, for all 10 archs,
    dense and packed trees, on a (2, 4) data x model mesh."""
    run_devices("""
        import jax
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import param_specs, serve_param_specs
        from repro.parallel import sharding as shd

        mesh = make_test_mesh((2, 4), ("data", "model"))
        for name, cfg in ARCHS.items():
            for tree in (param_specs(cfg), serve_param_specs(cfg, 8)):
                shards = shd.param_shardings(tree, mesh)
                flat = jax.tree_util.tree_flatten_with_path(tree)[0]
                sflat = jax.tree_util.tree_leaves(shards)
                for ((path, leaf), s) in zip(flat, sflat):
                    spec = s.spec
                    for dim, ax in zip(leaf.shape, spec):
                        if ax is None:
                            continue
                        size = mesh.shape[ax] if isinstance(ax, str) else 1
                        assert dim % size == 0, (name, path, leaf.shape, spec)
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_distributed_train_step_matches_single_device():
    """A jitted train step on a 2x2 mesh equals the single-device result."""
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import make_train_step, param_specs
        from repro.models import transformer as tfm
        from repro.optim import adamw
        from repro.parallel import sharding as shd

        cfg = ARCHS["qwen3-0.6b"].smoke().replace(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=256)
        opt = adamw()
        step = make_train_step(cfg, opt)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = dict(tokens=jnp.asarray(rng.integers(0, 256, (4, 32))),
                     labels=jnp.asarray(rng.integers(0, 256, (4, 32))))

        ref_p, _, ref_m = jax.jit(step)(params, opt_state, batch)

        mesh = make_test_mesh((2, 2), ("data", "model"))
        pshard = shd.param_shardings(params, mesh)
        oshard = shd.opt_state_shardings(opt_state, mesh, params)
        with mesh:
            params_d = jax.device_put(params, pshard)
            opt_d = jax.device_put(opt_state, oshard)
            out_p, _, m = jax.jit(step)(params_d, opt_d, batch)
        assert abs(float(m["loss"]) - float(ref_m["loss"])) < 1e-4
        for a, b in zip(jax.tree_util.tree_leaves(ref_p),
                        jax.tree_util.tree_leaves(out_p)):
            np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                       np.asarray(b, dtype=np.float32),
                                       rtol=2e-3, atol=2e-3)
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_compressed_allreduce():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.compress import (compressed_allreduce_mean,
                                             init_residual,
                                             with_error_feedback)

        mesh = make_test_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

        f = shard_map(lambda x: compressed_allreduce_mean(x, "data"),
                      mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                      check_rep=False)
        out = f(g)                      # every shard holds the mean row
        expect = np.mean(np.asarray(g), axis=0)
        got = np.asarray(out)[0]
        # int8 compression: error bounded by ~scale = absmax/127
        bound = np.abs(np.asarray(g)).max() / 127 + 1e-6
        assert np.abs(got - expect).max() <= bound, np.abs(got - expect).max()

        # error feedback shrinks the accumulated bias over repeats
        def ef_step(x, r):
            return with_error_feedback(dict(g=x), dict(g=r), "data")
        f2 = shard_map(ef_step, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_rep=False)
        r = jnp.zeros((8, 64))
        errs = []
        acc = np.zeros(64)
        for it in range(8):
            out, new_r = f2(g, r)
            acc += np.asarray(out["g"])[0]
            r = new_r["g"]
            errs.append(np.abs(acc / (it + 1) - expect).max())
        assert errs[-1] <= errs[0] + 1e-9
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_pipeline_parallel_equivalence():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.pipeline import bubble_fraction, pipelined_apply

        mesh = make_test_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(4, 16, 16)) * 0.3, jnp.float32)

        def layer_fn(x, w):
            return jnp.tanh(x @ w)

        fn = pipelined_apply(layer_fn, mesh, "stage", n_microbatches=4)
        x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        with mesh:
            out = fn(x, ws)
        ref = x
        for i in range(4):
            ref = layer_fn(ref, ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        assert bubble_fraction(4, 4) == (4 - 1) / (4 - 1 + 4)
        print("OK")
    """)


def test_opt_state_shardings_keyed_by_path_not_shape():
    """Two same-shape params with DIFFERENT partition specs must keep
    their own specs through the optimizer-state mirror — the shape-keyed
    lookup this replaces silently collided (last-one-wins)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.parallel import sharding as shd

    mesh = make_test_mesh((1, 1), ("data", "model"))
    # conv_w's rule is P(model, None); a generic 2-D (out, in) matmul
    # weight gets P(model, data) — same (8, 8) shape, different specs
    params = dict(conv_w=jnp.zeros((8, 8)), wq=jnp.zeros((8, 8)))
    assert (shd._param_pspec(("conv_w",), (8, 8), mesh)
            != shd._param_pspec(("wq",), (8, 8), mesh))
    opt_state = dict(mu=params, nu=params)
    out = shd.opt_state_shardings(opt_state, mesh, params)
    for moment in ("mu", "nu"):
        assert out[moment]["conv_w"].spec == P("model", None)
        assert out[moment]["wq"].spec == P("model", "data")


def test_make_test_mesh_clamps_to_available_devices():
    """A shape wanting more devices than the host exposes degrades (with
    a warning) instead of raising, keeping the axis NAMES intact."""
    import jax
    from repro.launch.mesh import make_test_mesh

    want = (jax.device_count() + 1, 2)
    with pytest.warns(UserWarning, match="clamping"):
        mesh = make_test_mesh(want, ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")
    assert mesh.devices.size <= jax.device_count()


def test_plan_for_budget_charges_sharded_params_per_device():
    """shard_factors: a param sharded n ways pins only 1/n of its bytes
    per device, so a tight per-device budget admits it resident where
    the unsharded charge would have paged it."""
    from repro.core.placement import Placement, plan_for_budget

    sizes = {"a": 1000, "b": 1000}
    hot = Placement("l1mram", 8, "resident")
    cold = Placement("l3flash", 8, "paged")
    flat = plan_for_budget(sizes, 500, hot=hot, cold=cold)
    assert flat.placement_for("a").residency == "paged"
    assert flat.placement_for("b").residency == "paged"
    plan = plan_for_budget(sizes, 500, hot=hot, cold=cold,
                           shard_factors={"a": 4})
    assert plan.placement_for("a").residency == "resident"  # 250 B/device
    assert plan.placement_for("b").residency == "paged"     # 1000 > 250 left
    # per-device budget respected: resident charge is the sharded one
    assert -(-sizes["a"] // 4) <= 500


def test_packed_sizes_shard_factors_divide():
    import numpy as np
    from repro.core.placement import packed_sizes

    tree = {"wq": {"packed": np.zeros((8, 16), np.uint8),
                   "scale": np.zeros((8, 1), np.float32)}}
    whole = packed_sizes(tree)
    per_dev = packed_sizes(tree, shard_factors={"wq": 4})
    assert whole["wq"] == 128
    assert per_dev["wq"] == -(-whole["wq"] // 4)


_SHARDED_SERVE = """
    import json, os, sys, tempfile
    from repro.launch import serve

    path = os.path.join(tempfile.mkdtemp(), "BENCH_mesh_test.json")
    argv = ["--smoke", "--budget-mb", "0.05", "--requests", "3",
            "--max-new", "4", "--mesh", "4", "--metrics-json", path]
    {extra}
    serve.main(argv)
    doc = json.load(open(path))
    mesh = doc["mesh"]
    assert mesh["n_devices"] == 4, mesh
    assert mesh["sharded_params"] > 0, mesh
    assert mesh["bit_exact"] is True, mesh
    assert mesh["predicted_ok"] is True, mesh
    assert mesh["ledger_ok"] is True, mesh
    led = mesh["ledger"]
    assert len(led["per_device"]) == 4
    for key in ("swap_count", "miss_count", "bytes_streamed_wire",
                "bytes_streamed_raw"):
        assert led[key] == sum(d[key] for d in led["per_device"]), key
    # the global ledger equals the single-device one; every link moves
    # strictly less than the single link did
    single = mesh["single_device"]
    assert led["bytes_streamed_wire"] == single["bytes_streamed_wire"]
    assert mesh["per_link_max_wire"] < single["bytes_streamed_wire"]
    assert doc["paging"]["devices"] == led["per_device"]
    print("OK")
"""


@pytest.mark.slow
@needs_mesh
def test_sharded_serving_bit_exact_fp_pages():
    """Mesh-sharded paged serving (fp pages) on a 1x4 mesh: serve.main's
    verify legs gate tokens bit-exact vs the single-device paged run
    (async AND sync — the sync leg is meshed too) and the per-device
    ledger summing to the global kv_pass_counters prediction."""
    run_devices(_SHARDED_SERVE.format(extra=""), n=4)


@pytest.mark.slow
@needs_mesh
def test_sharded_serving_bit_exact_int8_pages():
    """Same gates with int8-encoded page wire (--page-bits 8, the
    run-quantized identity): per-row scales slice along the shard axis
    with their rows, so shard-then-encode == encode-then-shard."""
    run_devices(
        _SHARDED_SERVE.format(extra='argv += ["--page-bits", "8"]'), n=4)


@pytest.mark.slow
@needs_mesh
def test_sharded_store_join_and_no_orphaned_pass():
    """ShardedPagedStore mechanics, below the engine: the joined fence
    reconstructs every sharded param's device bytes exactly, and an
    early close releases EVERY per-device pool's pass guard (no orphaned
    pass blocks the next one)."""
    run_devices("""
        import numpy as np
        import jax
        from repro.configs import ARCHS
        from repro.core.paging import ShardedPagedStore, packed_tree_store
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tfm
        from repro.parallel.sharding import freeze_for_serving

        cfg = ARCHS["qwen3-0.6b"].smoke().replace(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            vocab_size=256)
        packed = freeze_for_serving(
            tfm.init_params(cfg, jax.random.PRNGKey(0)), bits=8)
        store = packed_tree_store(packed, None)   # plan-less: all paged
        mesh = make_test_mesh((1, 4), ("data", "model"))
        page_bytes = max(p.nbytes_packed for p in store.params.values())
        sps = ShardedPagedStore(store, page_bytes, mesh, plan=None,
                                budget_bytes=1 << 22)
        assert sps.shard_axes, "smoke net must shard something"

        # a fenced pass joins the per-device fetches byte-exactly
        with sps.begin_pass() as ps1:
            dev = ps1.fence()
        for name, (ax, n) in sps.shard_axes.items():
            np.testing.assert_array_equal(
                np.asarray(dev[name].packed),
                np.asarray(store.params[name].packed))
            np.testing.assert_array_equal(
                np.asarray(dev[name].scale),
                np.asarray(store.params[name].scale))
            assert dev[name].orig_shape == store.params[name].orig_shape

        # runtime counters match the ledger's static prediction (every
        # begun pass fenced so far — the determinism precondition)
        pred = sps.predict()
        assert sps.swap_count == pred["swaps"], (sps.swap_count, pred)
        assert sps.bytes_streamed_wire == pred["bytes_wire"]

        # early close: the joined stream was never fenced, yet every
        # per-device pool guard is released — no orphaned pass
        ps = sps.begin_pass()
        ps.close()
        for pool in sps.ledger.pools:
            assert not pool._active_fetch, pool._active_fetch
        try:
            ps.fence()
            raise AssertionError("fence after close must raise")
        except RuntimeError:
            pass

        # and the store still serves: the next pass begins and fences
        with sps.begin_pass() as ps3:
            dev3 = ps3.fence()
        assert set(dev3) == set(dev)
        sps.close()
        print("OK")
    """, n=4)


@pytest.mark.slow
@needs_mesh
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save sharded on a (4,2) mesh, restore onto (2,4) — elastic scaling."""
    run_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_test_mesh
        from repro.parallel import sharding as shd
        from repro.models import transformer as tfm
        from repro.configs import ARCHS

        cfg = ARCHS["olmo-1b"].smoke()
        params = tfm.init_params(cfg, jax.random.PRNGKey(1))

        mesh_a = make_test_mesh((4, 2), ("data", "model"))
        shard_a = shd.param_shardings(params, mesh_a)
        params_a = jax.device_put(params, shard_a)

        mgr = CheckpointManager(r"{tmp_path}", async_save=False)
        mgr.save(3, dict(params=params_a))

        mesh_b = make_test_mesh((2, 4), ("data", "model"))
        shard_b = shd.param_shardings(params, mesh_b)
        step, state = mgr.restore(dict(params=params),
                                  shardings=dict(params=shard_b))
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored arrays live on the NEW mesh
        leaf = jax.tree_util.tree_leaves(state["params"])[0]
        assert leaf.sharding.mesh.shape == {{"data": 2, "model": 4}}
        print("OK")
    """)

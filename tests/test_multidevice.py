"""Multi-device behaviour (sharding rules, compressed collectives, pipeline
parallelism, elastic checkpoint restore) — each case runs in a subprocess
with xla_force_host_platform_device_count so the main test process keeps
its single CPU device.

These passed again once launch/mesh.py stopped requiring
``jax.sharding.AxisType`` (absent from older jax releases, where every
mesh axis is Auto anyway); ``_mesh_supported`` keeps them a *named* skip
— not a silent deselect — on environments where the forced-device
subprocess cannot build a mesh at all, and
``test_param_shardings_single_device_equivalence`` covers the sharding
rules in-process on one device so the path is never untested."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_supported() -> bool:
    import jax
    return hasattr(jax, "make_mesh")


needs_mesh = pytest.mark.skipif(
    not _mesh_supported(),
    reason="this jax has no jax.make_mesh; the subprocess mesh tests "
           "cannot run (single-device sharding equivalence still does)")


def run_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_param_shardings_single_device_equivalence():
    """In-process, one device: every arch's sharding specs divide the
    leaf shapes, and device_put under a 1x1 mesh is a value no-op — the
    rule set stays exercised even where the 8-device subprocess override
    is unavailable."""
    import jax
    import numpy as np
    from repro.configs import ARCHS
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import param_specs, serve_param_specs
    from repro.models import transformer as tfm
    from repro.parallel import sharding as shd

    mesh = make_test_mesh((1, 1), ("data", "model"))
    for name, cfg in list(ARCHS.items())[:4]:
        for tree in (param_specs(cfg), serve_param_specs(cfg, 8)):
            shards = shd.param_shardings(tree, mesh)
            flat = jax.tree_util.tree_flatten_with_path(tree)[0]
            sflat = jax.tree_util.tree_leaves(shards)
            for (path, leaf), s in zip(flat, sflat):
                for dim, ax in zip(leaf.shape, s.spec):
                    if ax is None:
                        continue
                    size = mesh.shape[ax] if isinstance(ax, str) else 1
                    assert dim % size == 0, (name, path, leaf.shape, s.spec)
    cfg = list(ARCHS.values())[0].smoke()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    placed = jax.device_put(params, shd.param_shardings(params, mesh))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@needs_mesh
def test_param_sharding_rules_all_archs():
    """Every leaf's PartitionSpec divides its dimensions, for all 10 archs,
    dense and packed trees, on a (2, 4) data x model mesh."""
    run_devices("""
        import jax
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import param_specs, serve_param_specs
        from repro.parallel import sharding as shd

        mesh = make_test_mesh((2, 4), ("data", "model"))
        for name, cfg in ARCHS.items():
            for tree in (param_specs(cfg), serve_param_specs(cfg, 8)):
                shards = shd.param_shardings(tree, mesh)
                flat = jax.tree_util.tree_flatten_with_path(tree)[0]
                sflat = jax.tree_util.tree_leaves(shards)
                for ((path, leaf), s) in zip(flat, sflat):
                    spec = s.spec
                    for dim, ax in zip(leaf.shape, spec):
                        if ax is None:
                            continue
                        size = mesh.shape[ax] if isinstance(ax, str) else 1
                        assert dim % size == 0, (name, path, leaf.shape, spec)
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_distributed_train_step_matches_single_device():
    """A jitted train step on a 2x2 mesh equals the single-device result."""
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import make_train_step, param_specs
        from repro.models import transformer as tfm
        from repro.optim import adamw
        from repro.parallel import sharding as shd

        cfg = ARCHS["qwen3-0.6b"].smoke().replace(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=256)
        opt = adamw()
        step = make_train_step(cfg, opt)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = dict(tokens=jnp.asarray(rng.integers(0, 256, (4, 32))),
                     labels=jnp.asarray(rng.integers(0, 256, (4, 32))))

        ref_p, _, ref_m = jax.jit(step)(params, opt_state, batch)

        mesh = make_test_mesh((2, 2), ("data", "model"))
        pshard = shd.param_shardings(params, mesh)
        oshard = shd.opt_state_shardings(opt_state, mesh, params)
        with mesh:
            params_d = jax.device_put(params, pshard)
            opt_d = jax.device_put(opt_state, oshard)
            out_p, _, m = jax.jit(step)(params_d, opt_d, batch)
        assert abs(float(m["loss"]) - float(ref_m["loss"])) < 1e-4
        for a, b in zip(jax.tree_util.tree_leaves(ref_p),
                        jax.tree_util.tree_leaves(out_p)):
            np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                       np.asarray(b, dtype=np.float32),
                                       rtol=2e-3, atol=2e-3)
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_compressed_allreduce():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.compress import (compressed_allreduce_mean,
                                             init_residual,
                                             with_error_feedback)

        mesh = make_test_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

        f = shard_map(lambda x: compressed_allreduce_mean(x, "data"),
                      mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                      check_rep=False)
        out = f(g)                      # every shard holds the mean row
        expect = np.mean(np.asarray(g), axis=0)
        got = np.asarray(out)[0]
        # int8 compression: error bounded by ~scale = absmax/127
        bound = np.abs(np.asarray(g)).max() / 127 + 1e-6
        assert np.abs(got - expect).max() <= bound, np.abs(got - expect).max()

        # error feedback shrinks the accumulated bias over repeats
        def ef_step(x, r):
            return with_error_feedback(dict(g=x), dict(g=r), "data")
        f2 = shard_map(ef_step, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_rep=False)
        r = jnp.zeros((8, 64))
        errs = []
        acc = np.zeros(64)
        for it in range(8):
            out, new_r = f2(g, r)
            acc += np.asarray(out["g"])[0]
            r = new_r["g"]
            errs.append(np.abs(acc / (it + 1) - expect).max())
        assert errs[-1] <= errs[0] + 1e-9
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_pipeline_parallel_equivalence():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.pipeline import bubble_fraction, pipelined_apply

        mesh = make_test_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(4, 16, 16)) * 0.3, jnp.float32)

        def layer_fn(x, w):
            return jnp.tanh(x @ w)

        fn = pipelined_apply(layer_fn, mesh, "stage", n_microbatches=4)
        x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        with mesh:
            out = fn(x, ws)
        ref = x
        for i in range(4):
            ref = layer_fn(ref, ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        assert bubble_fraction(4, 4) == (4 - 1) / (4 - 1 + 4)
        print("OK")
    """)


@pytest.mark.slow
@needs_mesh
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save sharded on a (4,2) mesh, restore onto (2,4) — elastic scaling."""
    run_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_test_mesh
        from repro.parallel import sharding as shd
        from repro.models import transformer as tfm
        from repro.configs import ARCHS

        cfg = ARCHS["olmo-1b"].smoke()
        params = tfm.init_params(cfg, jax.random.PRNGKey(1))

        mesh_a = make_test_mesh((4, 2), ("data", "model"))
        shard_a = shd.param_shardings(params, mesh_a)
        params_a = jax.device_put(params, shard_a)

        mgr = CheckpointManager(r"{tmp_path}", async_save=False)
        mgr.save(3, dict(params=params_a))

        mesh_b = make_test_mesh((2, 4), ("data", "model"))
        shard_b = shd.param_shardings(params, mesh_b)
        step, state = mgr.restore(dict(params=params),
                                  shardings=dict(params=shard_b))
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored arrays live on the NEW mesh
        leaf = jax.tree_util.tree_leaves(state["params"])[0]
        assert leaf.sharding.mesh.shape == {{"data": 2, "model": 4}}
        print("OK")
    """)

"""The calibrated memsys model must reproduce the paper's claims."""

import collections

import pytest

from repro.core import memsys
from repro.core.memsys import LOW_POWER, NOMINAL, neureka_gops
from repro.core.perf_model import (mnv2_scenario_table, mnv2_total_macs,
                                   mnv2_weight_bytes)


def test_mobilenet_job_list_matches_network():
    # MobileNet-V2 1.0-224: ~300M MACs, 3.4M params
    assert mnv2_total_macs() == pytest.approx(300e6, rel=0.05)
    assert mnv2_weight_bytes(8) == pytest.approx(3.4e6, rel=0.05)
    # all-weights-on-chip claim: 8-bit weights fit the 4 MiB MRAM
    assert mnv2_weight_bytes(8) <= 4 * 1024 * 1024


def test_fig10_latency_energy_anchors():
    tab = mnv2_scenario_table()
    lat = {s: t for s, (t, e, _) in tab.items()}
    en = {s: e for s, (t, e, _) in tab.items()}
    # paper: 12.6 ms / 3.8 mJ (L3FLASH) and 7.3 ms / 1.4 mJ (L1MRAM)
    assert lat["l3flash"] == pytest.approx(12.6e-3, rel=0.10)
    assert en["l3flash"] == pytest.approx(3.8e-3, rel=0.10)
    assert lat["l1mram"] == pytest.approx(7.3e-3, rel=0.10)
    assert en["l1mram"] == pytest.approx(1.4e-3, rel=0.10)


def test_fig10_headline_ratios():
    tab = mnv2_scenario_table()
    # 1.7x latency and ~3x energy vs off-chip NVM (abstract claim)
    assert tab["l3flash"][0] / tab["l1mram"][0] == pytest.approx(1.7, rel=0.08)
    assert tab["l3flash"][1] / tab["l1mram"][1] == pytest.approx(3.0, rel=0.15)
    # monotone improvement with coupling tightness
    order = ["l3flash", "l3mram", "l2mram", "l1mram"]
    lats = [tab[s][0] for s in order]
    assert lats == sorted(lats, reverse=True)


def test_l3mram_energy_halves():
    tab = mnv2_scenario_table()
    # paper: on-chip MRAM as L3 lowers energy ~2x vs off-chip flash
    assert tab["l3flash"][1] / tab["l3mram"][1] == pytest.approx(2.0, rel=0.15)


def test_neureka_throughput_anchors():
    # Fig 8 anchors at nominal 360 MHz
    assert neureka_gops("dense3x3", 8) == pytest.approx(698e9, rel=0.01)
    assert neureka_gops("dense3x3", 2) == pytest.approx(1947e9, rel=0.01)
    # ideal 738 GOp/s at 8b (utilization ~0.95)
    assert memsys.neureka_ideal_gops("dense3x3", 8) == pytest.approx(
        738e9, rel=0.01)
    # low-power point scales with frequency
    assert neureka_gops("dense3x3", 8, LOW_POWER) == pytest.approx(
        698e9 * 210 / 360, rel=0.01)


def test_layerwise_regimes_fig11():
    """L3FLASH shows weight-memory-bound deep layers; L1MRAM eliminates
    them (paper Fig 11)."""
    tab = mnv2_scenario_table()
    flash_regimes = collections.Counter(
        t.regime for t in tab["l3flash"][2])
    l1_regimes = collections.Counter(t.regime for t in tab["l1mram"][2])
    assert flash_regimes["weight-memory"] >= 5
    assert l1_regimes["weight-memory"] <= 1
    # the deep 1x1 layers are the weight-bound ones under L3FLASH
    deep_pw = [t for t in tab["l3flash"][2]
               if t.name.endswith("pw_proj")][-3:]
    assert any(t.regime == "weight-memory" for t in deep_pw)


def test_weight_bits_cut_weight_path():
    """2-bit weights reduce the weight-path pressure 4x (MRAM density /
    bit-serial claim carried to the model)."""
    t8 = mnv2_scenario_table(weight_bits=8)["l3flash"][0]
    t2 = mnv2_scenario_table(weight_bits=2)["l3flash"][0]
    assert t2 < t8 * 0.75  # substantially faster when weight-bound


def test_table1_operating_points():
    assert NOMINAL.cluster_hz == 360e6 and NOMINAL.mram_hz == 180e6
    assert LOW_POWER.cluster_hz == 210e6
    # power scaling ~2.2x from the paper
    assert NOMINAL.cluster_power_w / LOW_POWER.cluster_power_w == pytest.approx(
        2.2, rel=0.05)
    # MRAM port bandwidth: 92 Gbit/s at nominal
    assert memsys.mram_port_Bps(NOMINAL) * 8 == pytest.approx(92e9, rel=0.01)
    # L1 aggregate: 184 Gbit/s
    assert memsys.l1_total_Bps(NOMINAL) * 8 == pytest.approx(184e9, rel=0.01)

"""Fused dequant matmul — the At-MRAM weight path as a Pallas TPU kernel.

The Siracusa mechanism (paper Fig. 4): packed sub-byte weights are streamed
from the MRAM over a dedicated port, expanded bit-serially *at* the PEs, and
never staged at full width in any intermediate memory.  The TPU-native
analogue implemented here:

  * weights live **packed** (2/4/8-bit fields in a uint8 carrier) in HBM;
  * the Pallas grid pipeline double-buffers packed blocks HBM->VMEM
    (= the 2-bank interleaved MRAM prefetch hiding the 9-cycle latency);
  * unpack + dequant happen **inside the kernel**, adjacent to the MXU
    (= the At-Memory expansion at the PE inputs);
  * per-output-channel scales are applied once per output block on the final
    reduction step (= the NORMQUANT per-channel projection).

Two datapaths, mirroring N-EUREKA's two consumers:
  - float path  (LM serving):   x bf16/f32  @ W_packed -> f32
  - integer path (N-EUREKA pw): x uint8     @ W_packed -> int32 -> requant uint8

Block shapes are MXU-aligned (multiples of 128 where the problem allows) and
the K (reduction) grid axis is innermost so output blocks stay resident in
VMEM across the reduction — output-stationary, like N-EUREKA's accumulators.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_block(wp: jax.Array, bits: int) -> jax.Array:
    """uint8 carrier block (bn, bk/f) -> signed int8-valued int32 (bn, bk)."""
    if bits == 8:
        return wp.astype(jnp.int32) - 128
    f = 8 // bits
    shifts = (jnp.arange(f, dtype=jnp.uint32) * bits)
    mask = jnp.uint32((1 << bits) - 1)
    fields = (wp[..., None].astype(jnp.uint32) >> shifts) & mask
    levels = fields.astype(jnp.int32) - (1 << (bits - 1))
    bn, bkp, _ = levels.shape
    return levels.reshape(bn, bkp * f)


def _qmatmul_f32_kernel(x_ref, wp_ref, scale_ref, o_ref, *, bits: int, nk: int):
    """out[m, n] = sum_k x[m, k] * unpack(wp)[n, k] * scale[n]  (f32 acc)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    w = _unpack_block(wp_ref[...], bits).astype(jnp.float32)   # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _scale():
        o_ref[...] = o_ref[...] * scale_ref[...][None, :]


def _qmatmul_f32_blockscale_kernel(x_ref, wp_ref, scale_ref, o_ref, *,
                                   bits: int, block: int):
    """out[m, n] = sum_k x[m, k] * unpack(wp)[n, k] * scale[n, k // block].

    The per-(channel, block) scales of the page wire encoding
    (core.quantize.quantize_blockwise) are applied to the unpacked levels
    *inside* the reduction — the fused "run straight off the wire form"
    path, so an encoded page never needs decoding into the per-channel
    device format before compute.  Unlike the per-channel kernel there is
    no final scale step: each k-block is already fully scaled when it
    enters the MXU.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                         # (bm, bk)
    w = _unpack_block(wp_ref[...], bits).astype(jnp.float32)   # (bn, bk)
    s = scale_ref[...]                                         # (bn, bk/block)
    bn, bk = w.shape
    w = (w.reshape(bn, bk // block, block) * s[:, :, None]).reshape(bn, bk)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _qmatmul_int8_kernel(x_ref, wp_ref, mult_ref, bias_ref, o_ref, acc_ref,
                         *, bits: int, nk: int):
    """Integer path with fused requant: uint8 act x packed W -> uint8.

    acc int32 lives in VMEM scratch (the SCM accumulators); the NORMQUANT
    projection (per-channel float rescale + bias + clip) runs on the final
    reduction step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)                       # (bm, bk) uint8->i32
    w = _unpack_block(wp_ref[...], bits)                   # (bn, bk) i32
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _requant():
        acc = acc_ref[...].astype(jnp.float32) * mult_ref[...][None, :]
        acc = jnp.round(acc) + bias_ref[...][None, :].astype(jnp.float32)
        o_ref[...] = jnp.clip(acc, 0.0, 255.0).astype(jnp.uint8)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qmatmul_f32(x: jax.Array, packed: jax.Array, scale: jax.Array, *,
                bits: int, k_orig: int,
                bm: int = 128, bn: int = 128, bk: int = 512,
                interpret: bool = False) -> jax.Array:
    """x (M, K) float @ packed (N, K/f) uint8 with per-N scale -> (M, N) f32.

    Blocks are padded to (bm, bn, bk); bk must be a multiple of the packing
    factor so packed blocks stay byte-aligned (= MRAM-row aligned).
    """
    f = 8 // bits
    assert bk % f == 0
    m, k = x.shape
    n = packed.shape[0]
    assert packed.shape[1] * f >= k_orig and k == k_orig

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bn), 1, bk // f)
    sp = _pad_to(scale.astype(jnp.float32), 0, bn)
    mp, kp = xp.shape
    np_, kpp = wp.shape
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_qmatmul_f32_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // f), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]


def qmatmul_f32_blockscale(x: jax.Array, packed: jax.Array,
                           scales: jax.Array, *, bits: int, k_orig: int,
                           block: int = 32, bm: int = 128, bn: int = 128,
                           bk: int = 512, interpret: bool = False
                           ) -> jax.Array:
    """x (M, K) float @ packed (N, K/f) uint8 with per-(N, K/block) scales.

    The wire-encoded page form (packed intN levels + per-block scales)
    consumed directly — the At-MRAM expansion happens adjacent to the MXU
    with the *block* scale granularity of the page codec, so a cold page
    handed to compute run-quantized skips the host-side decode entirely.
    ``block`` must divide ``bk`` so scale groups align with reduction
    blocks; K tails shorter than a block are safe because the padded x
    columns are zero.
    """
    f = 8 // bits
    assert bk % f == 0 and bk % block == 0
    m, k = x.shape
    n = packed.shape[0]
    assert packed.shape[1] * f >= k_orig and k == k_orig
    assert scales.shape == (n, -(-k_orig // block))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bn), 1, bk // f)
    kp = xp.shape[1]
    sp = _pad_to(scales.astype(jnp.float32), 0, bn)
    sp = jnp.pad(sp, ((0, 0), (0, kp // block - sp.shape[1])))
    mp = xp.shape[0]
    np_ = wp.shape[0]
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_qmatmul_f32_blockscale_kernel, bits=bits,
                          block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // f), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // block), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]


def qmatmul_int8(x_q: jax.Array, packed: jax.Array, mult: jax.Array,
                 bias: jax.Array, *, bits: int, k_orig: int,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = False) -> jax.Array:
    """uint8 activations (M, K) @ packed weights -> requantized uint8 (M, N).

    ``mult`` is the folded float per-channel rescale (w_scale*in_scale/out_scale),
    ``bias`` the folded int32 per-channel bias (see core.quantize.fold_requant).
    """
    f = 8 // bits
    assert bk % f == 0
    m, k = x_q.shape
    n = packed.shape[0]

    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bn), 1, bk // f)
    multp = _pad_to(mult.astype(jnp.float32), 0, bn)
    biasp = _pad_to(bias.astype(jnp.int32), 0, bn)
    mp, kp = xp.shape
    np_ = wp.shape[0]
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_qmatmul_int8_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // f), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xp, wp, multp, biasp)
    return out[:m, :n]

"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

The LM serving cells (prefill_32k) are the attention hot-spot of the
assigned architectures; a 32k x 32k score matrix cannot be materialized in
HBM, so prefill runs a blocked kernel whose working set is VMEM-resident —
the same "keep the hot operand next to the compute unit" discipline as the
At-MRAM weight path.

Grid: (batch*heads, q blocks, kv blocks), kv innermost; running max / sum /
accumulator live in VMEM scratch across kv steps (output-stationary).
Supports causal masking and sliding windows (hymba).  Block-level early-out
skips fully-masked kv blocks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, nk: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (decode offset: queries sit at the end of the kv seq)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    q = q_ref[0].astype(jnp.float32)               # (bq, d)
    k = k_ref[0].astype(jnp.float32)               # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = kpos < sk                               # padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    window: Optional[int] = None,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, D), k/v (B, Sk, D) -> (B, Sq, D).  B folds batch*heads."""
    b, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, sk)
    qpad = (-sq) % bq
    kpad = (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, qpad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kpad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kpad), (0, 0)))
    nq = (sq + qpad) // bq
    nk = (sk + kpad) // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, sq=sq, sk=sk),
        grid=(b, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq + qpad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]

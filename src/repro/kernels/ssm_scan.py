"""Fused selective-scan (Mamba-1) Pallas TPU kernel.

EXPERIMENTS.md §Perf cell B identifies mamba's 16x state expansion as the
dominant memory term of the SSM/hybrid cells: the pure-JAX chunked scan
materializes the (B, T, d_inner, N) discretized tensors in HBM on every
associative-scan pass (log2(chunk) passes, x3 with remat+backward).

This kernel keeps the expansion entirely in VMEM:

  grid = (batch, d_inner blocks, sequence chunks)   [chunks innermost]
  scratch: h (di_blk, N) f32 — carried across the chunk axis
  per chunk: read x/dt (chunk, di_blk) + B/C (chunk, N) from HBM,
             discretize + associative-scan + output IN VMEM,
             write y (chunk, di_blk) back.

HBM traffic per token: x, dt, y (3·di) + B, C (2·N) bytes — the N-fold
expansion never leaves VMEM, exactly the At-Memory discipline the paper
applies to weights, applied here to the SSM state stream.  Per-chunk VMEM
footprint: chunk x di_blk x N x 4 B (default 256x128x16 = 2 MiB).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, h_ref, *,
                 n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)           # (T, dib)
    dt = dt_ref[0].astype(jnp.float32)         # (T, dib)
    A = a_ref[...].astype(jnp.float32)         # (dib, N)
    B = b_ref[0].astype(jnp.float32)           # (T, N)
    C = c_ref[0].astype(jnp.float32)           # (T, N)

    dA = jnp.exp(dt[:, :, None] * A[None])                   # (T, dib, N)
    dBx = dt[:, :, None] * B[:, None, :] * x[:, :, None]     # (T, dib, N)

    def comb(l, r):
        la, lb = l
        ra, rb = r
        return la * ra, ra * lb + rb

    aa, bb = jax.lax.associative_scan(comb, (dA, dBx), axis=0)
    h_all = aa * h_ref[...][None] + bb                       # (T, dib, N)
    h_ref[...] = h_all[-1]

    y = jnp.sum(h_all * C[:, None, :], axis=-1)              # (T, dib)
    y = y + x * d_ref[...][None, :]
    o_ref[0] = y.astype(o_ref.dtype)


def selective_scan_fused(x: jax.Array, dt: jax.Array, A: jax.Array,
                         B: jax.Array, C: jax.Array, D: jax.Array, *,
                         chunk: int = 256, di_block: int = 128,
                         interpret: bool = False) -> jax.Array:
    """x, dt: (Bz, S, Di); A: (Di, N); B, C: (Bz, S, N); D: (Di,) -> y.

    Zero initial state (the train/prefill case); S padded to chunk, Di to
    di_block.
    """
    bsz, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    di_block = min(di_block, di)
    spad = (-s) % chunk
    dpad = (-di) % di_block
    if spad or dpad:
        x = jnp.pad(x, ((0, 0), (0, spad), (0, dpad)))
        dt = jnp.pad(dt, ((0, 0), (0, spad), (0, dpad)))
        B = jnp.pad(B, ((0, 0), (0, spad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, spad), (0, 0)))
    if dpad:
        A = jnp.pad(A, ((0, dpad), (0, 0)))
        D = jnp.pad(D, ((0, dpad),))
    n_chunks = (s + spad) // chunk
    n_di = (di + dpad) // di_block

    out = pl.pallas_call(
        functools.partial(_scan_kernel, n_chunks=n_chunks),
        grid=(bsz, n_di, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((di_block, n), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((di_block,), lambda b, d, c: (d,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, s + spad, di + dpad), x.dtype),
        scratch_shapes=[pltpu.VMEM((di_block, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return out[:, :s, :di]


def hbm_bytes_per_token(di: int, n: int, itemsize: int = 2) -> Tuple[int, int]:
    """(fused, unfused) HBM bytes per token per layer — the §Perf estimate.

    Unfused (pure-JAX chunked scan): the (di, N) expansion crosses HBM
    ~2x per associative-scan pass (log2(chunk)=8 passes) plus x/dt/B/C/y.
    Fused: x, dt, y (3·di) + B, C (2·N) only.
    """
    fused = (3 * di + 2 * n) * itemsize
    passes = 8
    unfused = (3 * di + 2 * n) * itemsize + 2 * passes * di * n * 4
    return fused, unfused

"""Public jit'd wrappers for the Pallas kernels, with mode dispatch.

Every op takes ``mode``:
  * "pallas"     — compile the Pallas kernel for TPU (the deployment path)
  * "interpret"  — run the Pallas kernel body in the Python interpreter on
                   CPU (correctness validation in this container)
  * "xla"        — pure-jnp math of the same op (the ref oracle), used by
                   the multi-pod dry-run so GSPMD sees plain HLO.  The packed
                   weight layout (and therefore the HBM byte accounting that
                   the roofline reads) is identical in all three modes.

Weight-prep helpers define the single canonical packed layout shared by
kernels, oracles and the WeightStore.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing, quantize
from repro.kernels import ref as _ref
from repro.kernels import qmatmul as _qmm
from repro.kernels import neureka_conv as _nkc
from repro.kernels import flash_attention as _fa

Mode = str
DEFAULT_MODE = "xla"


def _check_mode(mode: Mode) -> Mode:
    if mode not in ("pallas", "interpret", "xla"):
        raise ValueError(f"unknown kernel mode {mode!r}")
    return mode


# -- weight preparation (the "MRAM programming" layouts) ---------------------

def prep_linear(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """(out, in) float -> (packed (out, in/f) uint8, scale (out,))."""
    qt = quantize.quantize_weights(w, bits, channel_axis=0)
    return packing.pack(qt.values, bits), qt.scale


def prep_conv3x3(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """(out, 3, 3, in) float -> (packed (out,3,3,in/f), scale (out,))."""
    qt = quantize.quantize_weights(w, bits, channel_axis=0)
    return packing.pack(qt.values, bits), qt.scale


def prep_dw3x3(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """(c, 3, 3) float -> (packed (c, ceil(9/f)), scale (c,))."""
    qt = quantize.quantize_weights(w.reshape(w.shape[0], 9), bits, channel_axis=0)
    return packing.pack(qt.values, bits), qt.scale


# -- ops ---------------------------------------------------------------------

def quant_matmul(x: jax.Array, packed: jax.Array, scale: jax.Array, *,
                 bits: int, k_orig: int, mode: Mode = DEFAULT_MODE,
                 bm: int = 128, bn: int = 128, bk: int = 512) -> jax.Array:
    """Float activations x packed weights -> f32.  x may have leading dims."""
    _check_mode(mode)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "xla":
        out = _ref.qmatmul_f32(x2, packed, scale, bits=bits, k_orig=k_orig)
    else:
        out = _qmm.qmatmul_f32(x2, packed, scale, bits=bits, k_orig=k_orig,
                               bm=bm, bn=bn, bk=bk,
                               interpret=(mode == "interpret"))
    return out.reshape(*lead, -1)


def quant_matmul_blockscale(x: jax.Array, packed: jax.Array,
                            scales: jax.Array, *, bits: int, k_orig: int,
                            block: int = 32, mode: Mode = DEFAULT_MODE,
                            bm: int = 128, bn: int = 128, bk: int = 512
                            ) -> jax.Array:
    """Float activations x *wire-form* packed weights -> f32.

    The page codec's blockwise form (packed intN levels + per-(row,
    ``block``) f32 scales) consumed directly — the serving fast path for
    int8-encoded cold pages that skip the host-side fetch decode
    (:func:`repro.core.placement.wire_served_bits`).  x may have leading
    dims."""
    _check_mode(mode)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "xla":
        out = _ref.qmatmul_f32_blockscale(x2, packed, scales, bits=bits,
                                          k_orig=k_orig, block=block)
    else:
        out = _qmm.qmatmul_f32_blockscale(x2, packed, scales, bits=bits,
                                          k_orig=k_orig, block=block,
                                          bm=bm, bn=bn, bk=bk,
                                          interpret=(mode == "interpret"))
    return out.reshape(*lead, -1)


def quant_matmul_int8(x_q: jax.Array, packed: jax.Array, mult: jax.Array,
                      bias: jax.Array, *, bits: int, k_orig: int,
                      mode: Mode = DEFAULT_MODE,
                      bm: int = 128, bn: int = 128, bk: int = 512) -> jax.Array:
    _check_mode(mode)
    lead = x_q.shape[:-1]
    x2 = x_q.reshape(-1, x_q.shape[-1])
    if mode == "xla":
        out = _ref.qmatmul_int8(x2, packed, mult, bias, bits=bits, k_orig=k_orig)
    else:
        out = _qmm.qmatmul_int8(x2, packed, mult, bias, bits=bits,
                                k_orig=k_orig, bm=bm, bn=bn, bk=bk,
                                interpret=(mode == "interpret"))
    return out.reshape(*lead, -1)


def neureka_conv2d(x: jax.Array, packed: jax.Array, mult: jax.Array,
                   bias: jax.Array, *, op: str, bits: int, cin: int,
                   stride: int = 1, mode: Mode = DEFAULT_MODE) -> jax.Array:
    """One N-EUREKA job: op in {dense3x3, dw3x3, pw1x1}; x (H, W, C) uint8."""
    _check_mode(mode)
    interp = mode == "interpret"
    if op == "dense3x3":
        if mode == "xla":
            return _ref.conv3x3_dense(x, packed, mult, bias, bits=bits,
                                      cin=cin, stride=stride)
        return _nkc.conv3x3_dense(x, packed, mult, bias, bits=bits, cin=cin,
                                  stride=stride, interpret=interp)
    if op == "dw3x3":
        if mode == "xla":
            return _ref.conv3x3_dw(x, packed, mult, bias, bits=bits,
                                   stride=stride)
        return _nkc.conv3x3_dw(x, packed, mult, bias, bits=bits,
                               stride=stride, interpret=interp)
    if op == "pw1x1":
        if mode == "xla":
            return _ref.conv1x1(x, packed, mult, bias, bits=bits, cin=cin,
                                stride=stride)
        return _nkc.conv1x1(x, packed, mult, bias, bits=bits, cin=cin,
                            stride=stride, interpret=interp)
    raise ValueError(f"unknown N-EUREKA op {op!r}")


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, scale: Optional[float] = None,
              window: Optional[int] = None, mode: Mode = DEFAULT_MODE,
              bq: int = 256, bk: int = 256) -> jax.Array:
    """(B, S, D)-shaped attention (B folds batch*heads)."""
    _check_mode(mode)
    if mode == "xla":
        return _ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                    window=window)
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               window=window, bq=bq, bk=bk,
                               interpret=(mode == "interpret"))

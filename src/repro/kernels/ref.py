"""Pure-jnp oracles for every Pallas kernel (no pallas imports).

Each oracle computes the *same math* as its kernel (including the float
formulation of the NORMQUANT requant) so integer paths match bit-exactly and
float paths match to accumulation-order tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing


def _requant_f32(acc: jax.Array, mult: jax.Array, bias: jax.Array) -> jax.Array:
    y = jnp.round(acc.astype(jnp.float32) * mult) + bias.astype(jnp.float32)
    return jnp.clip(y, 0.0, 255.0).astype(jnp.uint8)


def qmatmul_f32(x: jax.Array, packed: jax.Array, scale: jax.Array, *,
                bits: int, k_orig: int) -> jax.Array:
    w = packing.unpack(packed, bits, k_orig).astype(jnp.float32)
    w = w * scale[:, None].astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w.T)


def qmatmul_f32_blockscale(x: jax.Array, packed: jax.Array,
                           scales: jax.Array, *, bits: int, k_orig: int,
                           block: int = 32) -> jax.Array:
    """Wire-form matmul oracle: x @ dequantize_blockwise(levels, scales)^T.

    Same math as the Pallas blockscale kernel — intN levels expanded with
    per-(row, block) scales inside the reduction — so a cold page served
    straight from its wire encoding needs no host-side decode."""
    levels = packing.unpack(packed, bits, k_orig).astype(jnp.float32)
    n, k = levels.shape
    nblk = scales.shape[1]
    lp = jnp.pad(levels, ((0, 0), (0, nblk * block - k)))
    w = (lp.reshape(n, nblk, block)
         * scales[:, :, None].astype(jnp.float32)).reshape(n, nblk * block)
    return jnp.matmul(x.astype(jnp.float32), w[:, :k].T)


def qmatmul_int8(x_q: jax.Array, packed: jax.Array, mult: jax.Array,
                 bias: jax.Array, *, bits: int, k_orig: int) -> jax.Array:
    w = packing.unpack(packed, bits, k_orig).astype(jnp.int32)
    acc = jnp.matmul(x_q.astype(jnp.int32), w.T,
                     preferred_element_type=jnp.int32)
    return _requant_f32(acc, mult[None, :], bias[None, :])


def conv3x3_dense(x: jax.Array, packed: jax.Array, mult: jax.Array,
                  bias: jax.Array, *, bits: int, cin: int,
                  stride: int = 1) -> jax.Array:
    # packed layout: (Cout, 3, 3, Cin/f) — packed per tap along Cin
    cout = packed.shape[0]
    w = packing.unpack(packed, bits, cin).astype(jnp.int32)  # (Cout,3,3,Cin)
    h, w_, c = x.shape
    ho, wo = -(-h // stride), -(-w_ // stride)
    hpad = (ho - 1) * stride + 3 - h - 1
    wpad = (wo - 1) * stride + 3 - w_ - 1
    xp = jnp.pad(x.astype(jnp.int32), ((1, max(hpad, 1)), (1, max(wpad, 1)), (0, 0)))
    acc = jnp.zeros((ho, wo, cout), jnp.int32)
    for i in range(3):
        for j in range(3):
            patch = jax.lax.slice(
                xp, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (stride, stride, 1))
            acc = acc + jnp.einsum("hwc,oc->hwo", patch, w[:, i, j, :],
                                   preferred_element_type=jnp.int32)
    return _requant_f32(acc, mult[None, None, :], bias[None, None, :])


def conv3x3_dw(x: jax.Array, packed: jax.Array, mult: jax.Array,
               bias: jax.Array, *, bits: int, stride: int = 1) -> jax.Array:
    c = x.shape[-1]
    w = packing.unpack(packed, bits, 9).astype(jnp.int32)    # (C, 9)
    h, w_, _ = x.shape
    ho, wo = -(-h // stride), -(-w_ // stride)
    hpad = (ho - 1) * stride + 3 - h - 1
    wpad = (wo - 1) * stride + 3 - w_ - 1
    xp = jnp.pad(x.astype(jnp.int32), ((1, max(hpad, 1)), (1, max(wpad, 1)), (0, 0)))
    acc = jnp.zeros((ho, wo, c), jnp.int32)
    for i in range(3):
        for j in range(3):
            patch = jax.lax.slice(
                xp, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (stride, stride, 1))
            acc = acc + patch * w[:, i * 3 + j][None, None, :]
    return _requant_f32(acc, mult[None, None, :], bias[None, None, :])


def conv1x1(x: jax.Array, packed: jax.Array, mult: jax.Array, bias: jax.Array,
            *, bits: int, cin: int, stride: int = 1) -> jax.Array:
    if stride != 1:
        x = x[::stride, ::stride, :]
    h, w_, c = x.shape
    out = qmatmul_int8(x.reshape(h * w_, c), packed, mult, bias,
                       bits=bits, k_orig=cin)
    return out.reshape(h, w_, -1)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None) -> jax.Array:
    """Naive attention oracle.  q,k,v: (..., S, D) with leading batch dims."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[-2], k.shape[-2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32)).astype(q.dtype)

"""N-EUREKA convolution engine as Pallas TPU kernels.

Implements exactly the three operators the silicon supports (paper §II-C):
3x3 dense, 3x3 depthwise and 1x1 dense convolutions with 8-bit (uint8)
activations, 2-8-bit weights and the per-channel NORMQUANT requantization.
Layout is HWC, like the accelerator's L1 activation layout; weights are
packed along the reduction axis (the MRAM stream order).

Hardware adaptation notes (see DESIGN.md §2):
  * N-EUREKA is output-stationary with 6x6 PEs over 8x8 input tiles and
    28-channel input chunks (bandwidth-limited).  The TPU mapping keeps the
    output-stationary reduction (accumulators in VMEM scratch across the
    input-channel grid axis) but uses MXU-aligned channel blocks; spatial
    tiles are row-strips of the feature map, which at XR feature-map sizes
    fit VMEM whole.
  * Bit-serial weight arithmetic becomes sub-byte *packed streaming*: HBM
    traffic scales with the weight bit-width exactly as MRAM cycles do.
  * Strides 1 and 2 are supported (MobileNet-V2 needs stride 2); striding is
    applied when gathering the im2col view inside the kernel.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.qmatmul import _unpack_block, qmatmul_int8


def _requant_f32(acc: jax.Array, mult: jax.Array, bias: jax.Array) -> jax.Array:
    """NORMQUANT projection: int32 acc -> uint8 (float-rescale formulation)."""
    y = jnp.round(acc.astype(jnp.float32) * mult) + bias.astype(jnp.float32)
    return jnp.clip(y, 0.0, 255.0).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# 3x3 dense:  out[h, w, co] = sum_{i,j,ci} x[s*h+i, s*w+j, ci] * W[co, i, j, ci]
# Grid: (cout blocks, cin blocks); the padded input strip stays whole in VMEM
# (the INPUTBUFFER analogue); cin is the innermost (reduction) axis.
# ---------------------------------------------------------------------------

def _dense3x3_kernel(x_ref, wp_ref, mult_ref, bias_ref, o_ref, acc_ref, *,
                     bits: int, n_ci: int, stride: int, ho: int, wo: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)               # (Hp, Wp, bci)
    bci = x.shape[-1]
    # im2col with stride: (ho*wo, 9*bci) — the DISPATCHINGNETWORK view
    cols = []
    for i in range(3):
        for j in range(3):
            patch = jax.lax.slice(
                x, (i, j, 0), (i + (ho - 1) * stride + 1,
                               j + (wo - 1) * stride + 1, bci),
                (stride, stride, 1))
            cols.append(patch.reshape(ho * wo, bci))
    xm = jnp.concatenate(cols, axis=-1)            # (ho*wo, 9*bci)

    w = _unpack_block(wp_ref[...].reshape(wp_ref.shape[0], -1), bits)
    w = w[:, : 9 * bci]                            # (bco, 9*bci)
    acc_ref[...] += jax.lax.dot_general(
        xm, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ci == n_ci - 1)
    def _requant():
        o_ref[...] = _requant_f32(
            acc_ref[...], mult_ref[...][None, :], bias_ref[...][None, :])


def conv3x3_dense(x: jax.Array, packed: jax.Array, mult: jax.Array,
                  bias: jax.Array, *, bits: int, cin: int, stride: int = 1,
                  bco: int = 32, bci: int = 32,
                  interpret: bool = False) -> jax.Array:
    """x (H, W, Cin) uint8, packed (Cout, 3, 3, Cin/f) -> (Ho, Wo, Cout) uint8.

    'same' padding for stride 1; for stride 2 output is ceil(H/2) (pad=1).
    """
    f = 8 // bits
    h, w_, c = x.shape
    cout = packed.shape[0]
    assert c == cin
    ho = -(-h // stride)
    wo = -(-w_ // stride)

    # spatial halo pad + channel pad to block multiple
    cpad = (-c) % bci
    hpad = (ho - 1) * stride + 3 - h - 1
    wpad = (wo - 1) * stride + 3 - w_ - 1
    xp = jnp.pad(x, ((1, max(hpad, 1)), (1, max(wpad, 1)), (0, cpad)))
    # weights: (Cout, 3, 3, Cin/f) -> pad Cout and Cin(packed) to blocks
    copad = (-cout) % bco
    wp = jnp.pad(packed, ((0, copad), (0, 0), (0, 0), (0, (cpad // f) if cpad else 0)))
    # reorder so the packed reduction axis blocks as (3,3,bci/f) contiguous
    wp = wp.reshape(cout + copad, 9, -1)
    multp = jnp.pad(mult.astype(jnp.float32), (0, copad))
    biasp = jnp.pad(bias.astype(jnp.int32), (0, copad))

    n_ci = (c + cpad) // bci
    n_co = (cout + copad) // bco
    hp, wpd = xp.shape[0], xp.shape[1]

    out = pl.pallas_call(
        functools.partial(_dense3x3_kernel, bits=bits, n_ci=n_ci,
                          stride=stride, ho=ho, wo=wo),
        grid=(n_co, n_ci),
        in_specs=[
            pl.BlockSpec((hp, wpd, bci), lambda co, ci: (0, 0, ci)),
            pl.BlockSpec((bco, 9, bci // f), lambda co, ci: (co, 0, ci)),
            pl.BlockSpec((bco,), lambda co, ci: (co,)),
            pl.BlockSpec((bco,), lambda co, ci: (co,)),
        ],
        out_specs=pl.BlockSpec((ho * wo, bco), lambda co, ci: (0, co)),
        out_shape=jax.ShapeDtypeStruct((ho * wo, cout + copad), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((ho * wo, bco), jnp.int32)],
        interpret=interpret,
    )(xp, wp, multp, biasp)
    return out[:, :cout].reshape(ho, wo, cout)


# ---------------------------------------------------------------------------
# 3x3 depthwise: out[h, w, c] = sum_{i,j} x[s*h+i, s*w+j, c] * W[c, i, j]
# Bit-serial in silicon with parallel accumulator update; on TPU a VPU
# (elementwise) kernel over channel blocks.
# ---------------------------------------------------------------------------

def _dw3x3_kernel(x_ref, wp_ref, mult_ref, bias_ref, o_ref, *,
                  bits: int, stride: int, ho: int, wo: int):
    x = x_ref[...].astype(jnp.int32)               # (Hp, Wp, bc)
    bc = x.shape[-1]
    w = _unpack_block(wp_ref[...], bits)[:, :9]    # (bc, 9)
    acc = jnp.zeros((ho, wo, bc), jnp.int32)
    for i in range(3):
        for j in range(3):
            patch = jax.lax.slice(
                x, (i, j, 0), (i + (ho - 1) * stride + 1,
                               j + (wo - 1) * stride + 1, bc),
                (stride, stride, 1))
            acc = acc + patch * w[:, i * 3 + j][None, None, :]
    o_ref[...] = _requant_f32(acc, mult_ref[...][None, None, :],
                              bias_ref[...][None, None, :])


def conv3x3_dw(x: jax.Array, packed: jax.Array, mult: jax.Array,
               bias: jax.Array, *, bits: int, stride: int = 1, bc: int = 32,
               interpret: bool = False) -> jax.Array:
    """Depthwise 3x3; packed (C, ceil(9/f)) uint8 along the 9-tap axis."""
    f = 8 // bits
    h, w_, c = x.shape
    ho = -(-h // stride)
    wo = -(-w_ // stride)
    cpad = (-c) % bc
    hpad = (ho - 1) * stride + 3 - h - 1
    wpad = (wo - 1) * stride + 3 - w_ - 1
    xp = jnp.pad(x, ((1, max(hpad, 1)), (1, max(wpad, 1)), (0, cpad)))
    wp = jnp.pad(packed, ((0, cpad), (0, 0)))
    multp = jnp.pad(mult.astype(jnp.float32), (0, cpad))
    biasp = jnp.pad(bias.astype(jnp.int32), (0, cpad))
    hp, wpd = xp.shape[0], xp.shape[1]
    kp = wp.shape[1]

    out = pl.pallas_call(
        functools.partial(_dw3x3_kernel, bits=bits, stride=stride, ho=ho, wo=wo),
        grid=((c + cpad) // bc,),
        in_specs=[
            pl.BlockSpec((hp, wpd, bc), lambda cb: (0, 0, cb)),
            pl.BlockSpec((bc, kp), lambda cb: (cb, 0)),
            pl.BlockSpec((bc,), lambda cb: (cb,)),
            pl.BlockSpec((bc,), lambda cb: (cb,)),
        ],
        out_specs=pl.BlockSpec((ho, wo, bc), lambda cb: (0, 0, cb)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c + cpad), jnp.uint8),
        interpret=interpret,
    )(xp, wp, multp, biasp)
    return out[:, :, :c]


# ---------------------------------------------------------------------------
# 1x1 dense (pointwise): a channel matmul — runs on the integer qmatmul
# kernel (the silicon reuses the same PEs in bit-parallel mode).
# ---------------------------------------------------------------------------

def conv1x1(x: jax.Array, packed: jax.Array, mult: jax.Array, bias: jax.Array,
            *, bits: int, cin: int, stride: int = 1,
            bm: int = 256, bn: int = 128, bk: int = 128,
            interpret: bool = False) -> jax.Array:
    h, w_, c = x.shape
    if stride != 1:
        x = x[::stride, ::stride, :]
        h, w_ = x.shape[0], x.shape[1]
    cout = packed.shape[0]
    xf = x.reshape(h * w_, c)
    bk = min(bk, max(8 // bits, ((c + 7) // 8) * 8))
    out = qmatmul_int8(xf, packed, mult, bias, bits=bits, k_orig=cin,
                       bm=min(bm, ((h * w_ + 7) // 8) * 8), bn=min(bn, ((cout + 7) // 8) * 8),
                       bk=bk, interpret=interpret)
    return out.reshape(h, w_, cout)

"""Multi-model tenancy: N serving engines, one scheduler, one page budget.

Siracusa's headline system claim (§V) is *concurrent* heterogeneous
workloads — hand tracking, gaze and a background assistant sharing ONE
memory hierarchy inside the 10–20 ms frame budget.  Parmar et al. show
that exactly this cross-model memory contention dominates XR SoC
behavior.  This module is that claim's serving-side realization:

  * a :class:`MultiScheduler` multiplexes N :class:`ServingEngine`\\ s
    (e.g. a small dense assistant LM plus an SSM frame-tracker), each
    wrapped in its own per-model :class:`Scheduler` for mechanism, but
    admitted through ONE global EDF-with-priority loop: every tick, all
    tenants' queued requests are sorted together (priority class first,
    earliest absolute deadline within a class) and admitted in that order
    into their own model's free batch slots — a 5 ms-deadline tracker
    request outranks every queued assistant request, whatever model it
    belongs to;
  * all models' cold pages flow through ONE
    :class:`~repro.core.paging.SharedPagePool` under a single
    device-bytes budget: each tenant's ``attach_paging`` *joins* the pool
    instead of constructing a private store, cross-model page eviction is
    the pool's call, and per-model swap/miss/pool-hit/evict/stall
    counters expose the contention (and match the static
    :func:`~repro.core.paging.shared_pass_counters` prediction, because
    tenants stream sequentially per tick);
  * per-model deadline accounting lands in the
    ``repro.serving.metrics/v9`` multi shape (per-model sections plus the
    shared pool's contention stats and the exposed/hidden paging-stall
    split) via :func:`~repro.serving.metrics.multi_summary`;
  * the tick loop is the async paging **software pipeline**: per tick,
    every pending tenant fences the page pass begun last tick, then (in
    registration order) begins the next tick's stream, then computes —
    the tenants' weight I/O overlaps the whole tick's compute while the
    pool's serialized fetch worker keeps the pass order, and therefore
    every counter, identical to the synchronous schedule
    (``async_io=False``).

Each tenant's tokens are bit-exact versus serving that model alone on a
private pager: the pool changes *which* fetches cost a host->device swap,
never the bytes the jitted step consumes.

Typical use::

    pool = SharedPagePool(budget_bytes=4 << 20)
    ms = MultiScheduler(pool=pool)
    ms.add_model("assistant", assistant_engine, prefill_chunk=16)
    ms.add_model("tracker", tracker_engine)
    ms.add_stream("tracker", "frames", priority=2, deadline_ms=15.0)
    ms.submit("tracker", Request(uid=0, prompt=p), stream="frames")
    done = ms.run_until_done()
    print(ms.to_json())
"""

from __future__ import annotations

import itertools
import json
import time
from typing import Dict, List, Optional, Tuple

from repro.core.faults import FaultsArg, PageFetchTimeout, as_injector
from repro.core.paging import SharedPagePool
from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import multi_summary
from repro.serving.sched import Scheduler, StreamSpec
from repro.serving.trace import Tracer


class MultiScheduler:
    """One EDF-with-priority admission loop over N tenant engines.

    ``pool`` (or ``shared_budget_bytes``, which constructs one) is the
    single device-bytes budget every tenant's cold pages contend for.
    Without either, tenants serve fully resident (no paging is attached).

    ``token_budget`` is the continuous-batching budget shared across ALL
    tenants: every tick one global plan deals it out in admission-key
    order (decode-ready slots first, then prefill chunks), so a tracker
    tenant's 10 ms request draws budget away from the assistant's long
    prefill THIS tick.  ``preemptive`` / ``admission`` forward to every
    tenant scheduler (mid-request slot handover and predicted-miss
    refusal, see :class:`~repro.serving.sched.Scheduler`); the
    submission-sequence counter is shared, so the global admission order
    — and therefore every paging counter — is deterministic."""

    def __init__(self, *, pool: Optional[SharedPagePool] = None,
                 shared_budget_bytes: Optional[int] = None,
                 async_io: bool = True,
                 token_budget: Optional[int] = None,
                 preemptive: bool = False,
                 admission: Optional[str] = None,
                 clock=time.perf_counter,
                 tracer: Optional[Tracer] = None,
                 fetch_timeout_s: Optional[float] = None,
                 faults: FaultsArg = None):
        if pool is not None and shared_budget_bytes is not None:
            raise ValueError("pass either pool= or shared_budget_bytes=, "
                             "not both")
        if pool is None and shared_budget_bytes is not None:
            pool = SharedPagePool(shared_budget_bytes)
        self.pool = pool
        self.async_io = bool(async_io)
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got "
                             f"{token_budget}")
        self.token_budget = token_budget
        self.preemptive = bool(preemptive)
        self.admission = admission
        self.clock = clock
        # multi-wide fault defaults: every tenant added without its own
        # override inherits these (per-model overrides matter because the
        # pool's single serialized worker makes one tenant's stuck fetch
        # delay everyone's -- only the stuck tenant should defer)
        self.fetch_timeout_s = fetch_timeout_s
        self.faults = as_injector(faults)
        self.models: Dict[str, Scheduler] = {}
        self.ticks = 0
        self._seq = itertools.count()      # one submission order, global
        # one tracer across every tenant: each model gets its own track
        # (its registered name), the pool's I/O lands on the shared "io"
        # track, and the global admission pass on "scheduler"
        self.tracer = tracer

    @property
    def pass_log(self) -> List[str]:
        """One entry per member streaming pass in BEGIN (== execution)
        order — the exact ``passes=`` argument ``shared_pass_counters``
        needs.  Owned by the pool, which logs each pass at construction:
        under the async pipeline a tenant's next pass is begun a tick
        before it is fenced, and a tenant going idle then receiving live
        traffic re-enters the rotation out of registration order, so the
        fence order the scheduler sees is NOT always the order the pool
        executed."""
        return [] if self.pool is None else self.pool.pass_log

    # -- tenants --------------------------------------------------------------
    def add_model(self, name: str, engine: ServingEngine, *,
                  prefill_chunk: Optional[int] = None,
                  page_bytes: Optional[int] = None,
                  resident_slots: int = 2,
                  kv_paged: bool = False,
                  kv_block_rows: int = 16,
                  fetch_timeout_s: Optional[float] = None,
                  faults: FaultsArg = None) -> Scheduler:
        """Register a tenant.  When the MultiScheduler owns a shared pool
        and the engine's plan pages, the engine's paging is attached
        JOINED to that pool (an engine arriving with a private pager is
        rejected — a private cache would dodge the shared budget).  With
        ``kv_paged``, the tenant's per-slot KV cache pages through the
        SAME pool budget as everyone's weight pages (member
        ``<name>/kv`` — the one-memory-hierarchy reading of §V), in
        ``kv_block_rows``-row blocks.

        ``fetch_timeout_s`` / ``faults`` override the MultiScheduler-wide
        defaults for THIS tenant only (pass them to give one tenant a
        fetch deadline, or a private fault plan, without touching the
        others)."""
        if name in self.models:
            raise ValueError(f"model {name!r} already registered")
        if self.pool is not None and engine.pager is not None:
            raise ValueError(
                f"model {name!r} already has a private pager; tenants "
                f"of a shared pool must attach through it (pass the "
                f"engine un-attached)")
        if self.pool is not None and engine.kv_table is not None:
            raise ValueError(
                f"model {name!r} already pages its KV cache privately; "
                f"tenants of a shared pool must attach through it")
        # construct the Scheduler first: it validates prefill_chunk, and a
        # failure here must not leave the engine half-joined to the pool
        # (token_budget stays None per tenant — the GLOBAL plan below
        # deals the shared budget out instead)
        if fetch_timeout_s is None:
            fetch_timeout_s = self.fetch_timeout_s
        inj = as_injector(faults) if faults is not None else self.faults
        sched = Scheduler(engine, prefill_chunk=prefill_chunk,
                          async_io=self.async_io, clock=self.clock,
                          preemptive=self.preemptive,
                          admission=self.admission,
                          seq_counter=self._seq,
                          tracer=self.tracer, trace_track=name,
                          fetch_timeout_s=fetch_timeout_s)
        if self.pool is not None:
            from repro.core.placement import packed_sizes
            sizes = packed_sizes(engine.params)
            if engine.plan.paged_bytes(sizes) > 0:
                engine.attach_paging(page_bytes, resident_slots,
                                     pool=self.pool, name=name,
                                     faults=inj)
        if kv_paged and engine.kv_table is None and "kv" in engine.cache:
            # families without a KV cache (pure SSM trackers) simply have
            # no KV state to page — the flag is a no-op for them
            engine.attach_kv_paging(kv_block_rows, pool=self.pool,
                                    name=f"{name}/kv", faults=inj)
        self.models[name] = sched
        return sched

    def model(self, name: str) -> Scheduler:
        return self.models[name]

    def add_stream(self, model: str, name: str, *, priority: int = 0,
                   deadline_ms: Optional[float] = None) -> StreamSpec:
        return self.models[model].add_stream(name, priority=priority,
                                             deadline_ms=deadline_ms)

    def submit(self, model: str, req: Request,
               stream: Optional[str] = None) -> None:
        self.models[model].submit(req, stream=stream)

    # -- the single admission loop -------------------------------------------
    def admission_order(self) -> List[Tuple[str, Request]]:
        """ALL tenants' waiting requests in one service order: priority
        class first, then earliest absolute deadline (EDF), then the
        shared submission sequence — the same key each per-model
        scheduler uses, applied across models."""
        waiting = [(sched._admission_key(req), name, req)
                   for name, sched in self.models.items()
                   for req in sched.queue]
        waiting.sort(key=lambda t: t[0])
        return [(name, req) for _key, name, req in waiting]

    def _admit_global(self) -> None:
        """One global admission pass: every tenant's queue AND preempted
        pool in one key order; each candidate takes a free slot of its
        own model, or (``preemptive``) evicts a strictly-lower-priority
        occupant there.  Preempting here — before the tick's fences —
        defers the victim's KV-drop flush to its tenant's fence, which
        still lands before the usurper's first writeback."""
        for sched in self.models.values():
            sched._adopt_engine_queue()
            if sched.admission is not None:
                sched._admission_control()
        while True:
            cands = [(key, name, kind, obj)
                     for name, sched in self.models.items()
                     for key, kind, obj in sched._candidates()]
            cands.sort(key=lambda t: t[0])
            placed = False
            for _key, name, kind, obj in cands:
                sched = self.models[name]
                free = sched.engine.free_slots()
                if free:
                    sched._place(kind, obj, free[0])
                    placed = True
                    break            # keys are static: rescan continues
                if sched.preemptive:
                    req = obj if kind == "queue" else obj.req
                    slot = sched._preempt_for(req)
                    if slot is not None:
                        sched._preempt_slot(slot)
                        sched._place(kind, obj, slot)
                        placed = True
                        break
                # this tenant is full; later candidates may still admit
            if not placed:
                return

    def _plan_global(self) -> None:
        """Deal the shared ``token_budget`` across ALL tenants' live
        slots in one admission-key order (decode-ready slots cost 1 off
        the top, prefill chunks next) and hand each tenant its slice as
        the tick plan its ``tick_begin``/``tick_compute`` consume."""
        scheds = list(self.models.values())
        if self.token_budget is None:
            for sched in scheds:
                sched._tick_plan = None
                sched._tick_budget_tokens = None
                sched._tick_budget_used = None
            return
        plans: Dict[int, Dict[int, int]] = {id(s): {} for s in scheds}
        used: Dict[int, int] = {
            id(s): sum(1 for r in s.engine.slot_req
                       if r is not None and r.prefill_pos >= len(r.prompt))
            for s in scheds}
        remaining = self.token_budget - sum(used.values())
        prefilling = [(sched, i, r)
                      for sched in scheds
                      for i, r in enumerate(sched.engine.slot_req)
                      if r is not None and r.prefill_pos < len(r.prompt)]
        prefilling.sort(key=lambda t: t[0]._admission_key(t[2]))
        for sched, i, r in prefilling:
            rem = len(r.prompt) - r.prefill_pos
            if sched.engine._bucketed:
                alloc = min(sched.prefill_chunk or rem, rem,
                            max(remaining, 0))
            else:
                alloc = rem if remaining > 0 else 0
            if alloc > 0:
                plans[id(sched)][i] = int(alloc)
                remaining -= alloc
                used[id(sched)] += alloc
        for sched in scheds:
            sched._tick_plan = plans[id(sched)]
            sched._tick_budget_tokens = self.token_budget
            sched._tick_budget_used = used[id(sched)]

    # -- ticks ----------------------------------------------------------------
    @property
    def pending(self) -> bool:
        return any(s.pending for s in self.models.values())

    def tick(self) -> Dict[str, List[Request]]:
        """One tenancy tick, pipelined across tenants: one global
        EDF-with-priority admission pass, then — for every tenant with
        pending work, in registration order — phase 1 fences the page
        pass begun last tick, phase 2 begins the next tick's stream, and
        phase 3 runs this tick's prefill/decode while those streams
        proceed.  Keeping the phases tenant-ordered (all fences, then all
        begins, then all computes) preserves the global A,B,A,B pass
        order of the synchronous loop, which is what keeps the shared
        pool's counters on the static ``shared_pass_counters``
        prediction.  Returns {model: requests finished this tick}."""
        tr = self.tracer
        if tr is None:
            self._admit_global()
        else:
            with tr.span("admit", track="scheduler", tick=self.ticks):
                self._admit_global()
        active = [(name, sched) for name, sched in self.models.items()
                  if sched.pending]
        fenced = []
        for name, sched in active:
            try:
                t0, params = sched.tick_fence()
            except PageFetchTimeout as e:
                # only THIS tenant's tick degrades: its pass stays
                # resumable (futures/accounting intact) and is re-fenced
                # next tick; everyone else proceeds below
                sched.defer_tick(e)
                continue
            fenced.append((name, sched, t0, params))
        for _name, sched, _t0, _params in fenced:
            sched._admit()                 # late engine.submit stragglers
        self._plan_global()                # budget over the final slot set
        for _name, sched, _t0, _params in fenced:
            sched.tick_begin()
        finished: Dict[str, List[Request]] = {}
        for name, sched, t0, params in fenced:
            done = sched.tick_compute(t0, params)
            if done:
                finished[name] = done
        self.ticks += 1
        return finished

    def run_until_done(self, max_ticks: int = 100_000
                       ) -> Dict[str, List[Request]]:
        """Serve until every tenant's queue drains; ``max_ticks`` bounds
        this call, and the return value is {model: requests completed by
        this call}."""
        done: Dict[str, List[Request]] = {}
        ticks = 0
        while self.pending:
            for name, reqs in self.tick().items():
                done.setdefault(name, []).extend(reqs)
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("tenancy loop did not converge")
        return done

    def run_for(self, seconds: float) -> Dict[str, List[Request]]:
        """Serve until the wall budget is spent or every queue drains;
        returns the per-model requests completed by this call."""
        t0 = self.clock()
        done: Dict[str, List[Request]] = {}
        while self.pending and (self.clock() - t0) < seconds:
            for name, reqs in self.tick().items():
                done.setdefault(name, []).extend(reqs)
        return done

    # -- metrics / lifecycle --------------------------------------------------
    def summary(self) -> Dict:
        """The ``repro.serving.metrics/v9`` multi-model document."""
        models = {name: sched.metrics.summary(
                      paging=sched.engine.paging_summary(),
                      trace=sched.trace_summary(),
                      faults=sched.faults_summary())
                  for name, sched in self.models.items()}
        return multi_summary(
            models,
            shared_pool=self.pool.summary() if self.pool else None,
            ticks=self.ticks)

    def to_json(self, **extra) -> str:
        doc = self.summary()
        doc.update(extra)
        return json.dumps(doc, indent=2, sort_keys=False)

    def write(self, path: str, **extra) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json(**extra) + "\n")

    def close(self, wait: bool = True) -> None:
        """Shut every tenant's pager down (through the pool when one is
        shared).  In-flight overlapped passes are cancelled/drained FIRST
        so an early exit cannot leak worker fetches or the pool's
        eviction guard."""
        for sched in self.models.values():
            sched.close()                  # cancel unfenced AsyncPageStream
        if self.pool is not None:
            self.pool.close(wait=wait)
        for sched in self.models.values():
            if sched.engine.pager is not None:
                sched.engine.pager.close(wait=wait)
            if sched.engine.kv_table is not None:
                sched.engine.kv_table.close(wait=wait)

    def __enter__(self) -> "MultiScheduler":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

"""Batched serving engine over the packed At-MRAM weight store.

The paper's deployment story, at LM scale: weights live packed (WeightStore
= the MRAM), the fused dequant path computes, and when the packed model
exceeds the resident budget the layer pages stream host->HBM double-
buffered (core/paging.HostPagedStore) — §II-B2's software-assisted
virtual paging, proactive swaps included.

The engine is a continuous-batching loop:
  * requests join a waiting queue and are admitted into free batch slots;
  * one jitted ``step`` serves the whole batch each tick (prefill for
    fresh slots via right-aligned prompts, decode for the rest);
  * finished sequences free their slot immediately (no drain barrier).

For simplicity prompts are prefilled per-request (prefill_step) into the
slot's cache region; decode runs batched across all active slots.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementPlan, as_plan
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                 top_k: int = 0) -> jax.Array:
    """logits (..., V) -> token ids (...,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """``plan`` is the per-parameter weight placement
    (:class:`~repro.core.placement.PlacementPlan`); the legacy ``engine``
    dict ({"scenario", "mode", "bits"}) is still accepted and is converted
    to a uniform plan.  A mixed plan serves hot parameters over the fused
    At-MRAM path and cold parameters through the background scenarios in
    the SAME jitted step."""

    def __init__(self, cfg: ModelConfig, params: Any, *, batch_slots: int = 4,
                 max_len: int = 512, engine: Optional[Dict] = None,
                 plan: Optional[PlacementPlan] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        if plan is not None and engine is not None:
            raise ValueError("pass either plan= or the legacy engine=, "
                             "not both")
        self.plan = plan if plan is not None else as_plan(engine)
        # kept for backward compatibility with callers poking .engine
        self.engine = self.plan
        self.key = jax.random.PRNGKey(seed)

        self.cache = tfm.init_serve_cache(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(functools.partial(self._decode_impl))
        self._prefill_len_cache: Dict[int, Callable] = {}

    # -- jitted bodies --------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, pos_vec):
        # batched decode with PER-SLOT positions (continuous batching):
        # rope, cache insert and attention masks all take the (B,) vector.
        logits, cache = tfm.step(params, tokens, cache, pos_vec, self.cfg,
                                 engine=self.plan)
        return logits, cache

    def _prefill_for_len(self, s: int):
        if s not in self._prefill_len_cache:
            def impl(params, tokens, cache, slot):
                # single-sequence prefill into one slot: run batch-1 then
                # scatter the new cache rows into the slot index.
                sub = jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, 1),
                    cache)
                logits, sub = tfm.step(params, tokens[None], sub,
                                       jnp.int32(0), self.cfg,
                                       engine=self.plan)
                cache = jax.tree_util.tree_map(
                    lambda c, s_: jax.lax.dynamic_update_slice_in_dim(
                        c, s_.astype(c.dtype), slot, 1),
                    cache, sub)
                return logits[0, -1], cache
            self._prefill_len_cache[s] = jax.jit(impl)
        return self._prefill_len_cache[s]

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.slot_req[i] is None and self.waiting:
                req = self.waiting.pop(0)
                s = len(req.prompt)
                prefill = self._prefill_for_len(s)
                logits, self.cache = prefill(
                    self.params, jnp.asarray(req.prompt), self.cache,
                    jnp.int32(i))
                self.key, sub = jax.random.split(self.key)
                tok = int(sample_token(logits, sub, req.temperature))
                req.generated.append(tok)
                prefix = self.cfg.n_meta_tokens
                self.slot_req[i] = req
                self.slot_pos[i] = s + prefix

    def step(self) -> None:
        """One engine tick: admit, batched decode, retire."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].generated[-1]
        pos_vec = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, pos_vec)
        self.key, sub = jax.random.split(self.key)
        greedy = sample_token(logits[:, -1], sub, temperature=0.0)
        sampled = sample_token(logits[:, -1], sub, temperature=1.0)
        for i in active:
            req = self.slot_req[i]
            tok = greedy[i] if req.temperature == 0.0 else sampled[i]
            req.generated.append(int(tok))
            self.slot_pos[i] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.waiting or any(r is not None for r in self.slot_req)):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving loop did not converge")
        return self.finished

"""Batched serving engine over the packed At-MRAM weight store.

The paper's deployment story, at LM scale: weights live packed (WeightStore
= the MRAM), the fused dequant path computes, and when the packed model
exceeds the resident budget the layer pages stream host->HBM double-
buffered (core/paging.HostPagedStore) — §II-B2's software-assisted
virtual paging, proactive swaps included.

The engine is a continuous-batching loop:
  * requests join a waiting queue and are admitted into free batch slots;
  * prompts prefill in power-of-two **buckets** (left-aligned, padded on
    the right so the causal mask keeps the pads invisible to real tokens)
    — the jit cache stays <= log2(max_len) programs instead of one per
    exact prompt length — and all fresh slots of a tick prefill in ONE
    batched call (gather slots -> batch-k step -> scatter rows back);
  * one jitted ``step`` serves the whole batch each tick (decode for the
    active slots, per-slot sampling at each request's own temperature);
  * finished sequences free their slot immediately (no drain barrier);
  * with :meth:`attach_paging`, the plan's cold parameters live on the
    host and stream device-ward between ticks through the double-buffered
    ``HostPagedStore`` page cache, so a mixed ``plan_for_budget`` plan is
    exercised end-to-end at serve time (swap/miss/stall counters kept).
    The stream can run *overlapped*: :meth:`begin_tick_params` kicks the
    next tick's pass while this tick computes and
    :meth:`fence_tick_params` joins at first use, recording only the
    exposed wait on the critical path (the scheduler's async pipeline);
    :meth:`tick_params` remains the blocking begin+fence wrapper.

The engine owns *mechanism* only.  Policy — deadlines, priorities,
chunked prefill pacing, metrics — lives in
:class:`repro.serving.sched.Scheduler`, which drives the same tick
primitives (``tick_params`` / ``prefill_tick`` / ``decode_tick``).

Bucketed prefill is enabled for the attention families ("dense", "vlm").
SSM state and MoE capacity routing are position-history-dependent, so pad
tokens would perturb real activations there; those families keep the
exact-length single-shot prefill.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementPlan, as_plan
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.serving.trace import now as _now


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                 top_k: int = 0) -> jax.Array:
    """logits (..., V) -> token ids (...,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)


def sample_token_batch(logits: jax.Array, key: jax.Array,
                       temperatures: jax.Array) -> jax.Array:
    """Per-row sampling: logits (B, V) with temperatures (B,).

    Row b is greedy when ``temperatures[b] <= 0`` and otherwise sampled at
    its OWN temperature.  (The old engine computed one greedy and one
    temperature-1.0 draw for the whole batch, silently serving every
    stochastic request at temperature 1.0.)"""
    temps = jnp.asarray(temperatures, jnp.float32)
    greedy = jnp.argmax(logits, axis=-1)
    safe = jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.random.categorical(key, logits / safe, axis=-1)
    return jnp.where(temps <= 0.0, greedy, sampled)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _pow2_floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # deadline-aware scheduling (serving.sched): latency bound in ms from
    # arrival to the last generated token; None = best effort.  priority
    # None defers to the stream's default.
    deadline_ms: Optional[float] = None
    priority: Optional[int] = None
    stream: str = "default"
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # retired because the KV cache ran out (slot_pos hit max_len - 1)
    # before max_new_tokens was reached — such a request got *partial*
    # service, so deadline accounting must not conflate it with natural
    # completion
    truncated: bool = False
    # runtime bookkeeping (stamped by the engine / scheduler)
    prefill_pos: int = 0               # prompt tokens already prefilled
    arrival_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    # continuous batching (serving.sched): monotonic submission sequence
    # (the deterministic admission tie-break), admission-control outcome
    # flags, and how many times this request was preempted mid-service
    seq: Optional[int] = None
    rejected: bool = False             # admission control refused to queue
    degraded: bool = False             # deadline stripped at admission
    preemptions: int = 0


@dataclasses.dataclass
class SlotCheckpoint:
    """Bit-exact resumable snapshot of one preempted batch slot.

    ``kv`` holds the slot's valid cache rows ``[0, valid)`` (host copies;
    the dtype round-trips exactly), ``ssm`` the recurrent state, and the
    request itself carries its chunk frontier (``prefill_pos``) and the
    tokens generated so far.  Restoring scatters these back into any free
    slot; completed KV blocks re-writeback through the normal
    ``sync_kv_tick`` path, so the page-pool event log stays a faithful
    replay input for ``kv_pass_counters``."""
    req: Request
    slot_pos: int
    valid: int                          # valid KV rows at preemption
    kv: Optional[Dict[str, np.ndarray]] = None
    ssm: Optional[Dict[str, np.ndarray]] = None


class ServingEngine:
    """``plan`` is the per-parameter weight placement
    (:class:`~repro.core.placement.PlacementPlan`); the legacy ``engine``
    dict ({"scenario", "mode", "bits"}) is still accepted and is converted
    to a uniform plan.  A mixed plan serves hot parameters over the fused
    At-MRAM path and cold parameters through the background scenarios in
    the SAME jitted step."""

    def __init__(self, cfg: ModelConfig, params: Any, *, batch_slots: int = 4,
                 max_len: int = 512, engine: Optional[Dict] = None,
                 plan: Optional[PlacementPlan] = None, seed: int = 0,
                 prefill_chunk: int = 64):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        if plan is not None and engine is not None:
            raise ValueError("pass either plan= or the legacy engine=, "
                             "not both")
        self.plan = plan if plan is not None else as_plan(engine)
        # kept for backward compatibility with callers poking .engine
        self.engine = self.plan
        self.key = jax.random.PRNGKey(seed)
        # pad-safe bucketing needs pads to be invisible to real tokens:
        # attention families hide them behind the causal mask, and the
        # pure-SSM family masks them into exact state no-ops (dt = 0 at
        # pads — see models/ssm.mamba_mixer).  MoE capacity routing is
        # contended across the flattened batch and hybrid's parallel
        # attn+SSM heads are untested under masking, so those families
        # keep exact-length prefill.
        self._bucketed = cfg.family in ("dense", "vlm", "ssm")
        if prefill_chunk < 1:
            # _next_pow2 maps 0/negative to 1, which would silently serve
            # chunk=1 pacing the caller never asked for
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{prefill_chunk}")
        self.prefill_chunk = _next_pow2(prefill_chunk)

        self.cache = tfm.init_serve_cache(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        # mid-request preemption (serving.sched): every slot handover
        # bumps the slot's generation; a KV streaming pass begun under an
        # older generation must not scatter its (stale) rows over the new
        # occupant — the guard that makes preempt/restore safe while a
        # pass is in flight
        self._slot_gen = np.zeros(batch_slots, np.int64)
        self._kv_begun_gen: Optional[np.ndarray] = None
        self.preempt_count = 0
        self.restore_count = 0

        self._decode = jax.jit(self._decode_impl)
        # keyed by (bucket, add_prefix, kv_span): pow2 buckets x pow2 KV
        # spans = O(log^2 max_len) compiled prefill programs (the ROADMAP
        # KV-span-slicing note — chunks no longer attend the full max_len
        # cache, only the next pow2 >= insert_at + bucket)
        self._prefill_cache: Dict[Tuple[int, bool, Optional[int]],
                                  Callable] = {}

        # §II-B2 live paging (attach_paging).  Stall accounting is split
        # the way the paper's At-MRAM story demands: `exposed` is paging
        # wait that actually blocked a tick, `hidden` is stream time the
        # async pipeline absorbed behind compute.  paging_stall_s keeps
        # its historical name but holds the EXPOSED total (a synchronous
        # run hides nothing, so its numbers read exactly as before).
        self.pager = None
        self.page_resident_slots = 2
        self.paging_stall_s = 0.0
        self.paging_hidden_s = 0.0
        self.last_stall_s = 0.0
        self.last_hidden_s = 0.0
        # measured split of the LAST fenced pass — swap_s (stream wall),
        # window_s (begin->fence compute window), exposed_s, hidden_s —
        # which tests assert against memsys.overlap_stall's closed form
        self.last_overlap: Optional[Dict[str, float]] = None
        self._inflight_pass = None        # AsyncPageStream begun, unfenced
        self._thread_template = None      # (treedef, slots) cache

        # KV-cache paging (attach_kv_paging): the per-slot KV cache flows
        # through the SAME pool budget and the SAME begin/fence overlap
        # as the weight pages — one memory hierarchy, the paper's actual
        # constraint.  kv_stall_s / kv_hidden_s are the KV share of the
        # combined paging_stall_s / paging_hidden_s totals.
        self.kv_table = None
        self._inflight_kv = None          # KVPageStream begun, unfenced
        self.kv_stall_s = 0.0
        self.kv_hidden_s = 0.0
        self.last_kv_overlap: Optional[Dict[str, float]] = None
        self._kv_synced = np.zeros(batch_slots, np.int64)  # blocks on host

        # opt-in chrome-trace hook (set_tracer): None by default, so the
        # un-traced fence/begin path pays one branch and nothing else
        self.tracer = None
        self.trace_track = "serve"

    # -- jitted bodies --------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, pos_vec):
        # batched decode with PER-SLOT positions (continuous batching):
        # rope, cache insert and attention masks all take the (B,) vector.
        logits, cache = tfm.step(params, tokens, cache, pos_vec, self.cfg,
                                 engine=self.plan)
        return logits, cache

    def _prefill_for_bucket(self, bucket: int, add_prefix: bool,
                            kv_span: Optional[int] = None) -> Callable:
        """Batched multi-slot prefill for one (bucket, prefix, kv_span)
        shape: gather the k slot cache rows, slice the KV cache to the
        ``kv_span`` prefix (masked-out keys beyond the span are exact
        no-ops, so attending only the live rows changes FLOPs, never
        values), run a batch-k step at per-slot cache offsets, scatter
        the rows back.  The batch is always padded to the full slot
        count, so the jit cache is keyed by the power-of-two bucket, the
        power-of-two kv span, and (for meta-token models) whether the
        prefix is built — O(log^2 max_len) programs in place of the old
        full-cache O(log)."""
        key = (int(bucket), bool(add_prefix),
               None if kv_span is None else int(kv_span))
        if key not in self._prefill_cache:
            # SSM rows need each row's real-token count so the masked
            # scan treats the bucket pads as state no-ops; attention-only
            # families get pad safety from the causal mask alone and keep
            # the narrower signature
            needs_len = self._bucketed and "ssm" in self.cache

            def impl(params, tokens, cache, slot_idx, pos_vec,
                     lengths=None):
                sub = jax.tree_util.tree_map(
                    lambda c: jnp.take(c, slot_idx, axis=1), cache)
                if kv_span is not None:
                    sub = dict(sub, kv=dict(
                        k=sub["kv"]["k"][:, :, :, :kv_span],
                        v=sub["kv"]["v"][:, :, :, :kv_span]))
                logits, sub = tfm.step(params, tokens, sub, pos_vec,
                                       self.cfg, engine=self.plan,
                                       add_prefix=add_prefix,
                                       lengths=lengths if needs_len
                                       else None)
                out = {}
                for part, c in cache.items():
                    s_part = sub[part]
                    if part == "kv" and kv_span is not None:
                        out[part] = {
                            n: c[n].at[:, slot_idx, :, :kv_span].set(
                                s_part[n].astype(c[n].dtype))
                            for n in ("k", "v")}
                    else:
                        out[part] = jax.tree_util.tree_map(
                            lambda cc, ss: cc.at[:, slot_idx].set(
                                ss.astype(cc.dtype)),
                            c, s_part)
                return logits, out
            self._prefill_cache[key] = jax.jit(impl)
        return self._prefill_cache[key]

    # -- §II-B2: live paged-weight streaming ---------------------------------
    def attach_paging(self, page_bytes: Optional[int] = None,
                      resident_slots: int = 2, *,
                      pool: Optional[Any] = None,
                      name: Optional[str] = None,
                      faults: Optional[Any] = None,
                      wire_serve: bool = False,
                      mesh: Optional[Any] = None,
                      shard_budget_bytes: Optional[int] = None
                      ) -> "ServingEngine":
        """Put the plan's paged parameters behind a
        :class:`~repro.core.paging.HostPagedStore`.

        The plan's resident set is pinned on device once; every cold
        parameter group is evacuated to the host image and re-streamed
        device-ward each tick through the double-buffered page cache
        (``tick_params``).  ``page_bytes`` defaults to the largest cold
        group (page == parameter-group granularity).

        With ``pool`` (a :class:`~repro.core.paging.SharedPagePool`), the
        store JOINS the pool's shared device-bytes budget under ``name``
        instead of assuming a private cache — the multi-model tenancy
        path, where every tenant's cold pages contend for one budget and
        cross-model eviction is the pool's call.

        ``faults`` (a :class:`~repro.core.faults.FaultPlan` or shared
        :class:`~repro.core.faults.FaultInjector`) puts every page fetch
        under seeded fault injection with CRC-verified retry — see
        :mod:`repro.core.faults`.

        ``wire_serve=True`` serves int8-re-encoded cold pages straight
        from their wire form: the fetch skips the host decode, the device
        holds the packed blockwise levels + per-block scales, and
        ``linear`` dispatches those params to the blockscale matmul
        (:func:`repro.core.placement.wire_served_bits`).  Params the
        predicate excludes (fp/identity pages, non-int8 encodings, other
        scenarios) keep the host-decode path unchanged.

        ``mesh`` (a jax Mesh with a "model" axis of size > 1) shards the
        paged store across the mesh's model devices instead: each device
        streams only its shard's pages through its own per-device link
        (:class:`~repro.core.paging.ShardedPagedStore`), the tick's fence
        joins all the per-device streams, and ``shard_budget_bytes`` — if
        given — splits one global byte budget into per-device page pools
        under a :class:`~repro.core.paging.ShardedPoolLedger`.  A mesh
        whose model axis has size 1 falls back to the single-device path
        unchanged.  Mutually exclusive with ``pool`` (the ledger owns the
        per-device pools)."""
        from repro.core.paging import HostPagedStore, ShardedPagedStore, \
            packed_tree_store, thread_packed

        if resident_slots < 1:
            raise ValueError(f"resident_slots must be >= 1, got "
                             f"{resident_slots}")
        if wire_serve:
            # flip the plan BEFORE building the store and template so the
            # jitted model (which reads self.plan at trace time) and the
            # fetch path agree on which params arrive in wire form
            self.plan = self.plan.replace(wire_serve=True)
            self.engine = self.plan
        store = packed_tree_store(self.params, self.plan)
        paged = [n for n in store.params
                 if self.plan.placement_for(n).paged]
        if not paged:
            raise ValueError("plan has no paged parameters; nothing to "
                             "stream — use the engine without paging")
        if page_bytes is None:
            page_bytes = max(store.params[n].nbytes_packed for n in paged)
        mesh_wide = (mesh is not None
                     and "model" in tuple(getattr(mesh, "axis_names", ()))
                     and int(mesh.shape["model"]) > 1)
        if mesh_wide:
            if pool is not None:
                raise ValueError("mesh= and pool= are mutually exclusive: "
                                 "the sharded ledger owns its per-device "
                                 "pools")
            self.pager = ShardedPagedStore(
                store, page_bytes, mesh, plan=self.plan,
                budget_bytes=shard_budget_bytes,
                name=name if name is not None else "default",
                faults=faults)
        else:
            self.pager = HostPagedStore(store, page_bytes, plan=self.plan,
                                        pool=pool,
                                        name=name if name is not None
                                        else "default",
                                        faults=faults)
        self.page_resident_slots = resident_slots
        # repoint the template tree: resident groups at the pager's pinned
        # device copies, cold groups at the HOST image — nothing stays
        # device-resident behind the pager's back.  The template only
        # fixes shapes/dtypes; template_view() presents exactly the
        # leaves a streamed (and, on a mesh, joined) page will fill.
        host_view = self.pager.template_view()
        self.params = thread_packed(self.params,
                                    {**self.pager.resident, **host_view})
        self._build_thread_template(set(host_view))
        if self.tracer is not None:
            self.set_tracer(self.tracer)   # reach the new store/pool
        return self

    def _build_thread_template(self, paged_names) -> None:
        """Pre-flatten the repointed template ONCE: each leaf slot either
        passes through verbatim (resident/pinned) or names the paged
        group + half ("packed"/"scale") a streamed page must fill.  Ticks
        then thread fresh pages by list substitution + unflatten instead
        of re-walking the whole tree with path matching every tick."""
        from repro.core.placement import path_key
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.params)
        slots = []
        for path, leaf in flat:
            key = path_key(path)
            if key.endswith("/packed") and key[:-len("/packed")] in paged_names:
                slots.append(("packed", key[:-len("/packed")]))
            elif key.endswith("/scale") and key[:-len("/scale")] in paged_names:
                slots.append(("scale", key[:-len("/scale")]))
            else:
                slots.append((None, leaf))
        self._thread_template = (treedef, slots)

    def _thread_tick(self, dev: Dict[str, Any]) -> Any:
        """Streamed device pages -> the params tree the jitted step
        consumes, via the cached template (same result as
        ``paging.thread_packed(self.params, dev)``, without the per-tick
        tree rebuild)."""
        treedef, slots = self._thread_template
        leaves = [leaf if kind is None
                  else (dev[leaf].packed if kind == "packed"
                        else dev[leaf].scale)
                  for kind, leaf in slots]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- KV-cache paging through the same pool --------------------------------
    def attach_kv_paging(self, block_rows: int = 16, *,
                         pool: Optional[Any] = None,
                         name: Optional[str] = None,
                         faults: Optional[Any] = None) -> "ServingEngine":
        """Page the per-slot KV cache through the SAME device-bytes
        budget (and the same begin/fence overlap) the weight pages use.

        The preallocated device cache stays the compute buffer — jit
        shapes never change — but the authoritative copy of every
        *completed* ``block_rows``-row block lives in a
        :class:`~repro.core.paging.KVPageTable` host image: blocks are
        written back once when the append-only frontier crosses them,
        and each tick the admitted slots' ``[0, valid)`` spans stream
        host->device through the pool alongside the weight pages (one
        unified eviction domain; pooled blocks re-fetch swap-free).
        With ``pool``, the table JOINS the shared budget under ``name``
        (default ``<weights-name>/kv``); without one it keeps a private
        no-cache stream, re-swapping every block every pass — exactly
        the private ``HostPagedStore`` discipline.

        Attach before serving: the table snapshots the (empty) cache."""
        from repro.core.paging import KVPageTable

        if "kv" not in self.cache:
            raise ValueError(f"family {self.cfg.family!r} has no KV cache "
                             "to page (recurrent state is not paged)")
        if self.kv_table is not None:
            raise ValueError("KV paging already attached")
        if self.waiting or any(r is not None for r in self.slot_req):
            raise ValueError("attach_kv_paging before submitting work: "
                             "the host image snapshots an idle cache")
        if name is None:
            name = (self.pager.name if self.pager is not None
                    else "default") + "/kv"
        self.kv_table = KVPageTable(self.cache["kv"], block_rows=block_rows,
                                    pool=pool, name=name, faults=faults)
        self._kv_synced[:] = 0
        if self.tracer is not None:
            self.set_tracer(self.tracer)   # reach the new table/pool
        return self

    def set_tracer(self, tracer, track: Optional[str] = None
                   ) -> "ServingEngine":
        """Attach (or, with None, detach) a
        :class:`~repro.serving.trace.Tracer` to the engine and every
        paging component it owns — the paged weight store, the KV page
        table, and their shared pool all emit onto the same tracer so
        one trace shows scheduler phases, fence stalls, per-page I/O,
        evictions and pool occupancy together.  ``track`` names this
        engine's rows (the tenancy loop passes the tenant name).
        Re-invoked automatically when paging attaches later."""
        self.tracer = tracer
        if track is not None:
            self.trace_track = track
        if self.pager is not None:
            self.pager.tracer = tracer
            if self.pager.pool is not None:
                self.pager.pool.tracer = tracer
        if self.kv_table is not None:
            self.kv_table.tracer = tracer
            if self.kv_table.pool is not None:
                self.kv_table.pool.tracer = tracer
        return self

    def _kv_valid(self, i: int) -> int:
        """Valid KV rows of slot ``i`` — the admitted request's
        ``[0, slot_pos)`` prefix (during chunked prefill: the prefix plus
        the tokens absorbed so far)."""
        r = self.slot_req[i]
        if r is None or r.prefill_pos == 0:
            return 0
        if r.prefill_pos < len(r.prompt):
            return self.cfg.n_meta_tokens + r.prefill_pos
        return int(self.slot_pos[i])

    def _kv_full_blocks(self) -> Dict[int, int]:
        """{slot: host-synced completed-block count} over the occupied
        slots — the span map one KV streaming pass fetches.  Advertising
        the *synced* count (not the raw frontier) is what keeps a
        just-restored preemption victim safe: its completed blocks live
        only in the device cache until ``sync_kv_tick`` re-writes them
        back, and a fetch of an unsynced block would stream stale host
        rows.  At every begin/fence point of an unpreempted slot the two
        counts are equal (writeback runs at end of tick, before the next
        begin), so this is the same map the frontier would give."""
        out = {}
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            full = int(self._kv_synced[i])
            if full > 0:
                out[i] = full
        return out

    def _scatter_kv(self, blocks: Dict[int, Any]) -> None:
        """Fetched KV pages -> the device cache buffer (rows beyond the
        spans keep whatever was there; the causal/cache-length masks make
        them exact no-ops).  A slot's fetched blocks are always the
        contiguous ``[0, full*block_rows)`` prefix, so they scatter as
        ONE update per slot — each un-jitted ``.at[].set`` copies the
        whole cache buffer, so this is O(slots), not O(pages)."""
        if not blocks:
            return
        k, v = self.cache["kv"]["k"], self.cache["kv"]["v"]
        nb = self.kv_table.n_blocks
        by_slot: Dict[int, List[Any]] = {}
        for page in sorted(blocks):        # slot-major, block-ascending
            slot, _blk = divmod(page, nb)
            by_slot.setdefault(slot, []).append(blocks[page])
        for slot, rows in by_slot.items():
            if self.slot_req[slot] is None:
                continue        # retired mid-pass: rows are dead anyway
            if (self._kv_begun_gen is not None
                    and self._kv_begun_gen[slot] != self._slot_gen[slot]):
                # the slot changed hands (preempt/restore/assign) after
                # the pass was begun: these rows belong to the previous
                # occupant and must not clobber the new one's restored
                # or freshly prefilled cache rows
                continue
            ks = (rows[0]["k"] if len(rows) == 1
                  else jnp.concatenate([r["k"] for r in rows], axis=2))
            vs = (rows[0]["v"] if len(rows) == 1
                  else jnp.concatenate([r["v"] for r in rows], axis=2))
            hi = ks.shape[2]
            k = k.at[:, slot, :, :hi].set(ks.astype(k.dtype))
            v = v.at[:, slot, :, :hi].set(vs.astype(v.dtype))
        self.cache["kv"] = dict(k=k, v=v)

    def sync_kv_tick(self) -> None:
        """End-of-tick writeback: blocks the append-only frontier
        completed this tick move device->host exactly once, making them
        fetchable (and poolable) from the next pass on.  Driven by the
        Scheduler's tick_compute and the legacy step() loop."""
        if self.kv_table is None:
            return
        block = self.kv_table.block_rows
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            full = self._kv_valid(i) // block
            if full > self._kv_synced[i]:
                self.kv_table.writeback(i, int(self._kv_synced[i]), full,
                                        self.cache["kv"])
                self._kv_synced[i] = full

    def begin_tick_params(self) -> None:
        """Kick the overlapped host->device page stream for the NEXT
        fence and return immediately (no-op without paging, or when a
        pass is already in flight).  The fetch loop runs on the pager's
        worker while the caller keeps computing — the §II-B2 proactive
        swap, realized across ticks: tick t's compute hides tick t+1's
        page traffic.  With KV paging attached, the tick's live KV spans
        ride the same overlapped stream (blocks completed after this
        begin are demand-fetched at the fence)."""
        kicked = []
        if self.pager is not None and self._inflight_pass is None:
            self._inflight_pass = self.pager.begin_pass(
                self.page_resident_slots)
            kicked.append("weights")
        if self.kv_table is not None and self._inflight_kv is None:
            self._kv_begun_gen = self._slot_gen.copy()
            self._inflight_kv = self.kv_table.begin_pass(
                self._kv_full_blocks())
            kicked.append("kv")
        if kicked and self.tracer is not None:
            self.tracer.instant("begin_pass", track=self.trace_track,
                                streams="+".join(kicked))

    def fence_tick_params(self, timeout_s: Optional[float] = None) -> Any:
        """The params tree for this tick, fencing at first use.

        Without paging this is just the packed store.  With paging, the
        in-flight pass (begun by :meth:`begin_tick_params`; demand-begun
        here if nothing is in flight — the sync fallback and the cold
        first tick) is joined, the arrived pages are threaded through the
        cached template, and the stall is split into the *exposed* wait
        (time this call actually blocked) and the *hidden* overlap.  The
        fused step needs every layer resident at once (the stacked scan),
        so the page cache models the *traffic* (swap/miss counters, stall
        time) while the tick's working set is materialized in full — the
        TPU-native reading of the two live MRAM pages.

        ``timeout_s`` bounds the tick's I/O wait: on expiry the fence
        raises :class:`~repro.core.faults.PageFetchTimeout` and the
        in-flight streams stay owned by the engine, untouched — no page
        is threaded, no stall is accounted, and the next call resumes
        the SAME passes (stream fences are idempotent), so a scheduler
        can defer the tick instead of stalling the world."""
        self.last_stall_s = 0.0
        self.last_hidden_s = 0.0
        if self.pager is None and self.kv_table is None:
            return self.params
        demand = (self._inflight_pass is None
                  and self._inflight_kv is None)
        if demand:
            self.begin_tick_params()
        ps = self._inflight_pass
        ks = self._inflight_kv
        # fence BOTH streams before consuming either: a timeout raises
        # with the passes still in flight (a fenced stream's result is
        # cached, so the retry re-joins it for free), and the accounting
        # below runs exactly once, on the tick that actually consumes
        dev = ps.fence(timeout_s=timeout_s) if ps is not None else None
        blocks = (ks.fence(self._kv_full_blocks(), timeout_s=timeout_s)
                  if ks is not None else None)
        self._inflight_pass = None
        self._inflight_kv = None
        params = self.params
        if ps is not None:
            self.last_overlap = self._account_fence(
                ps, demand, self.pager.pool, self.pager.name)
            params = self._thread_tick(dev)
        if ks is not None:
            self.last_kv_overlap = self._account_fence(
                ks, demand, self.kv_table.pool, self.kv_table.name,
                kv=True)
            self._scatter_kv(blocks)
            # every in-flight fetch has settled: retired slots' stale
            # pooled blocks can now be dropped without a late fetch
            # resurrecting them
            self.kv_table.flush_drops()
        return params

    def _account_fence(self, ps, demand: bool, pool, name: str,
                       kv: bool = False) -> Dict[str, float]:
        """Book one fenced pass's stall split — ONE copy of the rule for
        both the weight stream and the KV stream (the PR 4
        double-attribution bug class lived in exactly this kind of
        duplicated accounting).  When the pass was demand-begun INSIDE
        this fence (sync tick_params, or the cold first tick), its whole
        begin->fence window was spent blocked here, not in caller
        compute: the full stream wall lands exposed, nothing was
        hidden."""
        exposed, hidden, window = ps.exposed_s, ps.hidden_s, ps.window_s
        if demand:
            exposed, hidden, window = exposed + hidden, 0.0, 0.0
        self.last_stall_s += exposed
        self.last_hidden_s += hidden
        self.paging_stall_s += exposed
        self.paging_hidden_s += hidden
        if kv:
            self.kv_stall_s += exposed
            self.kv_hidden_s += hidden
        if pool is not None:
            pool.add_stall(name, exposed, hidden)
        tr = self.tracer
        if tr is not None:
            # the measured stall split, retro-dated so [hidden][exposed]
            # render as one contiguous swap bar ending at the fence —
            # the spans the reconciliation tests sum against metrics/v8
            stream = "kv" if kv else "weights"
            track = f"{self.trace_track}:stall"
            if hidden > 0.0:
                tr.complete(f"hidden:{stream}", hidden, track=track,
                            end_offset_s=exposed, swap_ms=ps.swap_s * 1e3)
            tr.complete(f"exposed:{stream}", exposed, track=track,
                        demand=demand, window_ms=window * 1e3)
        return dict(swap_s=ps.swap_s, window_s=window,
                    exposed_s=exposed, hidden_s=hidden)

    def cancel_tick_params(self) -> None:
        """Cancel/drain an in-flight pass that will never be fenced
        (early scheduler exit, teardown) without leaking worker fetches
        or the shared pool's eviction guard."""
        if self._inflight_pass is not None:
            self._inflight_pass.close()
            self._inflight_pass = None
        if self._inflight_kv is not None:
            self._inflight_kv.close()
            self._inflight_kv = None

    def tick_params(self) -> Any:
        """Legacy blocking API: begin + fence back to back (the stream's
        full wall time lands exposed, hidden ~ 0 — exactly the old
        synchronous accounting).  Kept as the sync path the async
        pipeline is verified bit-exact against."""
        self.begin_tick_params()
        return self.fence_tick_params()

    def has_tick_after(self, chunk: Optional[int] = None,
                       plan: Optional[Dict[int, int]] = None) -> bool:
        """Will the engine still hold work after ONE more scheduler-paced
        tick (``complete=False`` prefill at ``chunk`` pacing, or at the
        per-slot ``plan`` allocations of the budgeted tick)?

        Drives the pipeline's begin decision: a pass begun with no tick
        left to consume it would stream a whole extra pass and skew the
        swap counters away from ``ticks * pass_counters``.  The predicate
        mirrors the tick's own retirement rules exactly; when in doubt it
        must answer False (a missed overlap costs latency, a phantom
        pass costs determinism)."""
        if self.waiting:
            return True
        prefix = self.cfg.n_meta_tokens
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            remaining = len(r.prompt) - r.prefill_pos
            if remaining > 0:
                if plan is not None:
                    if plan.get(i, 0) <= 0:
                        return True      # unscheduled this tick: the
                                         # frontier survives untouched
                    n, _b, _p, _q = self._chunk_shape(r, plan[i])
                else:
                    n, _bucket, _pfx, _pos = self._chunk_shape(r, chunk)
                if n < remaining:
                    return True          # more prefill chunks after this
                # prefill completes THIS tick — and the same tick's
                # decode_tick already sees it (prefill_pos is bumped
                # before decode runs), so the slot leaves this tick with
                # TWO tokens unless max_new retires it at one
                if (r.max_new_tokens > 2
                        and prefix + len(r.prompt) + 1 < self.max_len - 1):
                    return True
            elif (len(r.generated) + 1 < r.max_new_tokens
                    and self.slot_pos[i] + 1 < self.max_len - 1):
                return True              # survives this decode tick
        return False

    @property
    def swap_count(self) -> int:
        return 0 if self.pager is None else self.pager.swap_count

    @property
    def miss_count(self) -> int:
        return 0 if self.pager is None else self.pager.miss_count

    def paging_summary(self) -> Dict[str, Any]:
        total = self.paging_stall_s + self.paging_hidden_s
        kv = self.kv_table
        return dict(
            swap_count=self.swap_count, miss_count=self.miss_count,
            exposed_s=self.paging_stall_s, hidden_s=self.paging_hidden_s,
            overlap_frac=(self.paging_hidden_s / total) if total > 0 else 0.0,
            stall_s=self.paging_stall_s,       # v2 alias: exposed wait
            n_pages=0 if self.pager is None else len(self.pager.pages),
            # metrics/v8: encoded-pages byte ledger for the WEIGHT page
            # stream — wire = what crossed the link per swap (encoded
            # payload + scales), raw = the fp32-dense equivalent, so
            # wire/raw is the weight-page compression ratio.  The KV
            # stream moves device-format rows (ratio 1.0) and reports
            # through its own pool member / kv_swaps counters.
            bytes_streamed_wire=(0 if self.pager is None
                                 else self.pager.bytes_streamed_wire),
            bytes_streamed_raw=(0 if self.pager is None
                                else self.pager.bytes_streamed_raw),
            # wire-serve: wire bytes that never paid a fetch decode
            # (served straight to the blockscale matmul); 0 unless the
            # engine attached with wire_serve=True
            decode_skipped_bytes=(0 if self.pager is None
                                  else self.pager.decode_skipped_bytes),
            # metrics/v4: the KV share of the same budgeted page stream
            kv_swaps=0 if kv is None else kv.swap_count,
            kv_pool_hits=0 if kv is None else kv.pool_hits,
            kv_writebacks=0 if kv is None else kv.writebacks,
            kv_dropped=0 if kv is None else kv.dropped,
            kv_preempt_drops=0 if kv is None else kv.preempt_drops,
            kv_exposed_s=self.kv_stall_s,
            kv_hidden_s=self.kv_hidden_s,
            kv_block_rows=0 if kv is None else kv.block_rows,
            # metrics/v9: per-device counter rows when the pager is a
            # mesh-sharded store ([] on single-device runs)
            devices=(getattr(self.pager, "device_summaries", lambda: [])()
                     if self.pager is not None else []))

    def faults_summary(self) -> Dict[str, int]:
        """Fault-path counters summed over the engine's paging components
        (weight pager + KV table) — the per-model body of the metrics v8
        ``faults`` section.  The scheduler layers ``deferred_ticks`` on
        top (tick deferral is its decision, not the stores')."""
        from repro.core.faults import merge_fault_counters
        parts = [s.fault_counters for s in (self.pager, self.kv_table)
                 if s is not None]
        return merge_fault_counters(parts)

    # -- slot management ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._check_fits(req)
        if req.arrival_s is None:
            req.arrival_s = _now()
        self.waiting.append(req)

    def _check_fits(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: nothing to condition on (and "
                             "no first token to decode from)")
        if self.cfg.n_meta_tokens and len(req.prompt) < 2:
            # a 1-token prompt routes through the decode path (s==1),
            # which cannot build the meta-token prefix the position
            # accounting assumes — reject rather than serve garbage
            raise ValueError("meta-token models need prompts of >= 2 "
                             "tokens (single-token prefill cannot build "
                             "the prefix)")
        prefix = self.cfg.n_meta_tokens
        if prefix + len(req.prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens (+{prefix} prefix) "
                f"does not fit max_len={self.max_len}")

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def assign(self, req: Request, slot: int) -> None:
        """Bind a request to a batch slot (prefill starts next tick pass)."""
        if self.slot_req[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        self._check_fits(req)
        if req.arrival_s is None:
            req.arrival_s = _now()
        req.prefill_pos = 0
        self._slot_gen[slot] += 1
        if self.kv_table is not None:
            # the previous tenant's pooled blocks were queued for drop at
            # its retirement and flush at the next fence — BEFORE this
            # request's first writeback, so the flush can never zero live
            # data.  Only the sync bookkeeping resets here.
            self._kv_synced[slot] = 0
        if "ssm" in self.cache:
            # recurrent state is live across the whole row — unlike the kv
            # cache there is no position mask hiding a predecessor's
            # leftovers, so a reused slot must start cold
            self.cache["ssm"] = jax.tree_util.tree_map(
                lambda c: c.at[:, slot].set(0), self.cache["ssm"])
        self.slot_req[slot] = req

    # -- mid-request preemption (the continuous-batching slot handover) -------
    def preempt(self, slot: int) -> SlotCheckpoint:
        """Evict the request occupying ``slot`` mid-service and return a
        bit-exact resumable :class:`SlotCheckpoint`.

        The device cache is authoritative for an occupied slot (host
        writebacks are copies), so the snapshot reads the valid KV rows
        and recurrent state straight from it.  The slot is then released
        exactly like a retirement from the paging side: its pooled KV
        blocks are queued for drop — flushed immediately when no KV pass
        is in flight (the single-scheduler admit point, which sits
        between fence and begin), else deferred to the upcoming fence,
        which in the tenancy tick order still lands before the slot's
        next occupant writes back its first block."""
        req = self.slot_req[slot]
        if req is None:
            raise ValueError(f"slot {slot} is empty; nothing to preempt")
        valid = self._kv_valid(slot)
        kv = None
        if "kv" in self.cache and valid > 0:
            kv = dict(
                k=np.asarray(self.cache["kv"]["k"][:, slot, :, :valid]),
                v=np.asarray(self.cache["kv"]["v"][:, slot, :, :valid]))
        ssm = None
        if "ssm" in self.cache:
            ssm = {n: np.asarray(c[:, slot])
                   for n, c in self.cache["ssm"].items()}
        ckpt = SlotCheckpoint(req=req, slot_pos=int(self.slot_pos[slot]),
                              valid=int(valid), kv=kv, ssm=ssm)
        req.preemptions += 1
        self.slot_req[slot] = None
        self._slot_gen[slot] += 1
        self.preempt_count += 1
        if self.kv_table is not None:
            self.kv_table.preempt_release(
                slot, in_flight=self._inflight_kv is not None)
            self._kv_synced[slot] = 0
        return ckpt

    def restore(self, ckpt: SlotCheckpoint, slot: int) -> None:
        """Rebind a preempted request to a free slot and scatter its
        checkpointed state back — decode resumes from ``generated[-1]``,
        chunked prefill from its chunk frontier, bit-exactly for greedy
        sampling.  The host KV image is NOT written here: ``_kv_synced``
        restarts at 0 and the normal end-of-tick ``sync_kv_tick`` re-
        writes the completed blocks back (fresh writeback + fetch events,
        which the ``kv_pass_counters`` replay follows natively)."""
        if self.slot_req[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        req = ckpt.req
        self.slot_req[slot] = req
        self.slot_pos[slot] = ckpt.slot_pos
        self._slot_gen[slot] += 1
        self.restore_count += 1
        if ckpt.kv is not None:
            k, v = self.cache["kv"]["k"], self.cache["kv"]["v"]
            hi = ckpt.valid
            k = k.at[:, slot, :, :hi].set(
                jnp.asarray(ckpt.kv["k"], k.dtype))
            v = v.at[:, slot, :, :hi].set(
                jnp.asarray(ckpt.kv["v"], v.dtype))
            self.cache["kv"] = dict(k=k, v=v)
        if ckpt.ssm is not None:
            self.cache["ssm"] = {
                n: c.at[:, slot].set(jnp.asarray(ckpt.ssm[n], c.dtype))
                for n, c in self.cache["ssm"].items()}
        if self.kv_table is not None:
            self._kv_synced[slot] = 0

    @property
    def pending(self) -> bool:
        return bool(self.waiting
                    or any(r is not None for r in self.slot_req))

    # -- tick primitives (driven by step() or by sched.Scheduler) -------------
    def _chunk_shape(self, req: Request, chunk: Optional[int] = None
                     ) -> Tuple[int, int, bool, int]:
        """(n_tokens, bucket, add_prefix, insert_pos) of the next chunk."""
        prefix = self.cfg.n_meta_tokens
        remaining = len(req.prompt) - req.prefill_pos
        if self._bucketed:
            n = min(chunk if chunk is not None else self.prefill_chunk,
                    remaining)
            bucket = _next_pow2(n)
            # never let the padded window spill past the cache: near the
            # boundary shrink to the largest power of two that still fits
            # (the chunk loop absorbs the rest next round), so every
            # compiled prefill shape stays a power of two even for
            # non-pow2 max_len
            avail = self.max_len - prefix - req.prefill_pos
            if bucket > avail:
                bucket = _pow2_floor(avail)
                n = min(bucket, remaining)
        else:
            n = remaining          # exact-length single shot (hybrid / moe)
            bucket = n
        first = req.prefill_pos == 0
        # prefix is prepended inside the step only on the first chunk; the
        # flag is pinned True for prefix-free models so it never forks the
        # jit cache
        add_prefix = first if prefix else True
        insert_pos = 0 if first else prefix + req.prefill_pos
        return n, bucket, add_prefix, insert_pos

    def prefill_tick(self, params: Any, complete: bool = False,
                     chunk: Optional[int] = None,
                     plan: Optional[Dict[int, int]] = None
                     ) -> List[Request]:
        """Advance every prefilling slot by one chunk (``complete=True``
        loops until all prompts are absorbed — the legacy single-tick
        prefill).  ``chunk`` overrides the engine's default pacing for
        this call only (the Scheduler threads its own), and must be a
        power of two.  ``plan`` ({slot: token allocation}) is the
        budgeted continuous-batching composition: only the listed slots
        prefill this call, each at its OWN allocation — slots the
        scheduler left out of the plan simply hold their frontier for a
        tick.  Slots whose prompt completes sample their first token at
        the request's own temperature.  Returns the requests that got
        their first token this call."""
        if complete and plan is not None:
            raise ValueError("plan= paces one scheduler tick; it cannot "
                             "be combined with complete=True")
        started: List[Request] = []
        while True:
            pending = [(i, r) for i, r in enumerate(self.slot_req)
                       if r is not None and r.prefill_pos < len(r.prompt)
                       and (plan is None or plan.get(i, 0) > 0)]
            if not pending:
                break
            groups: Dict[Tuple[int, bool],
                         List[Tuple[int, Request, int, int]]] = {}
            for i, r in pending:
                c = plan[i] if plan is not None else chunk
                n, bucket, add_prefix, pos = self._chunk_shape(r, c)
                groups.setdefault((bucket, add_prefix),
                                  []).append((i, r, n, pos))
            for (bucket, add_prefix), rows in groups.items():
                self._run_prefill_group(params, bucket, add_prefix, rows,
                                        started)
            if not complete:
                break
        return started

    def _kv_span_for(self, bucket: int,
                     rows: List[Tuple[int, Request, int, int]]
                     ) -> Optional[int]:
        """KV-cache span one prefill group must attend: the next power of
        two covering every row's ``insert_pos + bucket`` (plus the
        meta-token prefix on first chunks), clamped to ``max_len``.  None
        for families without a KV cache."""
        if "kv" not in self.cache:
            return None
        prefix = self.cfg.n_meta_tokens
        need = max((prefix if r.prefill_pos == 0 else 0) + pos + bucket
                   for _i, r, _n, pos in rows)
        return min(self.max_len, _next_pow2(need))

    def _run_prefill_group(self, params: Any, bucket: int, add_prefix: bool,
                           rows: List[Tuple[int, Request, int, int]],
                           started: List[Request]) -> None:
        if self.cfg.family == "moe":
            # expert capacity is contended across the FLATTENED batch, so
            # padding rows (or co-batched neighbors) could displace real
            # tokens' routing; prefill MoE slots one at a time (batch-1,
            # the old engine's exact semantics)
            for row in rows:
                self._run_prefill_rows(params, bucket, add_prefix, [row],
                                       1, started)
            return
        self._run_prefill_rows(params, bucket, add_prefix, rows, self.slots,
                               started)

    def _run_prefill_rows(self, params: Any, bucket: int, add_prefix: bool,
                          rows: List[Tuple[int, Request, int, int]],
                          k: int, started: List[Request]) -> None:
        kv_span = self._kv_span_for(bucket, rows)
        tokens = np.zeros((k, bucket), np.int32)
        slot_idx = np.zeros((k,), np.int32)
        pos_vec = np.zeros((k,), np.int32)
        lengths = np.zeros((k,), np.int32)
        for j in range(k):
            # rows beyond the group repeat the last row: the duplicate
            # scatter writes identical values, so padding the batch to a
            # fixed k keeps the jit cache keyed by bucket alone
            i, r, n, pos = rows[min(j, len(rows) - 1)]
            tokens[j, :n] = r.prompt[r.prefill_pos:r.prefill_pos + n]
            slot_idx[j] = i
            pos_vec[j] = pos
            lengths[j] = n
        fn = self._prefill_for_bucket(bucket, add_prefix, kv_span)
        if self._bucketed and "ssm" in self.cache:
            logits, self.cache = fn(params, jnp.asarray(tokens), self.cache,
                                    jnp.asarray(slot_idx),
                                    jnp.asarray(pos_vec),
                                    jnp.asarray(lengths))
        else:
            logits, self.cache = fn(params, jnp.asarray(tokens), self.cache,
                                    jnp.asarray(slot_idx),
                                    jnp.asarray(pos_vec))
        for j, (i, r, n, _pos) in enumerate(rows):
            r.prefill_pos += n
            if r.prefill_pos < len(r.prompt):
                continue                      # more chunks next tick
            self.key, sub = jax.random.split(self.key)
            tok = int(sample_token(logits[j, n - 1], sub, r.temperature))
            r.generated.append(tok)
            r.first_token_s = _now()
            self.slot_pos[i] = len(r.prompt) + self.cfg.n_meta_tokens
            started.append(r)
            if len(r.generated) >= r.max_new_tokens:
                self._retire(i)

    def decode_tick(self, params: Any) -> List[Request]:
        """One batched decode step over the decode-ready slots; per-slot
        sampling at each request's own temperature.  Slots that are empty
        or still prefilling park their write at the scratch row
        (max_len - 1), which real decoding never reaches and the cache-
        length mask never attends.  Returns the requests finished this
        tick."""
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and r.prefill_pos >= len(r.prompt)]
        if not active:
            return []
        tokens = np.zeros((self.slots, 1), np.int32)
        temps = np.zeros((self.slots,), np.float32)
        pos = np.full((self.slots,), self.max_len - 1, np.int32)
        for i in active:
            req = self.slot_req[i]
            tokens[i, 0] = req.generated[-1]
            temps[i] = req.temperature
            pos[i] = self.slot_pos[i]
        # a KV slot mid-prefill parks its write at the scratch row, but
        # recurrent state has no position to park at — the batched decode
        # would advance a chunk-prefilling SSM slot's state with a
        # garbage token.  Save those slots' state and put it back after.
        parked: List[int] = []
        if "ssm" in self.cache:
            parked = [i for i, r in enumerate(self.slot_req)
                      if r is not None and r.prefill_pos < len(r.prompt)]
            if parked:
                p_idx = jnp.asarray(parked)
                p_saved = jax.tree_util.tree_map(
                    lambda c: jnp.take(c, p_idx, axis=1),
                    self.cache["ssm"])
        logits, self.cache = self._decode(params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(pos))
        if parked:
            self.cache["ssm"] = jax.tree_util.tree_map(
                lambda c, s: c.at[:, p_idx].set(s),
                self.cache["ssm"], p_saved)
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(sample_token_batch(logits[:, -1], sub, temps))
        finished: List[Request] = []
        for i in active:
            req = self.slot_req[i]
            req.generated.append(int(toks[i]))
            self.slot_pos[i] += 1
            if len(req.generated) >= req.max_new_tokens:
                finished.append(self._retire(i))
            elif self.slot_pos[i] >= self.max_len - 1:
                # cache exhausted mid-request: partial service, not a
                # natural completion — flag it so deadline accounting can
                # tell the two apart
                req.truncated = True
                finished.append(self._retire(i))
        return finished

    def _retire(self, slot: int) -> Request:
        req = self.slot_req[slot]
        req.done = True
        req.finish_s = _now()
        self.finished.append(req)
        self.slot_req[slot] = None
        self._slot_gen[slot] += 1
        if self.kv_table is not None:
            self.kv_table.queue_drop(slot)
            self._kv_synced[slot] = 0
        return req

    # -- legacy FIFO loop -----------------------------------------------------
    def _admit(self) -> None:
        for i in self.free_slots():
            if not self.waiting:
                break
            self.assign(self.waiting.pop(0), i)

    def step(self) -> List[Request]:
        """One engine tick: stream pages, admit FIFO, full prefill for the
        fresh slots, batched decode, retire.  Returns the requests that
        finished this tick."""
        before = len(self.finished)
        params = self.tick_params()
        self._admit()
        self.prefill_tick(params, complete=True)
        self.decode_tick(params)
        self.sync_kv_tick()
        return self.finished[before:]

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        """Serve until the queue drains; returns the requests completed by
        THIS call (``self.finished`` keeps the all-time list)."""
        done: List[Request] = []
        ticks = 0
        while self.pending:
            done += self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("serving loop did not converge")
        return done

"""Deadline-aware XR serving scheduler — policy over the engine's ticks.

Siracusa's system claim is not "fast on average" but "inside the frame
budget": the heterogeneous XR workload (hand tracking, gaze, audio, a
background assistant) must finish each invocation within a 10–20 ms
deadline while everything shares one memory hierarchy.  This module is
that claim's serving-side analogue:

  * N **request streams**, each with a default priority and deadline —
    the paper's concurrently-running XR models;
  * **EDF-with-priority admission**: free batch slots go to the highest
    priority class first, earliest absolute deadline within a class
    (classic earliest-deadline-first, which is optimal for preemptive
    uniprocessor scheduling and a strong heuristic for slot admission);
    ties on (priority, deadline) break on the monotonic submission
    sequence, so admission — and therefore every paging counter — is
    reproducible run to run;
  * **continuous batching** (``token_budget=``): every tick the
    scheduler re-plans a shared token budget across the live slots —
    each decode-ready slot costs one token off the top (decode is a
    single batched step; starving it would stall every live stream's
    next token), and the remainder is dealt to mid-prefill slots in
    admission-key order, so an arriving 10 ms request gets budget THIS
    tick instead of waiting behind a long assistant prefill.
    Exact-length prefill families (hybrid / moe) cannot be sliced, so a
    scheduled slot absorbs its whole prompt — a documented budget
    overrun rather than permanent starvation;
  * **mid-request preemption** (``preemptive=True``): when an urgent
    request has no free slot, the worst-ranked occupant of a strictly
    lower priority class is evicted mid-service — its KV blocks drop
    through the :class:`~repro.core.paging.KVPageTable` path, its state
    checkpoints host-ward (:meth:`ServingEngine.preempt`), and the slot
    is handed over.  The victim re-enters the admission pool and later
    :meth:`~ServingEngine.restore`\\ s bit-exactly — resuming decode, or
    chunked prefill at its chunk frontier (exactness holds for greedy
    requests; stochastic sampling shares the engine's RNG stream, whose
    consumption order legitimately changes under preemption);
  * **admission control** (``admission="reject"|"degrade"``): a request
    whose predicted completion — prefill + decode ticks at the measured
    per-tick cost, exposed stall estimated by the
    :func:`~repro.core.memsys.overlap_stall` model — already misses its
    deadline is refused up front (or, under ``"degrade"``, its
    ``max_new_tokens`` is cut to the longest completion that still
    fits), instead of being queued into a guaranteed miss;
  * **chunked prefill**: a long prompt advances at most ``prefill_chunk``
    tokens per tick, so it cannot monopolize a tick while a 10 ms-deadline
    request sits decode-starved in the next slot;
  * **overlapped paged weights** (``async_io=True``, the default): the
    tick loop is a software pipeline — fence the pass begun last tick,
    admit, *begin* the next tick's page stream, then run this tick's
    prefill/decode while the stream proceeds in the background.  Only
    the *exposed* wait (time the fence actually blocked) lands on the
    tick; the *hidden* remainder rides behind compute, the serving-side
    realization of the paper's At-MRAM latency hiding.  ``async_io=
    False`` keeps the fully synchronous stream-then-step tick, which the
    async path is verified bit-exact against (same tokens, same swap/
    miss counters — same traffic, different schedule);
  * **metrics**: TTFT / end-to-end latency / p50 / p99 / deadline-miss
    rate / tok/s / exposed-vs-hidden paging stalls / preemption and
    admission-control counters / budget utilization, recorded per tick
    and per request and emitted as the ``repro.serving.metrics/v8``
    JSON.

The scheduler owns no jit state — it drives the engine's tick primitives
(``begin_tick_params`` / ``fence_tick_params`` / ``assign`` /
``preempt`` / ``restore`` / ``prefill_tick`` / ``decode_tick``), so
engine mechanism tests and scheduler policy tests stay independent.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Tuple

from repro.core.faults import PageFetchTimeout
from repro.core.memsys import overlap_stall
from repro.core.paging import pass_counters
from repro.serving.engine import Request, ServingEngine, SlotCheckpoint
from repro.serving.metrics import MetricsRecorder
from repro.serving.trace import Tracer


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One request stream (an XR app's model invocations): requests
    submitted to the stream inherit its priority and deadline unless they
    carry their own."""
    name: str
    priority: int = 0                      # higher = more urgent
    deadline_ms: Optional[float] = None    # None = best effort


class Scheduler:
    """EDF-with-priority front-end over a :class:`ServingEngine`.

    Typical use::

        eng = ServingEngine(cfg, packed, plan=plan).attach_paging()
        sched = Scheduler(eng, prefill_chunk=32, token_budget=64,
                          preemptive=True, admission="reject")
        sched.add_stream("hand", priority=2, deadline_ms=15.0)
        sched.add_stream("assistant")                  # best effort
        sched.submit(Request(uid=0, prompt=p), stream="hand")
        done = sched.run_until_done()
        print(sched.metrics.to_json(paging=eng.paging_summary()))

    ``token_budget`` turns on the continuous-batching tick plan,
    ``preemptive`` allows mid-request slot handover to strictly-higher
    priority requests, and ``admission`` ("reject" or "degrade") refuses
    requests whose predicted completion already misses their deadline
    (an explicit ``est_tick_s`` pins the cost model — deterministic
    admission for virtual-clock benches; without it the controller uses
    measured per-tick EMAs, admitting optimistically until it has
    data).  ``seq_counter`` shares one submission sequence across
    schedulers (the tenancy loop passes its own so the global admission
    order stays deterministic)."""

    def __init__(self, engine: ServingEngine, *,
                 prefill_chunk: Optional[int] = None,
                 metrics: Optional[MetricsRecorder] = None,
                 async_io: bool = True,
                 token_budget: Optional[int] = None,
                 preemptive: bool = False,
                 admission: Optional[str] = None,
                 est_tick_s: Optional[float] = None,
                 seq_counter: Optional[itertools.count] = None,
                 clock=time.perf_counter,
                 tracer: Optional[Tracer] = None,
                 trace_track: Optional[str] = None,
                 fetch_timeout_s: Optional[float] = None):
        self.engine = engine
        # overlap the next tick's page stream with this tick's compute;
        # False = the fully synchronous stream-then-step tick
        self.async_io = bool(async_io)
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                # _next_pow2 maps 0/negative to 1 — reject instead of
                # silently pacing at chunk=1
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
            from repro.serving.engine import _next_pow2
            self.prefill_chunk: Optional[int] = _next_pow2(prefill_chunk)
        else:
            self.prefill_chunk = None      # engine default pacing
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got "
                             f"{token_budget}")
        self.token_budget = token_budget
        self.preemptive = bool(preemptive)
        if admission not in (None, "reject", "degrade"):
            raise ValueError(f"admission must be None, 'reject' or "
                             f"'degrade', got {admission!r}")
        self.admission = admission
        self.metrics = metrics if metrics is not None else MetricsRecorder(
            clock=clock)
        self.clock = clock
        self.streams: Dict[str, StreamSpec] = {
            "default": StreamSpec("default")}
        self.queue: List[Request] = []
        self.preempted: List[SlotCheckpoint] = []
        self.rejected: List[Request] = []
        self.finished: List[Request] = []
        self.ticks = 0
        # fetch deadline for the tick's I/O fence: on expiry the tick is
        # DEFERRED (the in-flight pass resumes at the next fence) instead
        # of stalling the world — graceful degradation under stuck pages
        self.fetch_timeout_s = fetch_timeout_s
        self.deferred_ticks = 0
        self._seq = (seq_counter if seq_counter is not None
                     else itertools.count())
        # the budgeted tick's plan ({slot: token alloc}), set between
        # admission and begin (the tenancy loop sets it from its GLOBAL
        # plan), consumed by tick_begin/tick_compute
        self._tick_plan: Optional[Dict[int, int]] = None
        self._tick_budget_tokens: Optional[int] = None
        self._tick_budget_used: Optional[int] = None
        # admission-control cost model: EMAs of per-tick compute and
        # stream (swap) seconds; predicted tick cost composes them via
        # the memsys overlap identity
        self._compute_ema: Optional[float] = None
        self._swap_ema: Optional[float] = None
        self._est_seed_s = est_tick_s
        # opt-in chrome-trace instrumentation: every hot-path hook guards
        # on ``tracer is None`` (the default), so the un-traced tick pays
        # one branch and allocates nothing
        self.tracer = tracer
        self.track = trace_track if trace_track is not None else "serve"
        if tracer is not None:
            engine.set_tracer(tracer, track=self.track)
        # predicted-vs-measured exposed-stall accumulators: the closed
        # form (memsys.overlap_stall over the fenced pass's swap/window)
        # against what the fence actually booked — summarized as the
        # metrics/v8 ``trace.predicted_vs_measured_stall_ratio``
        self._pred_exposed_s = 0.0
        self._meas_exposed_s = 0.0

    # -- streams & submission -------------------------------------------------
    def add_stream(self, name: str, *, priority: int = 0,
                   deadline_ms: Optional[float] = None) -> StreamSpec:
        spec = StreamSpec(name, priority=priority, deadline_ms=deadline_ms)
        self.streams[name] = spec
        return spec

    def submit(self, req: Request, stream: Optional[str] = None) -> None:
        """Queue a request.  Stream defaults fill in a missing priority /
        deadline; arrival is stamped here (TTFT and the deadline clock run
        from submission, not admission), as is the monotonic submission
        sequence the admission key breaks ties on."""
        name = stream if stream is not None else req.stream
        if name not in self.streams:
            raise KeyError(f"unknown stream {name!r}; add_stream() first")
        spec = self.streams[name]
        self.engine._check_fits(req)       # reject oversized/empty NOW,
        req.stream = name                  # not mid-loop at admission
        if req.priority is None:
            req.priority = spec.priority
        if req.deadline_ms is None:
            req.deadline_ms = spec.deadline_ms
        if req.arrival_s is None:
            req.arrival_s = self.clock()
        if req.seq is None:
            req.seq = next(self._seq)
        self.queue.append(req)

    # -- admission policy -----------------------------------------------------
    def _admission_key(self, req: Request) -> Tuple[int, float, int]:
        """Priority class first, EDF inside the class, and the monotonic
        submission sequence as the deterministic tie-break (requests that
        never passed :meth:`submit` fall back to their uid)."""
        deadline_abs = (float("inf") if req.deadline_ms is None
                        else req.arrival_s + req.deadline_ms / 1e3)
        seq = req.seq if req.seq is not None else req.uid
        return (-(req.priority or 0), deadline_abs, seq)

    def admission_order(self) -> List[Request]:
        """Waiting requests in service order: priority class first, then
        earliest absolute deadline (EDF), then submission sequence."""
        return sorted(self.queue, key=self._admission_key)

    def _adopt_engine_queue(self) -> None:
        """Requests submitted through the still-public ``engine.submit``
        join the scheduler's queue (their stream if it exists here, else
        "default") — otherwise ``pending`` would count them while nothing
        ever admits them."""
        while self.engine.waiting:
            req = self.engine.waiting.pop(0)
            stream = req.stream if req.stream in self.streams else "default"
            if self.clock is not time.perf_counter:
                # engine.submit stamped arrival with perf_counter; under a
                # custom scheduler clock that would mix domains in every
                # latency/deadline metric — re-stamp on adoption
                req.arrival_s = None
            self.submit(req, stream=stream)

    # -- admission control (predicted-miss refusal) ---------------------------
    def est_tick_s(self) -> Optional[float]:
        """Predicted cost of one tick.  An explicit ``est_tick_s``
        constructor seed PINS the cost model (deterministic admission —
        benches and tests driving a virtual clock need predictions that
        never drift with host load, since the engine-side stall split is
        measured in real time).  Without a seed, the prediction is the
        measured compute EMA plus the exposed share of the stream EMA
        under the memsys overlap model (``stall = swap - hidden``), and
        None until the first measured tick — the controller then admits
        optimistically rather than rejecting on no data."""
        if self._est_seed_s is not None:
            return self._est_seed_s
        if self._compute_ema is None:
            return None
        stall = overlap_stall(self._swap_ema or 0.0, self._compute_ema)
        return self._compute_ema + stall["exposed_s"]

    def _ticks_needed(self, req: Request, new_tokens: int) -> int:
        """Service ticks to produce ``new_tokens``: chunked prefill ticks
        (the first token lands on the last of them), then one decode tick
        per further token.  Optimistic — budget contention and queueing
        ahead are not modeled, so a predicted miss is a CERTAIN miss
        under at-least-this-cost service, which is exactly the one-sided
        guarantee rejection needs."""
        remaining = len(req.prompt) - req.prefill_pos
        if remaining <= 0:
            p_ticks = 0
        elif self.engine._bucketed and self.prefill_chunk:
            p_ticks = math.ceil(remaining / self.prefill_chunk)
        else:
            p_ticks = 1
        return p_ticks + max(new_tokens - 1, 0)

    def _admission_control(self) -> None:
        cost = self.est_tick_s()
        if cost is None or cost <= 0.0:
            return
        now = self.clock()
        kept: List[Request] = []
        for req in self.queue:
            if req.deadline_ms is None:
                kept.append(req)
                continue
            deadline_abs = req.arrival_s + req.deadline_ms / 1e3
            slack_ticks = math.floor((deadline_abs - now) / cost)
            if self._ticks_needed(req, req.max_new_tokens) <= slack_ticks:
                kept.append(req)
                continue
            # the longest completion that still fits the deadline
            feasible = slack_ticks - self._ticks_needed(req, 1) + 1
            if self.admission == "degrade" and feasible >= 1:
                if feasible < req.max_new_tokens:
                    req.max_new_tokens = int(feasible)
                    if not req.degraded:
                        req.degraded = True
                        self.metrics.record_degraded()
                        if self.tracer is not None:
                            self.tracer.instant(
                                "degrade", track=self.track, uid=req.uid,
                                max_new_tokens=req.max_new_tokens)
                kept.append(req)
            else:
                req.rejected = True
                req.finish_s = now
                self.rejected.append(req)
                self.metrics.record_rejected()
                if self.tracer is not None:
                    self.tracer.instant("reject", track=self.track,
                                        uid=req.uid)
        self.queue[:] = kept

    # -- admission + preemption -----------------------------------------------
    def _candidates(self) -> List[Tuple[tuple, str, object]]:
        """The unified admission pool — fresh queue entries and preempted
        checkpoints under ONE key — sorted into service order.  A
        preempted victim competes on its own (priority, deadline, seq):
        an urgent victim re-enters ahead of best-effort arrivals, and may
        itself preempt a lower-priority usurper."""
        cands = [(self._admission_key(r), "queue", r) for r in self.queue]
        cands += [(self._admission_key(c.req), "restore", c)
                  for c in self.preempted]
        cands.sort(key=lambda t: t[0])
        return cands

    def _place(self, kind: str, obj, slot: int) -> None:
        # remove by identity: Request's dataclass __eq__ compares the
        # ndarray prompt (and SlotCheckpoint's its state arrays), so
        # list.remove could raise on an equality tie
        if kind == "queue":
            idx = next(i for i, r in enumerate(self.queue) if r is obj)
            del self.queue[idx]
            self.engine.assign(obj, slot)
            if self.tracer is not None:
                self.tracer.instant("admit", track=self.track,
                                    uid=obj.uid, slot=slot)
        else:
            idx = next(i for i, c in enumerate(self.preempted) if c is obj)
            del self.preempted[idx]
            self.engine.restore(obj, slot)
            self.metrics.record_restore()
            if self.tracer is not None:
                self.tracer.instant("restore", track=self.track,
                                    uid=obj.req.uid, slot=slot)

    def _preempt_slot(self, slot: int) -> None:
        """Evict ``slot`` mid-service into the preempted pool — the one
        copy of the checkpoint + metrics + trace bookkeeping shared by
        the solo admit loop and the tenancy global pass."""
        ck = self.engine.preempt(slot)
        self.preempted.append(ck)
        self.metrics.record_preemption()
        if self.tracer is not None:
            self.tracer.instant("preempt", track=self.track,
                                uid=ck.req.uid, slot=slot)

    def _preempt_for(self, req: Request) -> Optional[int]:
        """Pick a victim slot for ``req``: the worst-ranked occupant of a
        STRICTLY lower priority class (equal-priority preemption would
        thrash: the victim would immediately out-rank its usurper by
        deadline and want the slot back).  Returns None when no occupant
        qualifies."""
        prio = req.priority or 0
        victims = [(i, r) for i, r in enumerate(self.engine.slot_req)
                   if r is not None and (r.priority or 0) < prio]
        if not victims:
            return None
        slot, _r = max(victims, key=lambda t: self._admission_key(t[1]))
        return slot

    def _admit(self) -> None:
        tr = self.tracer
        if tr is None:
            self._admit_impl()
            return
        with tr.span("admit", track=self.track):
            self._admit_impl()

    def _admit_impl(self) -> None:
        self._adopt_engine_queue()
        if self.admission is not None:
            self._admission_control()
        for slot in self.engine.free_slots():
            cands = self._candidates()
            if not cands:
                break
            _key, kind, obj = cands[0]
            self._place(kind, obj, slot)
        if not self.preemptive:
            return
        # every iteration strictly raises the evicted slot's priority, so
        # the handover chain terminates
        while True:
            cands = self._candidates()
            if not cands:
                return
            _key, kind, obj = cands[0]
            req = obj if kind == "queue" else obj.req
            slot = self._preempt_for(req)
            if slot is None:
                return
            self._preempt_slot(slot)
            self._place(kind, obj, slot)

    # -- the budgeted tick plan (continuous batching) -------------------------
    def _plan_tick(self) -> Optional[Dict[int, int]]:
        """Deal this tick's ``token_budget`` across the live slots: one
        token per decode-ready slot off the top (decode is a single
        batched step — withholding it would stall every live stream),
        the remainder to mid-prefill slots in admission-key order, capped
        at ``prefill_chunk``.  Exact-length families (hybrid / moe) are
        all-or-nothing: a scheduled slot absorbs its whole remaining
        prompt (documented overrun) rather than starving forever.
        Returns the {slot: alloc} plan, or None when unbudgeted."""
        if self.token_budget is None:
            self._tick_budget_tokens = None
            self._tick_budget_used = None
            return None
        eng = self.engine
        occ = [(i, r) for i, r in enumerate(eng.slot_req) if r is not None]
        used = sum(1 for _i, r in occ if r.prefill_pos >= len(r.prompt))
        remaining = self.token_budget - used
        plan: Dict[int, int] = {}
        prefilling = sorted(
            ((i, r) for i, r in occ if r.prefill_pos < len(r.prompt)),
            key=lambda t: self._admission_key(t[1]))
        for i, r in prefilling:
            rem = len(r.prompt) - r.prefill_pos
            if eng._bucketed:
                alloc = min(self.prefill_chunk or rem, rem,
                            max(remaining, 0))
            else:
                alloc = rem if remaining > 0 else 0
            if alloc > 0:
                plan[i] = int(alloc)
                remaining -= alloc
                used += alloc
        self._tick_budget_tokens = self.token_budget
        self._tick_budget_used = used
        return plan

    # -- the tick (a 3-phase software pipeline) -------------------------------
    def tick_fence(self) -> tuple:
        """Phase 1: fence the page pass begun last tick (demand-begins a
        blocking one on the cold first tick / in sync mode) and stamp the
        tick start.  Returns ``(t0, params)`` for :meth:`tick_compute`.

        On a mesh-sharded engine the fence joins one stream PER DEVICE
        LINK (:class:`~repro.core.paging.JoinedPageStream`): the tick
        waits for the slowest link, and a fetch-deadline expiry on any
        link defers the tick with EVERY per-device pass left resumable —
        the :class:`~repro.core.faults.PageFetchTimeout`'s ``model``
        names the offending link's store (``<name>@dev<i>``)."""
        t0 = self.clock()
        self.metrics.start()                     # wall clock spans tick 1
        tr = self.tracer
        if tr is None:
            params = self.engine.fence_tick_params(
                timeout_s=self.fetch_timeout_s)
        else:
            with tr.span("fence", track=self.track, tick=self.ticks):
                params = self.engine.fence_tick_params(
                    timeout_s=self.fetch_timeout_s)
        return t0, params

    def tick_begin(self) -> None:
        """Phase 2 (after admission + planning): begin the NEXT tick's
        page stream — only when the engine is certain to tick again, so
        every begun pass is consumed by exactly one fence and the
        swap/miss counters stay identical to the synchronous schedule."""
        if not self.async_io:
            return
        if self._tick_plan is not None:
            more = self.engine.has_tick_after(plan=self._tick_plan)
        else:
            more = self.engine.has_tick_after(self.prefill_chunk)
        if self.queue or self.preempted or more:
            tr = self.tracer
            if tr is None:
                self.engine.begin_tick_params()
            else:
                with tr.span("begin", track=self.track):
                    self.engine.begin_tick_params()

    def _compute_tick(self, params) -> List[Request]:
        """The engine-driving core of phase 3: planned prefills, one
        batched decode, KV writeback."""
        started = self.engine.prefill_tick(params, complete=False,
                                           chunk=self.prefill_chunk,
                                           plan=self._tick_plan)
        now = self.clock()
        for req in started:
            req.first_token_s = now              # scheduler clock wins
        finished = [r for r in started if r.done]
        finished += self.engine.decode_tick(params)
        # KV paging: blocks the append-only frontier completed this tick
        # are written back host-ward once, becoming fetchable next pass
        self.engine.sync_kv_tick()
        return finished

    def _trace_tick(self, measured_exposed_s: float) -> None:
        """Accumulate this tick's predicted-vs-measured exposed-stall
        drift (the metrics/v8 ``trace`` section) and, when tracing,
        render the closed-form prediction on the ``<track> (predicted)``
        overlay next to the measured fence spans."""
        eng = self.engine
        overlaps = [ov for ov in (eng.last_overlap, eng.last_kv_overlap)
                    if ov is not None]
        if not overlaps:
            return
        pred_exposed = pred_hidden = swap = 0.0
        for ov in overlaps:
            st = overlap_stall(ov["swap_s"], ov["window_s"])
            pred_exposed += st["exposed_s"]
            pred_hidden += st["hidden_s"]
            swap += ov["swap_s"]
        self._pred_exposed_s += pred_exposed
        self._meas_exposed_s += measured_exposed_s
        tr = self.tracer
        if tr is None:
            return
        per_pass_swaps = (
            pass_counters(len(eng.pager.pages),
                          eng.page_resident_slots)["swaps"]
            if eng.pager is not None else 0)
        tr.complete("stall(pred)", pred_exposed,
                    track=f"{self.track} (predicted)",
                    predicted_exposed_ms=pred_exposed * 1e3,
                    predicted_hidden_ms=pred_hidden * 1e3,
                    measured_exposed_ms=measured_exposed_s * 1e3,
                    swap_ms=swap * 1e3,
                    predicted_swaps_per_pass=per_pass_swaps)

    def tick_compute(self, t0: float, params) -> List[Request]:
        """Phase 3: prefill per the tick plan (one chunk per slot when
        unbudgeted), one batched decode, retire + metrics — overlapping
        with the phase-2 stream."""
        tr = self.tracer
        if tr is None:
            finished = self._compute_tick(params)
        else:
            with tr.span("compute", track=self.track, tick=self.ticks):
                finished = self._compute_tick(params)
        now = self.clock()
        for req in finished:
            req.finish_s = now
            self.metrics.record_request(req)
            self.finished.append(req)
        self.ticks += 1
        latency = now - t0
        exposed = self.engine.last_stall_s
        hidden = self.engine.last_hidden_s
        # cost-model EMAs: compute is the tick wall net of the exposed
        # paging wait; "swap" is the full stream time (exposed + hidden)
        alpha = 0.3
        compute = max(latency - exposed, 0.0)
        self._compute_ema = (compute if self._compute_ema is None
                             else (1 - alpha) * self._compute_ema
                             + alpha * compute)
        swap = exposed + hidden
        self._swap_ema = (swap if self._swap_ema is None
                          else (1 - alpha) * self._swap_ema + alpha * swap)
        self._trace_tick(exposed)
        self.metrics.record_tick(latency_s=latency,
                                 paging_exposed_s=exposed,
                                 paging_hidden_s=hidden,
                                 budget_tokens=self._tick_budget_tokens,
                                 budget_used=self._tick_budget_used)
        self._tick_plan = None
        self._tick_budget_tokens = None
        self._tick_budget_used = None
        return finished

    def defer_tick(self, exc: PageFetchTimeout) -> None:
        """Record a tick deferred on an I/O deadline: the fence timed out,
        the in-flight pass stays owned by the engine (resumed by the next
        fence), no compute ran and no tick counters advanced — so the
        weight-counter identity ``swaps == ticks x pass_counters`` holds
        on COMPUTED ticks, exactly as the static prediction expects."""
        self.deferred_ticks += 1
        if self.tracer is not None:
            self.tracer.instant("defer", track="io", model=exc.model,
                                timeout_ms=exc.timeout_s * 1e3,
                                pending=exc.pending, tick=self.ticks)

    def tick(self) -> List[Request]:
        """One scheduler tick: fence the in-flight pages, admit EDF
        (preempting / refusing per policy), re-plan the token budget,
        begin the next stream, then advance the planned prefills and run
        one batched decode while the stream proceeds.  Returns the
        requests that finished this tick.

        With a ``fetch_timeout_s``, a fence that exceeds the deadline
        defers the whole tick (empty return) instead of blocking: the
        pass resumes at the next tick's fence."""
        try:
            t0, params = self.tick_fence()
        except PageFetchTimeout as e:
            self.defer_tick(e)
            return []
        self._admit()
        self._tick_plan = self._plan_tick()
        self.tick_begin()
        return self.tick_compute(t0, params)

    # -- loops ----------------------------------------------------------------
    @property
    def pending(self) -> bool:
        return bool(self.queue or self.preempted or self.engine.pending)

    def run_until_done(self, max_ticks: int = 100_000) -> List[Request]:
        """Serve until the queue drains.  ``max_ticks`` bounds THIS call
        (a reused scheduler's cumulative ``self.ticks`` must not trip the
        convergence check early), and the return value is the requests
        completed by this call — ``self.finished`` keeps the all-time
        list (admission-rejected requests land in ``self.rejected``,
        never here)."""
        done: List[Request] = []
        ticks = 0
        while self.pending:
            done += self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("scheduler loop did not converge")
        return done

    def run_for(self, seconds: float) -> List[Request]:
        """Serve until the wall budget is spent or the queue drains;
        returns the requests completed by this call.  A pass begun for
        the tick after the budget expired stays in flight — a later run
        call fences it; call :meth:`close` instead to cancel it."""
        t0 = self.clock()
        done: List[Request] = []
        while self.pending and (self.clock() - t0) < seconds:
            done += self.tick()
        return done

    def close(self) -> None:
        """Early exit: cancel/drain a page pass begun for a tick that
        will never run, so nothing leaks past teardown (the engine's
        pager itself is owned by the caller / pool)."""
        self.engine.cancel_tick_params()

    def faults_summary(self) -> Dict[str, int]:
        """The metrics v8 ``faults`` section for this scheduler: the
        engine's store-level fault counters plus the ticks this scheduler
        deferred on a fetch deadline."""
        out = self.engine.faults_summary()
        out["deferred_ticks"] = self.deferred_ticks
        return out

    # -- trace introspection ---------------------------------------------------
    def trace_summary(self) -> Dict[str, object]:
        """The metrics/v8 ``trace`` section for this scheduler: tracer
        event/track counts (zeros when un-traced) and the run's
        predicted-vs-measured exposed-stall ratio.  The ratio is the
        summed closed-form prediction over the summed fence-measured
        exposure; 1.0 means the stall model matched reality (vacuously
        so for runs that never paged)."""
        meas, pred = self._meas_exposed_s, self._pred_exposed_s
        if meas > 0.0:
            ratio = pred / meas
        else:
            ratio = 1.0 if pred <= 0.0 else 0.0
        tr = self.tracer
        return dict(
            events=0 if tr is None else tr.event_count,
            tracks=[] if tr is None else tr.track_names,
            predicted_vs_measured_stall_ratio=ratio)

"""Deadline-aware XR serving scheduler — policy over the engine's ticks.

Siracusa's system claim is not "fast on average" but "inside the frame
budget": the heterogeneous XR workload (hand tracking, gaze, audio, a
background assistant) must finish each invocation within a 10–20 ms
deadline while everything shares one memory hierarchy.  This module is
that claim's serving-side analogue:

  * N **request streams**, each with a default priority and deadline —
    the paper's concurrently-running XR models;
  * **EDF-with-priority admission**: free batch slots go to the highest
    priority class first, earliest absolute deadline within a class
    (classic earliest-deadline-first, which is optimal for preemptive
    uniprocessor scheduling and a strong heuristic for slot admission);
  * **chunked prefill**: a long prompt advances at most ``prefill_chunk``
    tokens per tick, so it cannot monopolize a tick while a 10 ms-deadline
    request sits decoded-starved in the next slot;
  * **overlapped paged weights** (``async_io=True``, the default): the
    tick loop is a software pipeline — fence the pass begun last tick,
    admit, *begin* the next tick's page stream, then run this tick's
    prefill/decode while the stream proceeds in the background.  Only
    the *exposed* wait (time the fence actually blocked) lands on the
    tick; the *hidden* remainder rides behind compute, the serving-side
    realization of the paper's At-MRAM latency hiding.  ``async_io=
    False`` keeps the fully synchronous stream-then-step tick, which the
    async path is verified bit-exact against (same tokens, same swap/
    miss counters — same traffic, different schedule);
  * **metrics**: TTFT / end-to-end latency / p50 / p99 / deadline-miss
    rate / tok/s / exposed-vs-hidden paging stalls, recorded per tick
    and per request and emitted as the ``repro.serving.metrics/v4``
    JSON.

The scheduler owns no jit state — it drives the engine's tick primitives
(``begin_tick_params`` / ``fence_tick_params`` / ``assign`` /
``prefill_tick`` / ``decode_tick``), so engine mechanism tests and
scheduler policy tests stay independent.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import MetricsRecorder


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One request stream (an XR app's model invocations): requests
    submitted to the stream inherit its priority and deadline unless they
    carry their own."""
    name: str
    priority: int = 0                      # higher = more urgent
    deadline_ms: Optional[float] = None    # None = best effort


class Scheduler:
    """EDF-with-priority front-end over a :class:`ServingEngine`.

    Typical use::

        eng = ServingEngine(cfg, packed, plan=plan).attach_paging()
        sched = Scheduler(eng, prefill_chunk=32)
        sched.add_stream("hand", priority=2, deadline_ms=15.0)
        sched.add_stream("assistant")                  # best effort
        sched.submit(Request(uid=0, prompt=p), stream="hand")
        done = sched.run_until_done()
        print(sched.metrics.to_json(paging=eng.paging_summary()))
    """

    def __init__(self, engine: ServingEngine, *,
                 prefill_chunk: Optional[int] = None,
                 metrics: Optional[MetricsRecorder] = None,
                 async_io: bool = True,
                 clock=time.perf_counter):
        self.engine = engine
        # overlap the next tick's page stream with this tick's compute;
        # False = the fully synchronous stream-then-step tick
        self.async_io = bool(async_io)
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                # _next_pow2 maps 0/negative to 1 — reject instead of
                # silently pacing at chunk=1
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
            from repro.serving.engine import _next_pow2
            self.prefill_chunk: Optional[int] = _next_pow2(prefill_chunk)
        else:
            self.prefill_chunk = None      # engine default pacing
        self.metrics = metrics if metrics is not None else MetricsRecorder(
            clock=clock)
        self.clock = clock
        self.streams: Dict[str, StreamSpec] = {
            "default": StreamSpec("default")}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.ticks = 0

    # -- streams & submission -------------------------------------------------
    def add_stream(self, name: str, *, priority: int = 0,
                   deadline_ms: Optional[float] = None) -> StreamSpec:
        spec = StreamSpec(name, priority=priority, deadline_ms=deadline_ms)
        self.streams[name] = spec
        return spec

    def submit(self, req: Request, stream: Optional[str] = None) -> None:
        """Queue a request.  Stream defaults fill in a missing priority /
        deadline; arrival is stamped here (TTFT and the deadline clock run
        from submission, not admission)."""
        name = stream if stream is not None else req.stream
        if name not in self.streams:
            raise KeyError(f"unknown stream {name!r}; add_stream() first")
        spec = self.streams[name]
        self.engine._check_fits(req)       # reject oversized/empty NOW,
        req.stream = name                  # not mid-loop at admission
        if req.priority is None:
            req.priority = spec.priority
        if req.deadline_ms is None:
            req.deadline_ms = spec.deadline_ms
        if req.arrival_s is None:
            req.arrival_s = self.clock()
        self.queue.append(req)

    # -- admission policy -----------------------------------------------------
    def _admission_key(self, req: Request):
        deadline_abs = (float("inf") if req.deadline_ms is None
                        else req.arrival_s + req.deadline_ms / 1e3)
        return (-(req.priority or 0), deadline_abs, req.arrival_s, req.uid)

    def admission_order(self) -> List[Request]:
        """Waiting requests in service order: priority class first, then
        earliest absolute deadline (EDF), then arrival."""
        return sorted(self.queue, key=self._admission_key)

    def _adopt_engine_queue(self) -> None:
        """Requests submitted through the still-public ``engine.submit``
        join the scheduler's queue (their stream if it exists here, else
        "default") — otherwise ``pending`` would count them while nothing
        ever admits them."""
        while self.engine.waiting:
            req = self.engine.waiting.pop(0)
            stream = req.stream if req.stream in self.streams else "default"
            if self.clock is not time.perf_counter:
                # engine.submit stamped arrival with perf_counter; under a
                # custom scheduler clock that would mix domains in every
                # latency/deadline metric — re-stamp on adoption
                req.arrival_s = None
            self.submit(req, stream=stream)

    def _admit(self) -> None:
        self._adopt_engine_queue()
        free = self.engine.free_slots()
        if not free or not self.queue:
            return
        self.queue.sort(key=self._admission_key)
        for slot in free:
            if not self.queue:
                break
            self.engine.assign(self.queue.pop(0), slot)

    # -- the tick (a 3-phase software pipeline) -------------------------------
    def tick_fence(self) -> tuple:
        """Phase 1: fence the page pass begun last tick (demand-begins a
        blocking one on the cold first tick / in sync mode) and stamp the
        tick start.  Returns ``(t0, params)`` for :meth:`tick_compute`."""
        t0 = self.clock()
        self.metrics.start()                     # wall clock spans tick 1
        params = self.engine.fence_tick_params()
        return t0, params

    def tick_begin(self) -> None:
        """Phase 2 (after admission): begin the NEXT tick's page stream —
        only when the engine is certain to tick again, so every begun
        pass is consumed by exactly one fence and the swap/miss counters
        stay identical to the synchronous schedule."""
        if (self.async_io
                and (self.queue
                     or self.engine.has_tick_after(self.prefill_chunk))):
            self.engine.begin_tick_params()

    def tick_compute(self, t0: float, params) -> List[Request]:
        """Phase 3: one chunk of prefill per slot, one batched decode,
        retire + metrics — overlapping with the phase-2 stream."""
        started = self.engine.prefill_tick(params, complete=False,
                                           chunk=self.prefill_chunk)
        now = self.clock()
        for req in started:
            req.first_token_s = now              # scheduler clock wins
        finished = [r for r in started if r.done]
        finished += self.engine.decode_tick(params)
        # KV paging: blocks the append-only frontier completed this tick
        # are written back host-ward once, becoming fetchable next pass
        self.engine.sync_kv_tick()
        now = self.clock()
        for req in finished:
            req.finish_s = now
            self.metrics.record_request(req)
            self.finished.append(req)
        self.ticks += 1
        self.metrics.record_tick(latency_s=now - t0,
                                 paging_exposed_s=self.engine.last_stall_s,
                                 paging_hidden_s=self.engine.last_hidden_s)
        return finished

    def tick(self) -> List[Request]:
        """One scheduler tick: fence the in-flight pages, admit EDF,
        begin the next stream, then advance each prefilling slot by ONE
        chunk and run one batched decode while the stream proceeds.
        Returns the requests that finished this tick."""
        t0, params = self.tick_fence()
        self._admit()
        self.tick_begin()
        return self.tick_compute(t0, params)

    # -- loops ----------------------------------------------------------------
    @property
    def pending(self) -> bool:
        return bool(self.queue or self.engine.pending)

    def run_until_done(self, max_ticks: int = 100_000) -> List[Request]:
        """Serve until the queue drains.  ``max_ticks`` bounds THIS call
        (a reused scheduler's cumulative ``self.ticks`` must not trip the
        convergence check early), and the return value is the requests
        completed by this call — ``self.finished`` keeps the all-time
        list."""
        done: List[Request] = []
        ticks = 0
        while self.pending:
            done += self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("scheduler loop did not converge")
        return done

    def run_for(self, seconds: float) -> List[Request]:
        """Serve until the wall budget is spent or the queue drains;
        returns the requests completed by this call.  A pass begun for
        the tick after the budget expired stays in flight — a later run
        call fences it; call :meth:`close` instead to cancel it."""
        t0 = self.clock()
        done: List[Request] = []
        while self.pending and (self.clock() - t0) < seconds:
            done += self.tick()
        return done

    def close(self) -> None:
        """Early exit: cancel/drain a page pass begun for a tick that
        will never run, so nothing leaks past teardown (the engine's
        pager itself is owned by the caller / pool)."""
        self.engine.cancel_tick_params()

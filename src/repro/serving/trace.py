"""Chrome-trace span instrumentation for the serving tick pipeline.

The serving stack software-pipelines weight and KV paging behind compute
and preempts mid-request under 10-20 ms XR deadlines, but aggregate
counters (``metrics.py``) cannot show *when* a fence blocked, which page
fetch straddled a tick boundary, or whom a preemption evicted.  This
module is the timeline view: a zero-dependency span tracer whose output
is Chrome Trace Event Format JSON — load it in ``chrome://tracing`` or
https://ui.perfetto.dev and every tick's fence -> admit -> begin ->
compute phases, every per-page host->device fetch, every preemption /
admission verdict, and the closed-form stall *prediction*
(:func:`repro.core.memsys.overlap_stall`) render as parallel tracks.

Design constraints, in order:

  * **no-op when absent** — every instrumented hot path guards on
    ``tracer is None`` (the default), so the un-traced tick loop pays
    one attribute load + branch and allocates nothing;
  * **thread-safe** — page fetches run on the pool's serialized worker
    thread while the scheduler emits from the tick loop; one lock
    serializes event append and track registration;
  * **monotonic clock** — timestamps come from ``time.perf_counter``
    (via :data:`now`, the one canonical timestamp helper the serving
    stack shares) and are exported as microseconds relative to tracer
    construction;
  * **zero dependencies** — stdlib only, importable from ``core``
    without pulling the serving package in.

Event kinds map 1:1 onto the Trace Event Format: ``span`` emits ``B``/
``E`` duration pairs (single-emitter tracks: scheduler phases),
``complete`` emits one ``X`` event with an explicit duration (worker-
thread page fetches, the retro-dated stall spans), ``instant`` emits
``i`` (admission verdicts, preemptions, evictions), ``counter`` emits
``C`` (pool occupancy).  ``track`` names become ``thread_name``
metadata, one tid per track.

Since the encoded-pages refactor the ``io`` track splits its byte
arguments wire-vs-device: a swap's ``page`` span carries ``nbytes``
(decoded device footprint), ``wire_nbytes`` (what the link moved:
encoded payload + scales) and ``encoding``; the ``pool_bytes`` counter
samples both ``bytes`` (device occupancy, what the budget charges) and
``wire_bytes`` as parallel series.

:func:`validate` asserts structural validity (every ``B`` has a
matching ``E``, ``B``/``E``/``i`` timestamps monotonic per track,
non-negative ``X`` durations) and is what CI runs against the uploaded
trace artefact; :func:`doc_tracks` / :func:`span_durations` /
:func:`instant_count` are the small query helpers the reconciliation
tests use to check trace sums against the metrics/v8 document.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

#: The canonical monotonic timestamp source for the serving stack.
#: ``engine``/``sched``/``monitor`` stamp through this single alias
#: instead of sprinkling their own ``time.perf_counter`` bracketing
#: (identity is preserved — ``now is time.perf_counter`` — so clock-
#: domain checks like ``clock is not time.perf_counter`` still hold).
now: Callable[[], float] = time.perf_counter


class Stopwatch:
    """The one ``t0 = clock(); ...; dt = clock() - t0`` bracketing
    helper.  Use as a context manager (``with Stopwatch() as sw: ...;
    sw.elapsed_s``) or via :meth:`start`/:meth:`stop`; the clock is
    injectable for virtual-time benches."""

    __slots__ = ("clock", "t0_s", "elapsed_s")

    def __init__(self, clock: Callable[[], float] = now):
        self.clock = clock
        self.t0_s = 0.0
        self.elapsed_s = 0.0

    def start(self) -> "Stopwatch":
        self.t0_s = self.clock()
        return self

    def stop(self) -> float:
        self.elapsed_s = self.clock() - self.t0_s
        return self.elapsed_s

    def __enter__(self) -> "Stopwatch":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


class _NullSpan:
    """The reusable disabled span: one module-wide instance, zero
    allocations per use (class attributes, empty ``__slots__``)."""

    __slots__ = ()
    t0_s = 0.0
    dur_s = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Span:
    """One live ``B``/``E`` pair.  After ``__exit__``, :attr:`dur_s`
    holds the measured duration — consumers like
    :class:`~repro.runtime.monitor.StragglerMonitor` read their step
    time from the span instead of keeping their own bracketing."""

    __slots__ = ("_tracer", "name", "track", "args", "t0_s", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t0_s = 0.0
        self.dur_s = 0.0

    def __enter__(self) -> "Span":
        self.t0_s = self._tracer.clock()
        self._tracer._emit("B", self.name, self.track, self.t0_s,
                           self.args)
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer.clock()
        self.dur_s = t1 - self.t0_s
        self._tracer._emit("E", self.name, self.track, t1, None)
        return False


class Tracer:
    """Collects trace events and renders Chrome Trace Event JSON.

    ``clock`` must be monotonic (default :data:`now` ==
    ``time.perf_counter``); timestamps are exported in microseconds
    relative to construction.  ``enabled=False`` turns every emit
    method into an immediate return and :meth:`span` into the shared
    no-allocation null span — the programmatic off switch (the serving
    hot paths additionally guard on ``tracer is None`` so the default
    un-traced run never even reaches these methods)."""

    def __init__(self, clock: Callable[[], float] = now,
                 enabled: bool = True, pid: int = 0):
        self.clock = clock
        self.enabled = bool(enabled)
        self.pid = int(pid)
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._tids: Dict[str, int] = {}
        self._t0 = clock()

    # -- internals ------------------------------------------------------------
    def _ts_us(self, t_s: float) -> float:
        return (t_s - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        """Track name -> tid, registering (and emitting the
        ``thread_name`` metadata event) on first use.  Caller holds the
        lock."""
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
            self._events.append(dict(name="thread_name", ph="M",
                                     pid=self.pid, tid=tid,
                                     args=dict(name=track)))
        return tid

    def _emit(self, ph: str, name: str, track: str, t_s: float,
              args: Optional[Dict[str, Any]]) -> None:
        with self._lock:
            ev: Dict[str, Any] = dict(name=name, ph=ph, pid=self.pid,
                                      tid=self._tid(track),
                                      ts=self._ts_us(t_s))
            if ph == "i":
                ev["s"] = "t"          # thread-scoped instant
            if args:
                ev["args"] = args
            self._events.append(ev)

    # -- emit API -------------------------------------------------------------
    def span(self, name: str, track: str = "main", **args):
        """A ``with``-able duration span on ``track``.  Enter emits
        ``B``, exit emits ``E`` and records ``dur_s``.  Spans on one
        track must nest (single-emitter tracks); concurrent emitters
        should use :meth:`complete` instead."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, track, args or None)

    def instant(self, name: str, track: str = "main", **args) -> None:
        """A zero-duration marker (``i``): admission verdicts,
        preemptions, evictions, straggler flags."""
        if not self.enabled:
            return
        self._emit("i", name, track, self.clock(), args or None)

    def counter(self, name: str, track: str = "main", **values) -> None:
        """A counter sample (``C``) — Perfetto renders each key of
        ``values`` as a stacked series (e.g. pool occupancy bytes)."""
        if not self.enabled:
            return
        self._emit("C", name, track, self.clock(), values)

    def complete(self, name: str, dur_s: float, track: str = "main",
                 end_offset_s: float = 0.0, **args) -> None:
        """One already-finished span (``X``) ending ``end_offset_s``
        seconds before *now* with duration ``dur_s`` — the shape for
        worker-thread page fetches (measured locally, emitted once
        done) and for retro-dating stall spans whose window closed
        before the accounting ran."""
        if not self.enabled:
            return
        t1 = self.clock() - end_offset_s
        with self._lock:
            ev: Dict[str, Any] = dict(
                name=name, ph="X", pid=self.pid, tid=self._tid(track),
                ts=self._ts_us(t1 - max(dur_s, 0.0)),
                dur=max(dur_s, 0.0) * 1e6)
            if args:
                ev["args"] = args
            self._events.append(ev)

    def now(self) -> float:
        """The tracer's clock — instrumented code stamps through this so
        span math stays in one clock domain."""
        return self.clock()

    # -- introspection / export ----------------------------------------------
    @property
    def event_count(self) -> int:
        """Emitted events, excluding track-name metadata."""
        with self._lock:
            return sum(1 for e in self._events if e["ph"] != "M")

    @property
    def track_names(self) -> List[str]:
        with self._lock:
            return list(self._tids)

    def summary(self) -> Dict[str, Any]:
        return dict(events=self.event_count, tracks=self.track_names)

    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            return {"traceEvents": [dict(e) for e in self._events],
                    "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")

    def validate(self) -> Dict[str, Any]:
        return validate(self.to_dict())


# ---------------------------------------------------------------------------
# validation + query helpers (what CI and the reconciliation tests run)
# ---------------------------------------------------------------------------

_KNOWN_PH = ("B", "E", "X", "i", "C", "M")


def validate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Assert ``doc`` is structurally valid Chrome Trace Event JSON:

      * a dict with a ``traceEvents`` list, every event carrying
        ``name``/``ph``/``pid``/``tid`` (plus ``ts`` for non-metadata);
      * every ``B`` closed by a matching same-name ``E`` on its
        (pid, tid) track, properly nested;
      * ``B``/``E``/``i`` timestamps non-decreasing per track (the
        single-emitter invariant; ``X`` events are retro-dated by
        design and are only required to have non-negative durations).

    Returns the document unchanged; raises ValueError naming the first
    violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a trace document: missing 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' is not a list")
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    last_ts: Dict[Tuple[Any, Any], float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} missing {k!r}")
        ph = ev["ph"]
        if ph not in _KNOWN_PH:
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if ph == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event {i} ({ev['name']!r}) missing 'ts'")
        key = (ev["pid"], ev["tid"])
        if ph in ("B", "E", "i"):
            # 1 ns slack: float µs round-trips through JSON
            if ev["ts"] + 1e-3 < last_ts.get(key, float("-inf")):
                raise ValueError(
                    f"event {i} ({ev['name']!r}): ts went backwards on "
                    f"track {key}")
            last_ts[key] = max(last_ts.get(key, float("-inf")), ev["ts"])
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"event {i}: 'E' {ev['name']!r} "
                                 f"without an open 'B' on track {key}")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(f"event {i}: 'E' {ev['name']!r} closes "
                                 f"'B' {top!r} on track {key}")
        elif ph == "X":
            if ev.get("dur", 0.0) < 0.0:
                raise ValueError(f"event {i} ({ev['name']!r}): negative "
                                 f"'X' duration")
    for key, stack in stacks.items():
        if stack:
            raise ValueError(f"unclosed 'B' events {stack} on track {key}")
    return doc


def doc_tracks(doc: Dict[str, Any]) -> List[str]:
    """Track names in tid registration order, from the ``thread_name``
    metadata events."""
    out = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            out.append(ev.get("args", {}).get("name", ""))
    return out


def _track_tids(doc: Dict[str, Any], track: Optional[str]
                ) -> Optional[set]:
    if track is None:
        return None
    return {ev["tid"] for ev in doc.get("traceEvents", [])
            if ev.get("ph") == "M" and ev.get("name") == "thread_name"
            and ev.get("args", {}).get("name") == track}


def span_durations(doc: Dict[str, Any], name: str,
                   track: Optional[str] = None) -> List[float]:
    """Durations (seconds) of every completed span called ``name`` —
    matched ``B``/``E`` pairs and ``X`` events alike, optionally
    restricted to one track."""
    tids = _track_tids(doc, track)
    out: List[float] = []
    open_b: Dict[Tuple[Any, Any], List[Tuple[str, float]]] = {}
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "M" or (tids is not None and ev.get("tid") not in tids):
            continue
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X" and ev["name"] == name:
            out.append(ev.get("dur", 0.0) / 1e6)
        elif ph == "B":
            open_b.setdefault(key, []).append((ev["name"], ev["ts"]))
        elif ph == "E":
            stack = open_b.get(key)
            if stack:
                b_name, b_ts = stack.pop()
                if b_name == name:
                    out.append((ev["ts"] - b_ts) / 1e6)
    return out


def instant_count(doc: Dict[str, Any], name: str,
                  track: Optional[str] = None) -> int:
    """How many ``i`` events called ``name`` the trace holds."""
    tids = _track_tids(doc, track)
    return sum(1 for ev in doc.get("traceEvents", [])
               if ev.get("ph") == "i" and ev.get("name") == name
               and (tids is None or ev.get("tid") in tids))

"""Serving metrics — the observability half of the XR serving scheduler.

The paper's system claim is a *latency bound*, not a throughput number:
Siracusa must finish the whole heterogeneous workload inside the 10–20 ms
XR frame budget.  So the serving runtime records exactly the quantities
that bound makes interesting: per-request time-to-first-token and
end-to-end latency, per-tick engine latency, paging stalls (the §II-B2
cost of exceeding on-chip capacity) split into *exposed* wait (time that
actually blocked a tick) and *hidden* overlap (stream time absorbed
behind compute by the async paging pipeline), deadline-miss rate per
stream, and aggregate token throughput.

Everything is emitted as one JSON document (schema
``repro.serving.metrics/v9``) so the bench trajectory
(``benchmarks/serving_load.py`` -> ``BENCH_serving.json``) and the
launcher (``repro.launch.serve --metrics-json``) share a format:

    {
      "schema": "repro.serving.metrics/v9",
      "ticks":      {"count", "latency_ms": {mean,p50,p99,max},
                     "paging_exposed_ms": {mean,p50,p99,max},
                     "paging_hidden_ms":  {mean,p50,p99,max}},
      "requests":   {"count", "tokens_out", "truncated",
                     "ttft_ms": {mean,p50,p99,max},
                     "latency_ms": {mean,p50,p99,max}},
      "deadlines":  {"with_deadline", "missed", "miss_rate", "truncated"},
      "scheduler":  {"preemptions", "restores", "rejected", "degraded",
                     "budget_tokens_per_tick", "budget_used_mean",
                     "budget_utilization"},
      "throughput": {"wall_s", "tok_per_s"},
      "paging":     {"swap_count", "miss_count", "exposed_s", "hidden_s",
                     "overlap_frac", "stall_s", "n_pages",
                     "bytes_streamed_raw", "bytes_streamed_wire",
                     "kv_swaps", "kv_pool_hits", "kv_writebacks",
                     "kv_dropped", "kv_preempt_drops", "kv_exposed_s",
                     "kv_hidden_s", "kv_block_rows",
                     "devices": [{"device", "n_pages", "swap_count",
                                  "miss_count", "bytes_streamed_wire",
                                  "bytes_streamed_raw"}]},
      "trace":      {"events", "tracks",
                     "predicted_vs_measured_stall_ratio"},
      "faults":     {"injected", "retries", "checksum_failures",
                     "refetches", "fetch_timeouts", "deferred_ticks"},
      "streams":    {name: {"count", "missed", "miss_rate", "truncated",
                            "p99_ttft_ms"}}
    }

Latencies are milliseconds; a request's deadline is met when its
*end-to-end* latency (arrival -> last token) is within ``deadline_ms``.
Requests without a deadline never count toward the miss rate, and
*truncated* requests (retired by KV-cache exhaustion, i.e. partial
service) are excluded from it and reported under their own counter.
Requests the admission controller REJECTED never became requests at all
(no service, no tokens): they appear only in ``scheduler.rejected``.

v9 vs v8: the ``paging`` section grew ``devices`` — the per-device
counter rows of a mesh-sharded paged run (``--mesh NxM``): one entry per
device link carrying ``device``, ``n_pages``, ``swap_count``,
``miss_count`` and the wire/raw byte ledger for that link alone, so the
global ``paging`` counters are auditable as the SUM of their per-device
split (the :class:`~repro.core.paging.ShardedPoolLedger` aggregation).
An unsharded run reports ``devices: []`` — the list's *presence* is what
marks a v9 payload; an empty list just means one device.
:func:`validate` rejects v8 payloads — wrong schema string, or a
``paging`` section without ``devices``.

v8 vs v7: the ``faults`` section is new — fault-tolerant page I/O
(``repro.core.faults``): counts of injected faults, fetch ``retries``,
CRC32 ``checksum_failures`` caught before install, the ``refetches``
they triggered, ``fetch_timeouts`` raised by deadline-bounded fences,
and ``deferred_ticks`` — ticks the scheduler degraded gracefully
(skipped compute, left the pass resumable) instead of blocking past the
fetch deadline.  All zeros for a fault-free, deadline-free run.  The
multi shape's ``totals`` grows a summed ``faults`` dict with the same
keys.  :func:`validate` rejects v7 payloads — wrong schema string, or a
document without the ``faults`` section.

v7 vs v6: the ``paging`` section grew the encoded-pages byte ledger —
``bytes_streamed_wire`` (bytes that actually crossed the host->device
link: encoded payloads + their scales) and ``bytes_streamed_raw`` (the
fp32-dense-equivalent an unencoded stream would have moved; equal to
wire when pages stream in the ``"fp"`` encoding, i.e. nothing claimed
compression).  Their ratio is the run's page-compression factor.  The
multi shape's ``shared_pool`` section (and each of its per-model
entries) carries the same two keys, plus ``live_wire_bytes`` next to
``live_bytes``.  :func:`validate` rejects v6 payloads — wrong schema
string, or a ``paging`` section without the byte ledger.

v6 vs v5: the ``trace`` section is new — chrome-trace observability
(``repro.serving.trace``): the tracer's event/track counts (zeros for an
un-traced run) and ``predicted_vs_measured_stall_ratio``, the run's
summed closed-form exposed-stall prediction
(:func:`repro.core.memsys.overlap_stall` over each fenced pass's
swap/window split) over the fence-measured exposure — 1.0 means the
stall model matched reality, vacuously so when nothing paged.
:func:`validate` rejects v5 payloads — wrong schema string, or missing
``trace`` section.

v5 vs v4: the ``scheduler`` section is new — continuous-batching
observability (mid-request ``preemptions`` and ``restores``, admission
control's ``rejected`` / ``degraded`` verdicts, and the per-tick token
budget's mean use / utilization; all zero for an unbudgeted
run-to-completion scheduler) — and ``paging`` grew
``kv_preempt_drops``, the subset of ``kv_dropped`` block invalidations
caused by preemption rather than retirement.  :func:`validate` rejects
v4 payloads — wrong schema string, or missing ``scheduler`` section.
(v4 vs v3: the ``paging`` section grew the ``kv_*`` fields — the
KV-cache share of the same budgeted page stream.  v3 vs v2: the
per-tick ``paging_stall_ms`` became the ``paging_exposed_ms`` /
``paging_hidden_ms`` pair; ``stall_s`` is kept as an alias of
``exposed_s``.)

Multi-model tenancy (``repro.serving.tenancy.MultiScheduler``) emits the
v9 *multi* shape instead: per-model sections of the document above plus
the shared page pool's contention stats (KV page tables appear as their
own ``<model>/kv`` members)::

    {
      "schema": "repro.serving.metrics/v9",
      "ticks":       {"count"},                     # MultiScheduler ticks
      "models":      {name: <single-model document, sans schema>},
      "shared_pool": {"budget_bytes", "live_bytes", "live_wire_bytes",
                      "cached_pages", "evictions",
                      "bytes_streamed_wire", "bytes_streamed_raw",
                      "models": {name: {"swaps", "misses", "pool_hits",
                                        "evicted", "exposed_s",
                                        "hidden_s", "n_pages",
                                        "bytes_streamed_wire",
                                        "bytes_streamed_raw"}}},
      "totals":      {"requests", "tokens_out", "truncated",
                      "with_deadline", "missed", "miss_rate",
                      "preemptions", "restores", "rejected", "degraded",
                      "wall_s", "tok_per_s",
                      "paging_exposed_s", "paging_hidden_s",
                      "overlap_frac",
                      "faults": {summed per-model fault counters}}
    }

The ``totals`` paging seconds are summed from the per-model ``paging``
sections ONLY — the ``shared_pool`` per-model stalls are the pool's view
of the *same* wall time the engines already report, so adding both would
double-count every pooled pass (the v2-era double-attribution risk).

:func:`validate` checks either shape and is what CI asserts against the
uploaded ``BENCH_serving.json`` artefact.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

SCHEMA = "repro.serving.metrics/v9"


def quantiles(xs: List[float]) -> Dict[str, float]:
    """{mean, p50, p99, max} of a latency sample, in the sample's units."""
    if not xs:
        return dict(mean=0.0, p50=0.0, p99=0.0, max=0.0)
    a = np.asarray(xs, np.float64)
    return dict(mean=float(a.mean()), p50=float(np.percentile(a, 50)),
                p99=float(np.percentile(a, 99)), max=float(a.max()))


def _empty_paging() -> Dict[str, Any]:
    return dict(swap_count=0, miss_count=0, exposed_s=0.0, hidden_s=0.0,
                overlap_frac=0.0, stall_s=0.0, n_pages=0,
                bytes_streamed_raw=0, bytes_streamed_wire=0,
                kv_swaps=0, kv_pool_hits=0, kv_writebacks=0, kv_dropped=0,
                kv_preempt_drops=0,
                kv_exposed_s=0.0, kv_hidden_s=0.0, kv_block_rows=0,
                devices=[])


def _empty_faults() -> Dict[str, int]:
    # the fault-free default: nothing injected, nothing retried, no
    # deadline ever missed — what a run without a FaultPlan reports
    return dict(injected=0, retries=0, checksum_failures=0, refetches=0,
                fetch_timeouts=0, deferred_ticks=0)


def _empty_trace() -> Dict[str, Any]:
    # the un-traced default: no events, no tracks, and a drift ratio of
    # 1.0 (predicted == measured, vacuously — nothing paged or no
    # accumulation ran)
    return dict(events=0, tracks=[],
                predicted_vs_measured_stall_ratio=1.0)


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one finished request (seconds, recorder
    clock).  Derived metrics are properties so the aggregation below and
    ad-hoc inspection agree by construction."""

    uid: int
    stream: str = "default"
    priority: int = 0
    deadline_ms: Optional[float] = None
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    n_prompt: int = 0
    n_generated: int = 0
    truncated: bool = False

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def deadline_met(self) -> Optional[bool]:
        """None when the request carries no deadline."""
        if self.deadline_ms is None:
            return None
        lat = self.latency_s
        return lat is not None and lat * 1e3 <= self.deadline_ms


class MetricsRecorder:
    """Accumulates tick- and request-level events; renders the v5 JSON."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.tick_latency_s: List[float] = []
        self.tick_exposed_s: List[float] = []
        self.tick_hidden_s: List[float] = []
        self.records: List[RequestRecord] = []
        # continuous-batching events (v5 "scheduler" section)
        self.preemptions = 0
        self.restores = 0
        self.rejected = 0
        self.degraded = 0
        self.budget_tokens: Optional[int] = None
        self.tick_budget_used: List[int] = []
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- event intake ---------------------------------------------------------
    def start(self) -> None:
        if self._t0 is None:
            self._t0 = self.clock()

    def record_tick(self, latency_s: float, paging_exposed_s: float = 0.0,
                    paging_hidden_s: float = 0.0,
                    budget_tokens: Optional[int] = None,
                    budget_used: Optional[int] = None) -> None:
        """One tick: its wall latency, the paging wait that actually
        blocked it (*exposed*), and the stream time the async pipeline
        hid behind compute (*hidden*; 0 for synchronous streaming).
        Budgeted continuous-batching ticks also report the per-tick
        token budget and the tokens the tick's plan actually scheduled
        (``budget_used`` may exceed ``budget_tokens`` — exact-length
        prefill families absorb whole prompts, a documented overrun)."""
        self.start()
        self.tick_latency_s.append(float(latency_s))
        self.tick_exposed_s.append(float(paging_exposed_s))
        self.tick_hidden_s.append(float(paging_hidden_s))
        if budget_tokens is not None:
            self.budget_tokens = int(budget_tokens)
        if budget_used is not None:
            self.tick_budget_used.append(int(budget_used))
        self._t_last = self.clock()

    def record_preemption(self) -> None:
        """One mid-request slot eviction (the victim's state checkpoints
        host-ward and its pooled KV blocks drop)."""
        self.preemptions += 1

    def record_restore(self) -> None:
        """One preempted request rebound to a slot (bit-exact resume)."""
        self.restores += 1

    def record_rejected(self) -> None:
        """Admission control refused a request outright: its predicted
        completion already missed the deadline, so queuing it would only
        have manufactured a guaranteed miss."""
        self.rejected += 1

    def record_degraded(self) -> None:
        """Admission control shortened a request's ``max_new_tokens`` to
        the longest completion that still fits its deadline."""
        self.degraded += 1

    def record_request(self, req: Any) -> RequestRecord:
        """Fold a finished engine Request (duck-typed: uid, prompt,
        generated, plus the scheduler-stamped fields) into a record."""
        rec = RequestRecord(
            uid=req.uid,
            stream=getattr(req, "stream", "default") or "default",
            priority=getattr(req, "priority", 0) or 0,
            deadline_ms=getattr(req, "deadline_ms", None),
            arrival_s=getattr(req, "arrival_s", 0.0) or 0.0,
            first_token_s=getattr(req, "first_token_s", None),
            finish_s=getattr(req, "finish_s", None),
            n_prompt=len(req.prompt),
            n_generated=len(req.generated),
            truncated=bool(getattr(req, "truncated", False)),
        )
        self.records.append(rec)
        return rec

    # -- aggregation ----------------------------------------------------------
    @property
    def wall_s(self) -> float:
        if self._t0 is None or self._t_last is None:
            return 0.0
        return self._t_last - self._t0

    def summary(self, paging: Optional[Dict[str, Any]] = None,
                trace: Optional[Dict[str, Any]] = None,
                faults: Optional[Dict[str, int]] = None
                ) -> Dict[str, Any]:
        ttfts = [r.ttft_s * 1e3 for r in self.records if r.ttft_s is not None]
        lats = [r.latency_s * 1e3 for r in self.records
                if r.latency_s is not None]
        # truncated requests got partial service (KV cache ran out): they
        # are excluded from the miss rate and labeled under their own key
        with_dl = [r for r in self.records
                   if r.deadline_ms is not None and not r.truncated]
        trunc_dl = [r for r in self.records
                    if r.deadline_ms is not None and r.truncated]
        missed = [r for r in with_dl if r.deadline_met is False]
        tokens = sum(r.n_generated for r in self.records)
        wall = max(self.wall_s, 1e-9)

        streams: Dict[str, Dict[str, Any]] = {}
        for name in sorted({r.stream for r in self.records}):
            rs = [r for r in self.records if r.stream == name]
            rs_dl = [r for r in rs
                     if r.deadline_ms is not None and not r.truncated]
            rs_missed = [r for r in rs_dl if r.deadline_met is False]
            rs_ttft = [r.ttft_s * 1e3 for r in rs if r.ttft_s is not None]
            streams[name] = dict(
                count=len(rs), missed=len(rs_missed),
                miss_rate=(len(rs_missed) / len(rs_dl)) if rs_dl else 0.0,
                truncated=sum(1 for r in rs if r.truncated),
                p99_ttft_ms=quantiles(rs_ttft)["p99"])

        return {
            "schema": SCHEMA,
            "ticks": {
                "count": len(self.tick_latency_s),
                "latency_ms": quantiles([t * 1e3
                                         for t in self.tick_latency_s]),
                "paging_exposed_ms": quantiles([t * 1e3
                                                for t in self.tick_exposed_s]),
                "paging_hidden_ms": quantiles([t * 1e3
                                               for t in self.tick_hidden_s]),
            },
            "requests": {
                "count": len(self.records),
                "tokens_out": tokens,
                "truncated": sum(1 for r in self.records if r.truncated),
                "ttft_ms": quantiles(ttfts),
                "latency_ms": quantiles(lats),
            },
            "deadlines": {
                "with_deadline": len(with_dl),
                "missed": len(missed),
                "miss_rate": (len(missed) / len(with_dl)) if with_dl else 0.0,
                "truncated": len(trunc_dl),
            },
            "scheduler": self._scheduler_section(),
            "throughput": {
                "wall_s": self.wall_s,
                "tok_per_s": tokens / wall,
            },
            "paging": dict(paging if paging is not None else _empty_paging()),
            "trace": dict(trace if trace is not None else _empty_trace()),
            # store-level fault dicts may lack the scheduler-level
            # "deferred_ticks"; the empty template fills any gap
            "faults": {**_empty_faults(), **(faults or {})},
            "streams": streams,
        }

    def _scheduler_section(self) -> Dict[str, Any]:
        used = self.tick_budget_used
        mean_used = (sum(used) / len(used)) if used else 0.0
        budget = self.budget_tokens or 0
        return {
            "preemptions": self.preemptions,
            "restores": self.restores,
            "rejected": self.rejected,
            "degraded": self.degraded,
            "budget_tokens_per_tick": budget,
            "budget_used_mean": mean_used,
            "budget_utilization": (mean_used / budget) if budget else 0.0,
        }

    def to_json(self, paging: Optional[Dict[str, Any]] = None,
                trace: Optional[Dict[str, Any]] = None,
                faults: Optional[Dict[str, int]] = None, **extra) -> str:
        doc = self.summary(paging=paging, trace=trace, faults=faults)
        doc.update(extra)
        return json.dumps(doc, indent=2, sort_keys=False)

    def write(self, path: str, paging: Optional[Dict[str, Any]] = None,
              trace: Optional[Dict[str, Any]] = None,
              faults: Optional[Dict[str, int]] = None, **extra) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json(paging=paging, trace=trace,
                                  faults=faults, **extra)
                     + "\n")


# ---------------------------------------------------------------------------
# multi-model tenancy (metrics/v8 multi shape)
# ---------------------------------------------------------------------------

def multi_summary(models: Dict[str, Dict[str, Any]],
                  shared_pool: Optional[Dict[str, Any]] = None,
                  ticks: int = 0) -> Dict[str, Any]:
    """Assemble the multi-model document from per-model single-model
    summaries (as produced by :meth:`MetricsRecorder.summary`) plus the
    shared pool's :meth:`~repro.core.paging.SharedPagePool.summary`.

    The totals' paging seconds are summed from the per-model ``paging``
    sections alone; ``shared_pool.models[*].exposed_s/hidden_s`` are the
    pool's view of the SAME wall time (one pass, two vantage points), so
    they are deliberately NOT added — that would double-count every
    pooled pass."""
    sections = {}
    for name, doc in models.items():
        doc = dict(doc)
        doc.pop("schema", None)
        sections[name] = doc
    n_req = sum(d["requests"]["count"] for d in sections.values())
    tokens = sum(d["requests"]["tokens_out"] for d in sections.values())
    trunc = sum(d["requests"]["truncated"] for d in sections.values())
    with_dl = sum(d["deadlines"]["with_deadline"] for d in sections.values())
    missed = sum(d["deadlines"]["missed"] for d in sections.values())
    exposed = sum(d["paging"].get("exposed_s", 0.0)
                  for d in sections.values())
    hidden = sum(d["paging"].get("hidden_s", 0.0)
                 for d in sections.values())
    sched_totals = {k: sum(d.get("scheduler", {}).get(k, 0)
                           for d in sections.values())
                    for k in ("preemptions", "restores", "rejected",
                              "degraded")}
    fault_totals = {k: sum(int(d.get("faults", {}).get(k, 0))
                           for d in sections.values())
                    for k in _empty_faults()}
    # the tenants share one wall clock window, so aggregate throughput is
    # total tokens over the longest per-model span, not the sum of spans
    wall = max((d["throughput"]["wall_s"] for d in sections.values()),
               default=0.0)
    return {
        "schema": SCHEMA,
        "ticks": {"count": int(ticks)},
        "models": sections,
        "shared_pool": dict(shared_pool) if shared_pool else {},
        "totals": {
            "requests": n_req,
            "tokens_out": tokens,
            "truncated": trunc,
            "with_deadline": with_dl,
            "missed": missed,
            "miss_rate": (missed / with_dl) if with_dl else 0.0,
            **sched_totals,
            "wall_s": wall,
            "tok_per_s": tokens / max(wall, 1e-9),
            "paging_exposed_s": exposed,
            "paging_hidden_s": hidden,
            "overlap_frac": (hidden / (exposed + hidden)
                             if (exposed + hidden) > 0 else 0.0),
            "faults": fault_totals,
        },
    }


_SINGLE_KEYS = {
    "ticks": ("count", "latency_ms", "paging_exposed_ms",
              "paging_hidden_ms"),
    "requests": ("count", "tokens_out", "truncated", "ttft_ms",
                 "latency_ms"),
    "deadlines": ("with_deadline", "missed", "miss_rate", "truncated"),
    # v5: continuous-batching observability — its absence is exactly
    # what marks a stale v4 payload
    "scheduler": ("preemptions", "restores", "rejected", "degraded",
                  "budget_tokens_per_tick", "budget_used_mean",
                  "budget_utilization"),
    "throughput": ("wall_s", "tok_per_s"),
    "paging": ("swap_count", "miss_count", "exposed_s", "hidden_s",
               "overlap_frac", "n_pages",
               # v7: encoded-pages byte ledger — its absence is exactly
               # what marks a stale v6 payload
               "bytes_streamed_raw", "bytes_streamed_wire",
               # v4: the KV-cache share of the same page stream
               "kv_swaps", "kv_pool_hits", "kv_writebacks", "kv_dropped",
               # v5: preemption's share of the dropped blocks
               "kv_preempt_drops",
               "kv_exposed_s", "kv_hidden_s", "kv_block_rows",
               # v9: per-device split of a mesh-sharded run — its
               # presence (even as []) is exactly what marks a stale v8
               # payload
               "devices"),
    # v6: chrome-trace observability — its absence is exactly what marks
    # a stale v5 payload
    "trace": ("events", "tracks", "predicted_vs_measured_stall_ratio"),
    # v8: fault-tolerant page I/O — its absence is exactly what marks a
    # stale v7 payload
    "faults": ("injected", "retries", "checksum_failures", "refetches",
               "fetch_timeouts", "deferred_ticks"),
}

_TOTALS_KEYS = ("requests", "tokens_out", "truncated", "with_deadline",
                "missed", "miss_rate",
                "preemptions", "restores", "rejected", "degraded",
                "wall_s", "tok_per_s",
                "paging_exposed_s", "paging_hidden_s", "overlap_frac",
                "faults")


def _validate_single(doc: Dict[str, Any], where: str) -> None:
    for section, keys in _SINGLE_KEYS.items():
        if section not in doc:
            raise ValueError(f"{where}: missing section {section!r}")
        for k in keys:
            if k not in doc[section]:
                raise ValueError(f"{where}: missing {section}.{k}")
    if "streams" not in doc:
        raise ValueError(f"{where}: missing section 'streams'")
    for name, s in doc["streams"].items():
        for k in ("count", "missed", "miss_rate", "truncated",
                  "p99_ttft_ms"):
            if k not in s:
                raise ValueError(f"{where}: missing streams.{name}.{k}")


def validate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Assert ``doc`` is a well-formed ``repro.serving.metrics/v9``
    document (either the single-model or the multi-model shape); returns
    the document unchanged so it can be used inline.  Raises ValueError
    naming the first missing piece."""
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    if "models" in doc:
        if not doc["models"]:
            raise ValueError("multi document with an empty 'models' map")
        for section in ("shared_pool", "totals", "ticks"):
            if section not in doc:
                raise ValueError(f"multi document missing {section!r}")
        for k in _TOTALS_KEYS:
            if k not in doc["totals"]:
                raise ValueError(f"multi document missing totals.{k}")
        for name, sub in doc["models"].items():
            _validate_single(sub, where=f"models.{name}")
        pool = doc["shared_pool"]
        if pool:
            for k in ("budget_bytes", "live_bytes", "live_wire_bytes",
                      "cached_pages", "evictions",
                      "bytes_streamed_wire", "bytes_streamed_raw",
                      "models"):
                if k not in pool:
                    raise ValueError(f"shared_pool missing {k!r}")
            for name, c in pool["models"].items():
                for k in ("swaps", "misses", "pool_hits", "evicted",
                          "exposed_s", "hidden_s", "n_pages",
                          "bytes_streamed_wire", "bytes_streamed_raw"):
                    if k not in c:
                        raise ValueError(
                            f"shared_pool.models.{name} missing {k!r}")
    else:
        _validate_single(doc, where="document")
    return doc

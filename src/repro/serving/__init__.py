from repro.serving.engine import (Request, ServingEngine, sample_token,
                                  sample_token_batch)
from repro.serving.metrics import MetricsRecorder, RequestRecord
from repro.serving.sched import Scheduler, StreamSpec

__all__ = ["ServingEngine", "Request", "sample_token", "sample_token_batch",
           "Scheduler", "StreamSpec", "MetricsRecorder", "RequestRecord"]

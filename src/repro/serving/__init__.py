from repro.serving.engine import (Request, ServingEngine, SlotCheckpoint,
                                  sample_token, sample_token_batch)
from repro.serving.metrics import (MetricsRecorder, RequestRecord,
                                   multi_summary, validate)
from repro.serving.sched import Scheduler, StreamSpec
from repro.serving.tenancy import MultiScheduler
from repro.serving.trace import Stopwatch, Tracer

__all__ = ["ServingEngine", "Request", "SlotCheckpoint", "sample_token",
           "sample_token_batch", "Scheduler", "StreamSpec", "MultiScheduler",
           "MetricsRecorder", "RequestRecord", "multi_summary", "validate",
           "Tracer", "Stopwatch"]

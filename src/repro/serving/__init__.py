from repro.serving.engine import ServingEngine, Request, sample_token

__all__ = ["ServingEngine", "Request", "sample_token"]

"""LLaVA-NeXT-style VLM backbone (llava-next-34b assignment).

The anyres vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, d_model), standing in
for the CLIP tower + anyres tiling + projector.  The language backbone is
the full decoder LM (models/transformer.py); patches are prepended to the
token embeddings, as the real model splices projected image features into
the prompt.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    return tfm.init_params(cfg, key)


def forward(params: Dict[str, Any], tokens: jax.Array,
            patches: jax.Array, cfg: ModelConfig, *,
            engine: Optional[Dict] = None) -> jax.Array:
    """tokens (B, S_text), patches (B, P, D) -> logits over S_text + P."""
    return tfm.forward(params, tokens, cfg, engine=engine,
                       extra_embeds=patches)


def vlm_loss(params: Dict[str, Any], batch: Dict[str, jax.Array],
             cfg: ModelConfig, *, engine: Optional[Dict] = None) -> jax.Array:
    """Loss over text positions only (image patches carry no labels)."""
    return tfm.lm_loss(params, batch, cfg, engine=engine)


def prefill(params: Dict[str, Any], tokens: jax.Array, patches: jax.Array,
            cache: Dict[str, Any], cfg: ModelConfig, *,
            engine: Optional[Dict] = None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Serve-path prefill: embed patches+tokens, fill the KV cache.

    Patch embeddings enter the cache like ordinary positions (the real
    system does exactly this — image tokens are just prompt positions).
    """
    logits, cache = tfm.step(params, tokens, cache, jnp.int32(0), cfg,
                             engine=engine, extra_embeds=patches)
    return logits, cache


def decode_step(params: Dict[str, Any], token: jax.Array,
                cache: Dict[str, Any], pos: jax.Array, cfg: ModelConfig, *,
                engine: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    return tfm.step(params, token, cache, pos, cfg, engine=engine)

"""Mamba-1 selective SSM (falcon-mamba-7b; hymba's SSM heads).

Train/prefill uses a chunked parallel scan (lax.scan over sequence chunks,
associative scan inside a chunk) so the (B, S, d_inner, N) discretized
tensors never materialize beyond one chunk — the VMEM-bounded discipline
again.  Decode is the O(1) recurrent update carrying (h, conv window).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.placement import dp_axes_of
from repro.models import layers


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array],
                  state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over sequence.  x: (B, S, C), w: (C, K).

    Returns (y, new_state) with state = last K-1 inputs (B, K-1, C).
    """
    bsz, s, c = x.shape
    k = w.shape[1]
    if state is None:
        state = jnp.zeros((bsz, k - 1, c), x.dtype)
    xe = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(k):
        y = y + xe[:, i:i + s, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_state = xe[:, s:, :] if k > 1 else state
    return y.astype(x.dtype), new_state


def _ssm_chunk_scan(dA: jax.Array, dBx: jax.Array, h0: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """h_t = dA_t * h_{t-1} + dBx_t within one chunk via associative scan.

    dA, dBx: (B, T, Di, N); h0: (B, Di, N).  Returns (h_all, h_last).
    """
    def comb(a, b):
        a_a, a_b = a
        b_a, b_b = b
        return a_a * b_a, b_a * a_b + b_b

    aa, bb = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
    h_all = aa * h0[:, None] + bb
    return h_all, h_all[:, -1]


def selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array, h0: Optional[jax.Array] = None,
                   chunk: int = 256,
                   compute_dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Selective SSM over a sequence.

    x, dt: (Bz, S, Di);  A: (Di, N);  B, C: (Bz, S, N);  D: (Di,).
    Returns (y (Bz, S, Di), h_last (Bz, Di, N)).
    """
    bsz, s, di = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nchunks = (s + pad) // chunk

    xc = jnp.moveaxis(x.reshape(bsz, nchunks, chunk, di), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(bsz, nchunks, chunk, di), 1, 0)
    Bc = jnp.moveaxis(B.reshape(bsz, nchunks, chunk, n), 1, 0)
    Cc = jnp.moveaxis(C.reshape(bsz, nchunks, chunk, n), 1, 0)

    def step(h, xs):
        # compute_dtype=bf16 halves the HBM traffic of the (B,T,Di,N)
        # discretized tensors; the carried state h stays f32 for stability.
        xk, dtk, bk, ck = (v.astype(compute_dtype) for v in xs)
        dA = jnp.exp(dtk.astype(jnp.float32)[..., None]
                     * A[None, None]).astype(compute_dtype)   # (B,T,Di,N)
        dBx = dtk[..., None] * bk[:, :, None, :] * xk[..., None]
        h_all, h_last = _ssm_chunk_scan(dA.astype(compute_dtype),
                                        dBx.astype(compute_dtype),
                                        h.astype(compute_dtype))
        y = jnp.einsum("btdn,btn->btd", h_all, ck,
                       preferred_element_type=jnp.float32)
        return h_last.astype(jnp.float32), y

    h_last, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, di)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * D[None, None]
    return y, h_last


def ssm_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, D: jax.Array, h: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  x, dt: (Bz, Di); B, C: (Bz, N); h: (Bz, Di, N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * A[None])                    # (Bz, Di, N)
    dBx = dtf[..., None] * B[:, None, :].astype(jnp.float32) * xf[..., None]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32))
    y = y + xf * D[None]
    return y, h


def mamba_mixer(x: jax.Array, p: Dict[str, Any], *, d_inner: int,
                ssm_state: int, dt_rank: int, conv_k: int = 4,
                chunk: int = 256, scan_dtype=jnp.float32,
                shard_inner: bool = False,
                state: Optional[Dict[str, jax.Array]] = None,
                lengths: Optional[jax.Array] = None,
                engine: Optional[Dict[str, Any]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-1 mixer.  x: (B, S, D) -> (B, S, D).

    ``state`` (decode): {"h": (B, Di, N), "conv": (B, K-1, Di)}.

    ``lengths`` (B,) marks right-padded rows (pow2-bucketed chunked
    prefill): positions >= lengths[b] are *state no-ops* — their dt is
    masked to zero, so dA = exp(0·A) = 1 and dBx = 0 are exact identity
    elements of the scan, and the carried conv window is gathered from
    the last K-1 REAL inputs.  The returned state is therefore bit-
    independent of the pad content (y at pad positions is garbage the
    caller must ignore)."""
    decode = state is not None and x.shape[1] == 1

    xz = layers.linear(x, p["in_proj"], engine=engine,
                       path="layers/ssm/in_proj")                    # (B,S,2*Di)
    if shard_inner and dp_axes_of(engine):
        from jax.sharding import PartitionSpec as P
        xz = jax.lax.with_sharding_constraint(
            xz, P(dp_axes_of(engine), None, "model"))
    xs, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(xs, p["conv_w"], p.get("conv_b"), conv_state)
    if (not decode) and lengths is not None and state is not None:
        # the carried conv window must hold the last K-1 *real* inputs,
        # not the pads: token t sits at index K-1+t of [state ; x], so
        # the window after n real tokens is ext[:, n : n+K-1) — a per-row
        # gather (conv OUTPUTS at real positions are already exact, since
        # pads are strictly to the right of every real tap)
        kk = p["conv_w"].shape[1]
        if kk > 1:
            cs = (conv_state if conv_state is not None
                  else jnp.zeros((xs.shape[0], kk - 1, xs.shape[2]),
                                 xs.dtype))
            ext = jnp.concatenate([cs, xs], axis=1)      # (B, S+K-1, Di)
            idx = lengths[:, None] + jnp.arange(kk - 1)[None]    # (B, K-1)
            new_conv = jnp.take_along_axis(ext, idx[..., None], axis=1)
    xc = jax.nn.silu(xc)

    dbc = layers.linear(xc, p["x_proj"], engine=engine,
                        path="layers/ssm/x_proj")                    # (B,S,R+2N)
    dt_in = dbc[..., :dt_rank]
    B = dbc[..., dt_rank:dt_rank + ssm_state]
    C = dbc[..., dt_rank + ssm_state:]
    dt = jax.nn.softplus(layers.linear(dt_in, p["dt_proj"], engine=engine,
                                       path="layers/ssm/dt_proj")
                         + p["dt_bias"])
    if (not decode) and lengths is not None:
        # dt = 0 at pads -> dA = 1, dBx = 0: the scan's exact identity
        # element, so h passes through pad positions bit-unchanged
        smask = jnp.arange(dt.shape[1])[None, :] < lengths[:, None]
        dt = jnp.where(smask[..., None], dt, jnp.zeros((), dt.dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (Di, N)

    if decode:
        h = state["h"]
        y, h_new = ssm_decode_step(xc[:, 0], dt[:, 0], A, B[:, 0], C[:, 0],
                                   p["D"], h)
        y = y[:, None]
        new_state = dict(h=h_new, conv=new_conv)
    else:
        h0 = state["h"] if state is not None else None
        y, h_last = selective_scan(xc, dt, A, B, C, p["D"], h0, chunk=chunk,
                                   compute_dtype=scan_dtype)
        new_state = dict(h=h_last, conv=new_conv) if state is not None else None

    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = layers.linear(y, p["out_proj"], engine=engine,
                        path="layers/ssm/out_proj")
    return out, new_state


def init_ssm_state(batch: int, d_inner: int, ssm_state: int, conv_k: int = 4,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    return dict(h=jnp.zeros((batch, d_inner, ssm_state), jnp.float32),
                conv=jnp.zeros((batch, conv_k - 1, d_inner), dtype))

"""Decoder-only LM assembly: init / forward / train loss / prefill / decode.

One code path covers the dense, MoE, SSM and hybrid families via
ModelConfig; layers are *stacked* and executed with ``jax.lax.scan`` so the
lowered HLO stays one-layer-sized (essential for 512-device dry-run compile
times and for weight paging, whose page == layer granularity).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_params(cfg: ModelConfig, key, shape_d: int) -> Optional[Dict]:
    if cfg.norm_type == "nonparam_ln":
        return {}
    if cfg.norm_type == "layernorm":
        return dict(scale=jnp.ones((shape_d,), _dtype(cfg)),
                    bias=jnp.zeros((shape_d,), _dtype(cfg)))
    return dict(scale=jnp.zeros((shape_d,), _dtype(cfg)))   # rmsnorm (1+s)


def _dense_init(key, out_d: int, in_d: int, cfg: ModelConfig,
                scale: float = 1.0) -> jax.Array:
    std = scale * (in_d ** -0.5)
    return (jax.random.normal(key, (out_d, in_d), jnp.float32) * std
            ).astype(_dtype(cfg))


def _attn_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p = dict(
        wq=_dense_init(ks[0], cfg.q_dim, cfg.d_model, cfg),
        wk=_dense_init(ks[1], cfg.kv_dim, cfg.d_model, cfg),
        wv=_dense_init(ks[2], cfg.kv_dim, cfg.d_model, cfg),
        wo=_dense_init(ks[3], cfg.d_model, cfg.q_dim, cfg),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), _dtype(cfg))
        p["bk"] = jnp.zeros((cfg.kv_dim,), _dtype(cfg))
        p["bv"] = jnp.zeros((cfg.kv_dim,), _dtype(cfg))
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.hd,), _dtype(cfg))
        p["k_norm"] = jnp.zeros((cfg.hd,), _dtype(cfg))
    return p


def _mlp_params(cfg: ModelConfig, key, d_ff: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return dict(w_gate=_dense_init(ks[0], d_ff, cfg.d_model, cfg),
                    w_up=_dense_init(ks[1], d_ff, cfg.d_model, cfg),
                    w_down=_dense_init(ks[2], cfg.d_model, d_ff, cfg))
    return dict(w_up=_dense_init(ks[0], d_ff, cfg.d_model, cfg),
                b_up=jnp.zeros((d_ff,), _dtype(cfg)),
                w_down=_dense_init(ks[1], cfg.d_model, d_ff, cfg),
                b_down=jnp.zeros((cfg.d_model,), _dtype(cfg)))


def _moe_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    e, f, d = cfg.n_experts, cfg.moe_d_ff, cfg.d_model
    std = d ** -0.5
    p = dict(
        router=_dense_init(ks[0], e, d, cfg),
        w_gate=(jax.random.normal(ks[1], (e, f, d), jnp.float32) * std
                ).astype(_dtype(cfg)),
        w_up=(jax.random.normal(ks[2], (e, f, d), jnp.float32) * std
              ).astype(_dtype(cfg)),
        w_down=(jax.random.normal(ks[3], (e, d, f), jnp.float32) * (f ** -0.5)
                ).astype(_dtype(cfg)),
    )
    if cfg.shared_d_ff:
        p["shared"] = _mlp_params(cfg, ks[4], cfg.shared_d_ff)
    if cfg.dense_residual_d_ff:
        p["dense"] = _mlp_params(cfg, ks[5], cfg.dense_residual_d_ff)
    return p


def _ssm_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return dict(
        in_proj=_dense_init(ks[0], 2 * di, cfg.d_model, cfg),
        conv_w=(jax.random.normal(ks[1], (di, cfg.ssm_conv), jnp.float32)
                * (cfg.ssm_conv ** -0.5)).astype(_dtype(cfg)),
        conv_b=jnp.zeros((di,), _dtype(cfg)),
        x_proj=_dense_init(ks[2], r + 2 * n, di, cfg),
        dt_proj=_dense_init(ks[3], di, r, cfg),
        dt_bias=jnp.full((di,), -4.6, _dtype(cfg)),   # softplus^-1(0.01)
        A_log=jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None],
                               (di, 1))),
        D=jnp.ones((di,), jnp.float32),
        out_proj=_dense_init(ks[4], cfg.d_model, di, cfg),
    )


def _layer_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "hybrid", "vlm"):
        p["attn_norm"] = _norm_params(cfg, ks[0], cfg.d_model)
        p["attn"] = _attn_params(cfg, ks[1])
    if cfg.family in ("ssm", "hybrid"):
        p["ssm_norm"] = _norm_params(cfg, ks[2], cfg.d_model)
        p["ssm"] = _ssm_params(cfg, ks[3])
    if cfg.family == "moe":
        p["mlp_norm"] = _norm_params(cfg, ks[4], cfg.d_model)
        p["moe"] = _moe_params(cfg, ks[5])
    elif cfg.family != "ssm":     # dense / hybrid / vlm get a dense MLP
        p["mlp_norm"] = _norm_params(cfg, ks[4], cfg.d_model)
        p["mlp"] = _mlp_params(cfg, ks[5], cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    layer_ps = [_layer_params(cfg, ks[4 + i]) for i in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layer_ps)
    params: Dict[str, Any] = dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                 jnp.float32) * 0.02).astype(_dtype(cfg)),
        final_norm=_norm_params(cfg, ks[1], cfg.d_model),
        layers=stacked,
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[2], cfg.vocab_size, cfg.d_model, cfg)
    if cfg.n_meta_tokens:
        params["meta_tokens"] = (jax.random.normal(
            ks[3], (cfg.n_meta_tokens, cfg.d_model), jnp.float32) * 0.02
            ).astype(_dtype(cfg))
    return params


def count_params(params: Any) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _attn_apply(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig, *,
                window, q_offset: int = 0,
                cache: Optional[Dict[str, jax.Array]] = None,
                cache_pos: Optional[jax.Array] = None,
                static_window: Optional[int] = None,
                engine: Optional[Dict] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, _ = x.shape
    hd = cfg.hd
    q = L.linear(x, p["wq"], engine=engine, path="layers/attn/wq", bias=p.get("bq"))
    k = L.linear(x, p["wk"], engine=engine, path="layers/attn/wk", bias=p.get("bk"))
    v = L.linear(x, p["wv"], engine=engine, path="layers/attn/wv", bias=p.get("bv"))
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"])
        k = L.rmsnorm(k, p["k_norm"])
    pos = q_offset + jnp.arange(s)
    if cache_pos is not None:
        if getattr(cache_pos, "ndim", 0) == 1:   # per-batch (continuous batching)
            pos = cache_pos[:, None] + jnp.arange(s)[None]
        else:
            pos = cache_pos + jnp.arange(s)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)

    adt = jnp.dtype(cfg.attn_dtype)
    if cache is not None:
        insert_at = cache_pos if cache_pos is not None else 0
        cache = attn_lib.update_cache(cache, k, v, insert_at)
        if s == 1:   # decode
            o = attn_lib.decode_attention(
                q, cache["k"], cache["v"],
                cache_len=insert_at + 1,
                window=window if window is not None else None,
                compute_dtype=adt)
        else:        # prefill into cache, possibly mid-sequence (chunked
            # prefill): attend over the updated cache at the chunk's offset
            # so earlier chunks' keys are visible; positions beyond the
            # chunk are causally masked, so unwritten cache rows are inert.
            o = attn_lib.chunked_attention(q, cache["k"],
                                           cache["v"], causal=True,
                                           window=window,
                                           q_offset=insert_at,
                                           block=cfg.attn_block,
                                           compute_dtype=adt)
    elif static_window is not None:
        # q-blocked sliding-window fast path: O(S*(window+bq)) work
        o = attn_lib.windowed_attention(q, k, v, window=static_window,
                                        q_offset=q_offset,
                                        compute_dtype=adt)
    else:
        o = attn_lib.chunked_attention(q, k, v, causal=True, window=window,
                                       q_offset=q_offset,
                                       block=cfg.attn_block,
                                       compute_dtype=adt)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return L.linear(o, p["wo"], engine=engine, path="layers/attn/wo"), cache


def _layer_apply(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig, *,
                 window, cache: Optional[Dict] = None,
                 cache_pos: Optional[jax.Array] = None,
                 static_window: Optional[int] = None,
                 lengths: Optional[jax.Array] = None,
                 engine: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    new_cache: Dict[str, Any] = {}
    if "attn" in p:
        h = L.apply_norm(x, p.get("attn_norm"), cfg.norm_type)
        a, kv = _attn_apply(h, p["attn"], cfg, window=window,
                            cache=cache.get("kv") if cache else None,
                            cache_pos=cache_pos,
                            static_window=static_window, engine=engine)
        if cfg.family == "hybrid":
            # hymba: attention and SSM heads run in parallel on the same
            # normalized input; outputs are averaged.
            m, s_state = ssm_lib.mamba_mixer(
                h, p["ssm"], d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                dt_rank=cfg.dt_rank, conv_k=cfg.ssm_conv,
                chunk=cfg.ssm_chunk, scan_dtype=jnp.dtype(cfg.scan_dtype),
                shard_inner=cfg.ssm_shard_inner,
                state=cache.get("ssm") if cache else None,
                lengths=lengths, engine=engine)
            a = 0.5 * (a + m)
            if cache is not None:
                new_cache["ssm"] = s_state
        x = x + a
        if cache is not None:
            new_cache["kv"] = kv
    elif "ssm" in p:   # pure SSM family
        h = L.apply_norm(x, p.get("ssm_norm"), cfg.norm_type)
        m, s_state = ssm_lib.mamba_mixer(
            h, p["ssm"], d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
            dt_rank=cfg.dt_rank, conv_k=cfg.ssm_conv, chunk=cfg.ssm_chunk,
            scan_dtype=jnp.dtype(cfg.scan_dtype),
            shard_inner=cfg.ssm_shard_inner,
            state=cache.get("ssm") if cache else None,
            lengths=lengths, engine=engine)
        x = x + m
        if cache is not None:
            new_cache["ssm"] = s_state

    if "moe" in p:
        h = L.apply_norm(x, p.get("mlp_norm"), cfg.norm_type)
        x = x + moe_lib.moe_apply(
            h, p["moe"], n_experts=cfg.n_experts, k=cfg.n_experts_active,
            capacity_factor=cfg.capacity_factor, act=cfg.mlp_act,
            groups=max(cfg.moe_groups, 1), engine=engine)
    elif "mlp" in p:
        h = L.apply_norm(x, p.get("mlp_norm"), cfg.norm_type)
        x = x + L.mlp(h, p["mlp"], cfg.mlp_act, engine=engine, path="layers/mlp")
    return x, (new_cache if cache is not None else None)


def _layer_windows(cfg: ModelConfig) -> Optional[jax.Array]:
    """Per-layer window sizes (hymba mixes sliding-window + global layers)."""
    if cfg.window is None:
        return None
    w = jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    if cfg.n_global_layers:
        # global layers: first, last, and evenly spaced middles (hymba)
        idx = jnp.linspace(0, cfg.n_layers - 1,
                           cfg.n_global_layers).round().astype(jnp.int32)
        w = w.at[idx].set(jnp.int32(2 ** 30))
    return w


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params: Dict[str, Any], tokens: jax.Array, cfg: ModelConfig, *,
            engine: Optional[Dict] = None,
            extra_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B, S) -> logits (B, S_total, V).

    ``extra_embeds`` (B, P, D) are prepended (VLM patches / hymba meta
    tokens are handled internally).
    """
    x = L.embed(tokens, params["embed"]).astype(_dtype(cfg))
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix = []
    if extra_embeds is not None:
        prefix.append(extra_embeds.astype(x.dtype))
    if cfg.n_meta_tokens:
        b = x.shape[0]
        prefix.append(jnp.broadcast_to(
            params["meta_tokens"][None], (b, cfg.n_meta_tokens, cfg.d_model)
        ).astype(x.dtype))
    if prefix:
        x = jnp.concatenate(prefix + [x], axis=1)

    windows = _layer_windows(cfg)
    win_xs = (windows if windows is not None
              else jnp.zeros((cfg.n_layers,), jnp.int32))

    if (cfg.segmented_window_scan and cfg.window is not None
            and cfg.n_global_layers):
        # order-preserving segmentation: unroll the (few) global-attention
        # layers, scan the sliding-window runs between them with a STATIC
        # window so the q-blocked fast path applies (hymba optimization,
        # EXPERIMENTS.md §Perf).
        import numpy as _np
        g_idx = sorted(set(int(i) for i in _np.round(
            _np.linspace(0, cfg.n_layers - 1, cfg.n_global_layers))))

        def win_body(x, p):
            y, _ = _layer_apply(x, p, cfg, window=None,
                                static_window=cfg.window, engine=engine)
            return y, None

        if cfg.remat:
            win_body = jax.checkpoint(win_body)
        pos = 0
        for g in g_idx + [cfg.n_layers]:
            if g > pos:   # sliding-window run [pos, g)
                seg = jax.tree_util.tree_map(lambda a: a[pos:g],
                                             params["layers"])
                x, _ = jax.lax.scan(win_body, x, seg)
            if g < cfg.n_layers:   # the global layer itself
                pg = jax.tree_util.tree_map(lambda a: a[g], params["layers"])
                x, _ = _layer_apply(x, pg, cfg, window=None, engine=engine)
            pos = g + 1
    else:
        def body(x, xs):
            p, win = xs
            w = win if windows is not None else None
            y, _ = _layer_apply(x, p, cfg, window=w, engine=engine)
            return y, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["layers"], win_xs))

    x = L.apply_norm(x, params.get("final_norm"), cfg.norm_type)
    head = params.get("lm_head", params["embed"])
    logits = L.unembed(x, head)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def lm_loss(params: Dict[str, Any], batch: Dict[str, jax.Array],
            cfg: ModelConfig, *, engine: Optional[Dict] = None) -> jax.Array:
    """Next-token cross-entropy.  batch: tokens (B, S), labels (B, S),
    optional loss_mask, optional frames/patches for stub frontends."""
    extra = batch.get("patches")
    logits = forward(params, batch["tokens"], cfg, engine=engine,
                     extra_embeds=extra)
    # only score the text positions (prefix tokens carry no labels)
    s = batch["labels"].shape[1]
    logits = logits[:, -s:, :].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(ll))
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-layer caches (stacked, scan-carried)
# ---------------------------------------------------------------------------

def init_serve_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = _dtype(cfg)
    cache: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "hybrid", "vlm", "encdec"):
        cache["kv"] = dict(
            k=jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
            v=jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
        )
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = dict(
            h=jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                        jnp.float32),
            conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                            cfg.d_inner), dt),
        )
    return cache


def step(params: Dict[str, Any], tokens: jax.Array, cache: Dict[str, Any],
         pos: jax.Array, cfg: ModelConfig, *,
         engine: Optional[Dict] = None,
         extra_embeds: Optional[jax.Array] = None,
         add_prefix: bool = True,
         lengths: Optional[jax.Array] = None
         ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Serve step: run ``tokens`` (B, S) through the model, reading/writing
    the stacked cache at position ``pos`` (scalar, or (B,) per-batch for
    continuous batching).  S == 1 is decode; S > 1 prefill.

    On prefill, ``extra_embeds`` (VLM patches) and hymba meta tokens are
    prepended exactly as in :func:`forward`; the returned logits cover only
    the last S (token) positions.  ``pos`` must account for the prefix when
    decoding (first decode pos = prefix_len + prompt_len).

    ``add_prefix=False`` suppresses the prefix build — required for
    prefill chunks after the first, which continue mid-sequence (the
    chunked-prefill path of the serving scheduler).

    ``lengths`` (B,) gives each row's count of REAL tokens in a right-
    padded prefill chunk (pow2 bucketing).  Attention families already
    hide pads behind the causal mask; this is the SSM families' pad
    discipline — the recurrent state treats pad positions as exact
    no-ops (see :func:`repro.models.ssm.mamba_mixer`).
    """
    s_tokens = tokens.shape[1]
    x = L.embed(tokens, params["embed"]).astype(_dtype(cfg))
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if s_tokens > 1 and add_prefix:   # prefill: prefix exactly as forward()
        prefix = []
        if extra_embeds is not None:
            prefix.append(extra_embeds.astype(x.dtype))
        if cfg.n_meta_tokens:
            b = x.shape[0]
            prefix.append(jnp.broadcast_to(
                params["meta_tokens"][None],
                (b, cfg.n_meta_tokens, cfg.d_model)).astype(x.dtype))
        if prefix:
            x = jnp.concatenate(prefix + [x], axis=1)
    if lengths is not None and s_tokens > 1:
        # the prepended prefix tokens are real positions too
        lengths = lengths + (x.shape[1] - s_tokens)
    windows = _layer_windows(cfg)
    win_xs = (windows if windows is not None
              else jnp.zeros((cfg.n_layers,), jnp.int32))

    def body(x, xs):
        p, win, layer_cache = xs
        w = win if windows is not None else None
        y, new_cache = _layer_apply(x, p, cfg, window=w, cache=layer_cache,
                                    cache_pos=pos,
                                    lengths=lengths if s_tokens > 1 else None,
                                    engine=engine)
        return y, new_cache

    x, new_cache = jax.lax.scan(body, x,
                                (params["layers"], win_xs, cache))
    x = x[:, -s_tokens:]       # score only the token positions
    x = L.apply_norm(x, params.get("final_norm"), cfg.norm_type)
    head = params.get("lm_head", params["embed"])
    logits = L.unembed(x, head)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, new_cache


# ---------------------------------------------------------------------------
# FLOP accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts only active experts)."""
    d = cfg.d_model
    per_layer = 0
    if cfg.family in ("dense", "moe", "hybrid", "vlm", "encdec"):
        per_layer += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        per_layer += (d * 2 * di + di * cfg.ssm_conv
                      + di * (cfg.dt_rank + 2 * cfg.ssm_state)
                      + cfg.dt_rank * di + di * d)
    if cfg.family == "moe":
        e_active = cfg.n_experts_active
        per_layer += 3 * d * cfg.moe_d_ff * e_active
        if cfg.shared_d_ff:
            per_layer += 3 * d * cfg.shared_d_ff
        if cfg.dense_residual_d_ff:
            per_layer += 3 * d * cfg.dense_residual_d_ff
        per_layer += d * cfg.n_experts        # router
    elif cfg.family != "ssm":
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        per_layer += mult * d * cfg.d_ff
    n = cfg.n_layers * per_layer
    n += cfg.vocab_size * d                   # embedding/unembedding
    n_enc = cfg.n_encoder_layers * (4 * d * d + 2 * d * cfg.d_ff)
    return n + n_enc


def total_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    per_layer = 0
    if cfg.family in ("dense", "moe", "hybrid", "vlm", "encdec"):
        per_layer += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.d_inner
        per_layer += (d * 2 * di + di * cfg.ssm_conv
                      + di * (cfg.dt_rank + 2 * cfg.ssm_state)
                      + cfg.dt_rank * di + di * d)
    if cfg.family == "moe":
        per_layer += 3 * d * cfg.moe_d_ff * cfg.n_experts
        if cfg.shared_d_ff:
            per_layer += 3 * d * cfg.shared_d_ff
        if cfg.dense_residual_d_ff:
            per_layer += 3 * d * cfg.dense_residual_d_ff
        per_layer += d * cfg.n_experts
    elif cfg.family != "ssm":
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        per_layer += mult * d * cfg.d_ff
    n = cfg.n_layers * per_layer + cfg.vocab_size * d
    n += cfg.n_encoder_layers * (4 * d * d + 2 * d * cfg.d_ff)
    return n

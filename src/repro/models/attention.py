"""Attention: GQA with qk-norm, chunked (flash-style) softmax, KV caches.

Three execution paths:
  * ``chunked_attention`` — pure-JAX blocked online-softmax (lax.scan over
    KV blocks).  Memory-bounded (never materializes S x S), used by the
    multi-pod dry-run and the default DSP path.  Same math as the Pallas
    flash kernel (kernels/flash_attention.py), which replaces it on real
    TPUs.
  * ``decode_attention`` — single-step attention over a preallocated cache;
    reduction-friendly for caches sharded along the sequence axis
    (sequence-parallel decode, DESIGN.md §3).
  * the Pallas kernel via kernels.ops.attention (mode="pallas"/"interpret").
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fold_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, Hq, S, D) -> (B, Hkv, G, S, D)."""
    b, hq, s, d = q.shape
    return q.reshape(b, n_kv, hq // n_kv, s, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset=0, block: int = 1024,
                      scale: Optional[float] = None,
                      compute_dtype=jnp.float32) -> jax.Array:
    """Blocked online-softmax GQA attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.
    q_offset: absolute position of q[0] within the kv sequence — a python
    int, a traced scalar, or a (B,) vector for continuous-batching prefill
    chunks that start at a different cache offset per batch row.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = (_fold_gqa(q, hkv).astype(jnp.float32)
          * scale).astype(compute_dtype)                   # (B,Hkv,G,Sq,D)

    block = min(block, sk)
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = (sk + pad) // block
    kb = jnp.moveaxis(k.reshape(b, hkv, nblk, block, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nblk, block, d), 2, 0)

    q_off = jnp.asarray(q_offset)
    if q_off.ndim == 1:                                    # (B,) per-batch
        qpos = (q_off[:, None] + jnp.arange(sq))[..., None]   # (B, Sq, 1)
    else:
        qpos = (q_off + jnp.arange(sq))[:, None]           # (Sq, 1)

    def step(carry, xs):
        m, l, acc = carry
        idx, kblk, vblk = xs
        kpos = idx * block + jnp.arange(block)             # (block,)
        kpos = kpos[None, None] if qpos.ndim == 3 else kpos[None]
        s_blk = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                           kblk.astype(compute_dtype),
                           preferred_element_type=jnp.float32)
        mask = kpos < sk
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        mask = (mask[:, None, None] if mask.ndim == 3     # (B,1,1,Sq,block)
                else mask[None, None, None])
        s_blk = jnp.where(mask, s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(compute_dtype),
            vblk.astype(compute_dtype), preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    g = hq // hkv
    init = (jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, sq), jnp.float32),
            jnp.zeros((b, hkv, g, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def windowed_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       window: int, q_offset: int = 0, bq: int = 512,
                       scale: Optional[float] = None,
                       compute_dtype=jnp.float32) -> jax.Array:
    """Causal sliding-window attention with q-blocking: each q block only
    touches its visible key span (window + bq keys), so work and traffic
    are O(S * (window + bq)) instead of O(S^2).  ``window`` must be a
    static int — the hymba fast path (EXPERIMENTS.md §Perf hymba cell).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    pad = (-sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (sq + pad) // bq
    span = min(window + bq, sk)

    qb = jnp.moveaxis(q.reshape(b, hq, nq, bq, d), 2, 0)   # (nq,B,H,bq,d)
    qg = (qb.astype(jnp.float32) * scale).astype(compute_dtype)

    def one_block(i, qblk):
        qstart = i * bq + q_offset
        kstart = jnp.clip(qstart + bq - span, 0, max(sk - span, 0))
        ks = jax.lax.dynamic_slice_in_dim(k, kstart, span, axis=2)
        vs = jax.lax.dynamic_slice_in_dim(v, kstart, span, axis=2)
        qgg = _fold_gqa(qblk, hkv)                         # (B,Hkv,G,bq,d)
        s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qgg,
                        ks.astype(compute_dtype),
                        preferred_element_type=jnp.float32)
        qpos = qstart + jnp.arange(bq)[:, None]
        kpos = kstart + jnp.arange(span)[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - window)
        s_ = jnp.where(mask[None, None, None], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(compute_dtype),
                       vs.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
        return o.reshape(b, hq, bq, d)

    out = jax.vmap(one_block)(jnp.arange(nq), qg)          # (nq,B,H,bq,d)
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sq + pad, d)
    return out[:, :, :sq].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None,
                     compute_dtype=jnp.float32) -> jax.Array:
    """One-token attention over a preallocated cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, Smax, D); cache_len: () int32 —
    number of valid positions (the new token is at cache_len - 1).
    """
    b, hq, _, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    # compute_dtype=bf16: the cache is consumed at its storage precision by
    # a mixed-precision dot (f32 accumulate) — no full-width cache copy in
    # HBM.  This is the At-Memory discipline applied to the KV stream.
    qg = (_fold_gqa(q, hkv).astype(jnp.float32) * scale).astype(compute_dtype)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                   k_cache.astype(compute_dtype),
                   preferred_element_type=jnp.float32)
    kpos = jnp.arange(smax)[None, :]
    cl = (cache_len[:, None] if getattr(cache_len, "ndim", 0) == 1
          else cache_len)                     # (B,1) per-batch or scalar
    mask = kpos < cl
    if window is not None:
        mask = mask & (kpos > cl - 1 - window)
    mask = jnp.broadcast_to(mask[:, None, None, None] if mask.ndim == 2
                            else mask[None, None, None], s.shape)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(compute_dtype),
                     v_cache.astype(compute_dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def init_cache(batch: int, n_kv: int, max_len: int, head_dim: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return dict(
        k=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
        v=jnp.zeros((batch, n_kv, max_len, head_dim), dtype),
    )


def update_cache(cache: Dict[str, jax.Array], k_new: jax.Array,
                 v_new: jax.Array, pos: jax.Array) -> Dict[str, jax.Array]:
    """Insert (B, Hkv, S_new, D) at ``pos`` (scalar, or (B,) per-batch for
    continuous-batching decode)."""
    if getattr(pos, "ndim", 0) == 1:
        upd = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(
            c, n, (0, p, 0)))
        return dict(k=upd(cache["k"], k_new.astype(cache["k"].dtype), pos),
                    v=upd(cache["v"], v_new.astype(cache["v"].dtype), pos))
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, pos, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, pos, 0))
    return dict(k=k, v=v)

"""Shared model layers (pure functional JAX).

Weights can be *dense* arrays (training / DSP path) or *packed* dicts
{"packed": uint8, "scale": f32} produced by the WeightStore freeze (the
At-MRAM serving path).  Every matmul goes through :func:`linear`, which
dispatches between them — the zero-copy heterogeneous-engine contract of
the Siracusa cluster (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import placement, scenarios
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# linear dispatch
# ---------------------------------------------------------------------------

def _subpath(prefix: Optional[str], leaf: str) -> str:
    return f"{prefix}/{leaf}" if prefix else leaf


def linear(x: jax.Array, w, *, engine: Optional[Any] = None,
           bias: Optional[jax.Array] = None,
           path: Optional[str] = None) -> jax.Array:
    """y = x @ W^T (+ bias).  W: dense (N, K) array or packed dict.

    ``engine`` selects the weight path for packed weights: a
    :class:`~repro.core.placement.PlacementPlan` (per-parameter dispatch
    keyed by ``path``) or the legacy {"scenario", "mode", "bits"} dict
    (one global answer).  Defaults: l1mram / xla / 8-bit.
    """
    if isinstance(w, dict) and "packed" in w:
        scenario, mode, bits = placement.linear_dispatch(engine, path)
        k_orig = x.shape[-1]
        wire_bits = placement.wire_served_bits(engine, path)
        if wire_bits is not None:
            # wire-serve fast path: this param's cold page skipped the
            # host decode, so "packed"/"scale" hold the page codec's
            # blockwise wire form — expand it adjacent to the matmul
            out = kops.quant_matmul_blockscale(x, w["packed"], w["scale"],
                                               bits=wire_bits,
                                               k_orig=k_orig, mode=mode)
        elif scenario == "l1mram":
            out = kops.quant_matmul(x, w["packed"], w["scale"], bits=bits,
                                    k_orig=k_orig, mode=mode)
        else:
            from repro.core.weight_store import PackedParam
            f = 8 // bits
            n = w["packed"].shape[0]
            p = PackedParam(packed=w["packed"], scale=w["scale"], bits=bits,
                            orig_shape=(n, k_orig))
            out = scenarios.linear_apply(x, p, scenario=scenario, mode=mode)
        out = out.astype(x.dtype)
    else:
        out = jnp.matmul(x, w.T)
    if bias is not None:
        out = out + bias
    return out


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + 0.0 + scale.astype(jnp.float32))  # scale stored raw
    return x.astype(dt)


def layernorm(x: jax.Array, scale: Optional[jax.Array],
              bias: Optional[jax.Array], eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(x: jax.Array, params: Optional[Dict[str, jax.Array]],
               kind: str) -> jax.Array:
    """kind: rmsnorm | layernorm | nonparam_ln (OLMo-1B's non-parametric LN)."""
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layernorm(x, params.get("scale") if params else None,
                         params.get("bias") if params else None)
    if kind == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, head_dim); positions: (S,) shared or (B, S) per-batch."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (d/2,)
    if positions.ndim == 2:                                  # per-batch
        angles = (positions[:, None, :, None].astype(jnp.float32) * freqs)
    else:
        angles = positions[..., :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(x: jax.Array, p: Dict[str, Any], act: str,
        engine: Optional[Any] = None,
        path: Optional[str] = None) -> jax.Array:
    """Gated (swiglu/geglu) or plain (gelu) MLP.  ``path`` is the placement
    prefix for the weights (e.g. "mlp" -> "mlp/w_down")."""
    if act in ("swiglu", "geglu"):
        g = linear(x, p["w_gate"], engine=engine,
                   path=_subpath(path, "w_gate"))
        u = linear(x, p["w_up"], engine=engine, path=_subpath(path, "w_up"))
        h = (jax.nn.silu(g) if act == "swiglu"
             else jax.nn.gelu(g, approximate=True)) * u
    elif act == "gelu":
        h = jax.nn.gelu(linear(x, p["w_up"], engine=engine,
                               path=_subpath(path, "w_up"),
                               bias=p.get("b_up")), approximate=True)
    else:
        raise ValueError(f"unknown mlp act {act!r}")
    return linear(h, p["w_down"], engine=engine,
                  path=_subpath(path, "w_down"), bias=p.get("b_down"))


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """logits = x @ table^T (tied or dedicated head)."""
    return jnp.matmul(x, table.T.astype(x.dtype))

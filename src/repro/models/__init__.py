from repro.models import (attention, config, encdec, layers, mobilenet_v2,
                          moe, ssm, transformer, vlm)

__all__ = ["attention", "config", "encdec", "layers", "mobilenet_v2",
           "moe", "ssm", "transformer", "vlm"]

"""Mixture-of-Experts with capacity-based EP dispatch (qwen2-moe, arctic).

Dispatch is the static-shape sort+scatter formulation used on TPUs:
tokens' top-k assignments are sorted by expert, each assignment gets a
rank-within-expert via a searchsorted offset, assignments whose rank
exceeds the per-expert capacity are dropped (standard capacity-factor
routing), and the (E, C, D) dispatch buffer is built with one scatter.
Expert FFNs run as a single batched einsum over the expert dimension,
which shards over the `model` mesh axis (expert parallelism).

Under the At-MRAM serving path, expert weights are the paging showcase:
a 60-expert layer's packed weights behave exactly like a > 8 MiB network
on Siracusa — pages of experts stream through the resident budget
(core/paging.py) while the router's deterministic layer order drives
proactive prefetch.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.placement import dp_axes_of
from repro.models import layers


def capacity(n_tokens: int, n_experts: int, k: int,
             capacity_factor: float) -> int:
    c = int(n_tokens * k / n_experts * capacity_factor)
    return max(8, -(-c // 8) * 8)     # pad to 8 for TPU-friendly shapes


def route(x: jax.Array, router_w: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) -> (gates (T, k) softmaxed over chosen, idx (T, k))."""
    logits = jnp.matmul(x.astype(jnp.float32), router_w.T.astype(jnp.float32))
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def dispatch(x: jax.Array, gates: jax.Array, idx: jax.Array,
             n_experts: int, cap: int):
    """Sort+scatter dispatch: returns (buf (E, C, D), aux arrays).

    Pure-array form (no closures) so it vmaps over dispatch groups —
    group-local dispatch keeps the scatter on-shard (no cross-device
    scatter collectives), the EP optimization of EXPERIMENTS.md §Perf.
    """
    t, d = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]

    # rank within expert: position - first index of that expert in the sort
    starts = jnp.searchsorted(e_sorted, jnp.arange(n_experts), side="left")
    rank = jnp.arange(t * k) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                 # overflow -> trash slot

    buf = jnp.zeros((n_experts, cap + 1, d), x.dtype)
    buf = buf.at[e_sorted, slot].set(x[tok_sorted])
    buf = buf[:, :cap, :]
    aux = dict(e_sorted=e_sorted, slot=slot, tok_sorted=tok_sorted,
               g_sorted=g_sorted, keep=keep)
    return buf, aux


def combine(expert_out: jax.Array, aux, t: int) -> jax.Array:
    dout = expert_out.shape[-1]
    padded = jnp.pad(expert_out, ((0, 0), (0, 1), (0, 0)))
    y_sorted = padded[aux["e_sorted"], aux["slot"]]   # (T*k, Dout)
    w = jnp.where(aux["keep"], aux["g_sorted"], 0.0)[:, None]
    y_sorted = y_sorted * w.astype(y_sorted.dtype)
    out = jnp.zeros((t, dout), y_sorted.dtype)
    return out.at[aux["tok_sorted"]].add(y_sorted)


def dispatch_combine(x: jax.Array, gates: jax.Array, idx: jax.Array,
                     n_experts: int, cap: int):
    """Back-compat wrapper: returns (buf, combine closure)."""
    buf, aux = dispatch(x, gates, idx, n_experts, cap)
    t = x.shape[0]
    return buf, lambda expert_out: combine(expert_out, aux, t)


def expert_ffn(buf: jax.Array, p: Dict[str, Any], act: str = "swiglu",
               engine: Optional[Dict[str, Any]] = None) -> jax.Array:
    """Batched expert MLP: buf (E, C, D) x stacked weights (E, F, D)."""
    if isinstance(p["w_gate"], dict):
        # packed experts: vmap the quantized path over the expert dim
        def one(b, wg, wu, wd, sg, su, sd):
            pe = dict(w_gate=dict(packed=wg, scale=sg),
                      w_up=dict(packed=wu, scale=su),
                      w_down=dict(packed=wd, scale=sd))
            return layers.mlp(b, pe, act, engine=engine, path="layers/moe")
        return jax.vmap(one)(buf, p["w_gate"]["packed"], p["w_up"]["packed"],
                             p["w_down"]["packed"], p["w_gate"]["scale"],
                             p["w_up"]["scale"], p["w_down"]["scale"])
    g = jnp.einsum("ecd,efd->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,efd->ecf", buf, p["w_up"])
    h = (jax.nn.silu(g) if act == "swiglu"
         else jax.nn.gelu(g, approximate=True)) * u
    return jnp.einsum("ecf,edf->ecd", h, p["w_down"])


def moe_apply(x: jax.Array, p: Dict[str, Any], *, n_experts: int, k: int,
              capacity_factor: float = 1.25, act: str = "swiglu",
              groups: int = 1,
              engine: Optional[Dict[str, Any]] = None) -> jax.Array:
    """Full MoE layer.  x: (..., D) -> (..., D).

    p: router (E, D), w_gate/w_up (E, F, D), w_down (E, D, F),
    optional shared-expert MLP (w_gate/w_up/w_down without E dim) and
    optional dense-residual MLP (arctic) under p["dense"].

    ``groups > 1`` enables DP-local dispatch: tokens are regrouped to
    (G, T/G, ...) with G matching the data-parallel shard count, so the
    sort/scatter/gather machinery never crosses shards — only the expert
    einsums touch the network (psum over the TP'd expert hidden dim).
    Beyond-paper optimization; see EXPERIMENTS.md §Perf.
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]

    if groups > 1 and t % groups == 0:
        from jax.sharding import PartitionSpec as P
        tg = t // groups
        xg = xf.reshape(groups, tg, d)
        if dp_axes_of(engine):
            xg = jax.lax.with_sharding_constraint(
                xg, P(dp_axes_of(engine), None, None))
        gates, idx = jax.vmap(lambda xx: route(xx, p["router"], k))(xg)
        cap = capacity(tg, n_experts, k, capacity_factor)
        buf, aux = jax.vmap(
            lambda xx, gg, ii: dispatch(xx, gg, ii, n_experts, cap))(
            xg, gates, idx)
        if dp_axes_of(engine):
            dp = dp_axes_of(engine)
            # keep the dispatch buffer group-sharded and the expert hidden
            # dim TP'd — vmap otherwise loses the F-sharding and GSPMD
            # replicates the expert einsums (measured: 3x compute blowup).
            buf = jax.lax.with_sharding_constraint(
                buf, P(dp, None, None, None))
            g_ = jnp.einsum("gecd,efd->gecf", buf, p["w_gate"])
            u_ = jnp.einsum("gecd,efd->gecf", buf, p["w_up"])
            g_ = jax.lax.with_sharding_constraint(g_, P(dp, None, None, "model"))
            u_ = jax.lax.with_sharding_constraint(u_, P(dp, None, None, "model"))
            h_ = (jax.nn.silu(g_) if act == "swiglu"
                  else jax.nn.gelu(g_, approximate=True)) * u_
            expert_out = jnp.einsum("gecf,edf->gecd", h_, p["w_down"])
            expert_out = jax.lax.with_sharding_constraint(
                expert_out, P(dp, None, None, None))
        else:
            expert_out = jax.vmap(
                lambda bb: expert_ffn(bb, p, act=act, engine=engine))(buf)
        y = jax.vmap(lambda eo, ax: combine(eo, ax, tg))(expert_out, aux)
        y = y.reshape(t, d).astype(x.dtype)
    else:
        gates, idx = route(xf, p["router"], k)
        cap = capacity(t, n_experts, k, capacity_factor)
        buf, aux = dispatch(xf, gates, idx, n_experts, cap)
        expert_out = expert_ffn(buf, p, act=act, engine=engine)
        y = combine(expert_out, aux, t).astype(x.dtype)

    if "shared" in p:
        y = y + layers.mlp(xf, p["shared"], act, engine=engine,
                           path="layers/moe/shared")
    if "dense" in p:
        y = y + layers.mlp(xf, p["dense"], act, engine=engine,
                           path="layers/moe/dense")
    return y.reshape(*lead, d)


def router_aux_loss(x: jax.Array, router_w: jax.Array, idx: jax.Array,
                    n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    xf = x.reshape(-1, x.shape[-1])
    logits = jnp.matmul(xf.astype(jnp.float32), router_w.T.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)           # (T, E)
    # fraction of tokens whose top-1 hits each expert
    top1 = jax.nn.one_hot(idx[..., 0].reshape(-1), n_experts)
    f = jnp.mean(top1, axis=0)
    pbar = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pbar)

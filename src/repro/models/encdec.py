"""Whisper-style encoder-decoder backbone (whisper-tiny assignment).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, n_audio_frames, d_model).  The
backbone is faithful: sinusoidal-position encoder with bidirectional
attention + GELU MLPs, decoder with causal self-attention, cross-attention
to the encoder output, learned positions, layernorm-with-bias throughout.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import (_attn_params, _dense_init, _mlp_params,
                                      _norm_params, _dtype)


def _sinusoid(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _enc_layer_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    return dict(attn_norm=_norm_params(cfg, ks[0], cfg.d_model),
                attn=_attn_params(cfg, ks[1]),
                mlp_norm=_norm_params(cfg, ks[2], cfg.d_model),
                mlp=_mlp_params(cfg, ks[3], cfg.d_ff))


def _dec_layer_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    return dict(attn_norm=_norm_params(cfg, ks[0], cfg.d_model),
                attn=_attn_params(cfg, ks[1]),
                xattn_norm=_norm_params(cfg, ks[2], cfg.d_model),
                xattn=_attn_params(cfg, ks[3]),
                mlp_norm=_norm_params(cfg, ks[4], cfg.d_model),
                mlp=_mlp_params(cfg, ks[5], cfg.d_ff))


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(key, 6 + cfg.n_encoder_layers + cfg.n_layers)
    enc = [_enc_layer_params(cfg, ks[6 + i]) for i in range(cfg.n_encoder_layers)]
    dec = [_dec_layer_params(cfg, ks[6 + cfg.n_encoder_layers + i])
           for i in range(cfg.n_layers)]
    return dict(
        embed=(jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                 jnp.float32) * 0.02).astype(_dtype(cfg)),
        dec_pos=(jax.random.normal(ks[1], (4096 + 32768, cfg.d_model),
                                   jnp.float32) * 0.01).astype(_dtype(cfg)),
        enc_layers=jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc),
        dec_layers=jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dec),
        enc_final_norm=_norm_params(cfg, ks[2], cfg.d_model),
        final_norm=_norm_params(cfg, ks[3], cfg.d_model),
    )


def _mha(x: jax.Array, kv_src: jax.Array, p: Dict[str, Any],
         cfg: ModelConfig, *, causal: bool,
         engine: Optional[Dict] = None,
         path: Optional[str] = None) -> jax.Array:
    b, s, _ = x.shape
    sk = kv_src.shape[1]
    hd = cfg.hd
    sub = L._subpath
    q = L.linear(x, p["wq"], engine=engine, path=sub(path, "wq")).reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = L.linear(kv_src, p["wk"], engine=engine,
                 path=sub(path, "wk")).reshape(
        b, sk, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = L.linear(kv_src, p["wv"], engine=engine,
                 path=sub(path, "wv")).reshape(
        b, sk, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    o = attn_lib.chunked_attention(q, k, v, causal=causal,
                                   q_offset=sk - s if causal else 0,
                                   block=cfg.attn_block)
    return L.linear(o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim),
                    p["wo"], engine=engine, path=sub(path, "wo"))


def enc_layer_apply(x: jax.Array, p: Dict[str, Any], cfg: ModelConfig, *,
                    engine: Optional[Dict] = None) -> jax.Array:
    h = L.apply_norm(x, p.get("attn_norm"), cfg.norm_type)
    x = x + _mha(h, h, p["attn"], cfg, causal=False, engine=engine,
                 path="enc_layers/attn")
    h = L.apply_norm(x, p.get("mlp_norm"), cfg.norm_type)
    return x + L.mlp(h, p["mlp"], cfg.mlp_act, engine=engine,
                     path="enc_layers/mlp")


def dec_train_layer_apply(x: jax.Array, enc_out: jax.Array,
                          p: Dict[str, Any], cfg: ModelConfig, *,
                          engine: Optional[Dict] = None) -> jax.Array:
    """One decoder layer of the training path (no cache): causal self-attn
    + cross-attn to the encoder states + MLP.  Used by decode() and by the
    roofline microbench."""
    h = L.apply_norm(x, p.get("attn_norm"), cfg.norm_type)
    x = x + _mha(h, h, p["attn"], cfg, causal=True, engine=engine,
                 path="dec_layers/attn")
    h = L.apply_norm(x, p.get("xattn_norm"), cfg.norm_type)
    x = x + _mha(h, enc_out, p["xattn"], cfg, causal=False, engine=engine,
                 path="dec_layers/xattn")
    h = L.apply_norm(x, p.get("mlp_norm"), cfg.norm_type)
    return x + L.mlp(h, p["mlp"], cfg.mlp_act, engine=engine,
                     path="dec_layers/mlp")


def encode(params: Dict[str, Any], frames: jax.Array, cfg: ModelConfig, *,
           engine: Optional[Dict] = None) -> jax.Array:
    """frames: (B, T, D) stub embeddings -> encoder states (B, T, D)."""
    x = frames.astype(_dtype(cfg)) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(_dtype(cfg))[None]

    def body(x, p):
        return enc_layer_apply(x, p, cfg, engine=engine), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(x, params.get("enc_final_norm"), cfg.norm_type)


def decode(params: Dict[str, Any], tokens: jax.Array, enc_out: jax.Array,
           cfg: ModelConfig, *, engine: Optional[Dict] = None) -> jax.Array:
    """tokens (B, S) + encoder states -> logits (B, S, V)."""
    b, s = tokens.shape
    x = (L.embed(tokens, params["embed"]).astype(_dtype(cfg))
         + params["dec_pos"][None, :s].astype(_dtype(cfg)))

    def body(x, p):
        return dec_train_layer_apply(x, enc_out, p, cfg, engine=engine), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(x, params.get("final_norm"), cfg.norm_type)
    return L.unembed(x, params["embed"])


def seq2seq_loss(params: Dict[str, Any], batch: Dict[str, jax.Array],
                 cfg: ModelConfig, *, engine: Optional[Dict] = None) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, engine=engine)
    logits = decode(params, batch["tokens"], enc_out, cfg, engine=engine)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(ll))
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# -- serving: decoder KV cache + precomputed cross-attn KV -------------------

def init_serve_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = _dtype(cfg)
    L_ = cfg.n_layers
    return dict(
        kv=dict(k=jnp.zeros((L_, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
                v=jnp.zeros((L_, batch, cfg.n_kv_heads, max_len, cfg.hd), dt)),
        xk=jnp.zeros((L_, batch, cfg.n_kv_heads, cfg.n_audio_frames, cfg.hd), dt),
        xv=jnp.zeros((L_, batch, cfg.n_kv_heads, cfg.n_audio_frames, cfg.hd), dt),
    )


def precompute_cross_kv(params: Dict[str, Any], enc_out: jax.Array,
                        cfg: ModelConfig, cache: Dict[str, Any],
                        *, engine: Optional[Dict] = None) -> Dict[str, Any]:
    b, t, _ = enc_out.shape

    def body(_, p):
        k = L.linear(enc_out, p["xattn"]["wk"], engine=engine,
                     path="dec_layers/xattn/wk").reshape(
            b, t, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
        v = L.linear(enc_out, p["xattn"]["wv"], engine=engine,
                     path="dec_layers/xattn/wv").reshape(
            b, t, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_layers"])
    return dict(cache, xk=xk.astype(_dtype(cfg)), xv=xv.astype(_dtype(cfg)))


def dec_layer_apply(x: jax.Array, p: Dict[str, Any],
                    layer_cache: Dict[str, jax.Array], xk: jax.Array,
                    xv: jax.Array, pos: jax.Array, cfg: ModelConfig, *,
                    engine: Optional[Dict] = None):
    """One decoder layer of the serve path: self-attn (cached) + cross-attn
    (precomputed encoder KV) + MLP."""
    b, s, _ = x.shape
    hd = cfg.hd
    h = L.apply_norm(x, p.get("attn_norm"), cfg.norm_type)
    q = L.linear(h, p["attn"]["wq"], engine=engine,
                 path="dec_layers/attn/wq").reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = L.linear(h, p["attn"]["wk"], engine=engine,
                 path="dec_layers/attn/wk").reshape(
        b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = L.linear(h, p["attn"]["wv"], engine=engine,
                 path="dec_layers/attn/wv").reshape(
        b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    kv = attn_lib.update_cache(layer_cache, k, v, pos)
    if s == 1:
        o = attn_lib.decode_attention(q, kv["k"], kv["v"], cache_len=pos + 1)
    else:
        o = attn_lib.chunked_attention(q, k, v, causal=True,
                                       block=cfg.attn_block)
    x = x + L.linear(o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim),
                     p["attn"]["wo"], engine=engine,
                     path="dec_layers/attn/wo")
    # cross attention over precomputed encoder KV
    h = L.apply_norm(x, p.get("xattn_norm"), cfg.norm_type)
    q = L.linear(h, p["xattn"]["wq"], engine=engine,
                 path="dec_layers/xattn/wq").reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    o = attn_lib.chunked_attention(q, xk, xv, causal=False,
                                   block=cfg.attn_block)
    x = x + L.linear(o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim),
                     p["xattn"]["wo"], engine=engine,
                     path="dec_layers/xattn/wo")
    h = L.apply_norm(x, p.get("mlp_norm"), cfg.norm_type)
    x = x + L.mlp(h, p["mlp"], cfg.mlp_act, engine=engine,
                  path="dec_layers/mlp")
    return x, kv


def step(params: Dict[str, Any], tokens: jax.Array, cache: Dict[str, Any],
         pos: jax.Array, cfg: ModelConfig, *,
         engine: Optional[Dict] = None) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decoder serve step (S==1 decode / S>1 prefill) with cross-attn."""
    b, s = tokens.shape
    x = (L.embed(tokens, params["embed"]).astype(_dtype(cfg))
         + jax.lax.dynamic_slice_in_dim(
             params["dec_pos"], pos, s, axis=0)[None].astype(_dtype(cfg)))

    def body(x, xs):
        p, layer_cache, xk, xv = xs
        return dec_layer_apply(x, p, layer_cache, xk, xv, pos, cfg,
                               engine=engine)

    x, new_kv = jax.lax.scan(
        body, x, (params["dec_layers"], cache["kv"], cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params.get("final_norm"), cfg.norm_type)
    logits = L.unembed(x, params["embed"])
    return logits, dict(cache, kv=new_kv)

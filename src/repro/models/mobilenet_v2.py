"""Int8 MobileNet-V2 1.0-224 on the N-EUREKA path — the paper's workload.

This is the end-to-end network of the paper's §IV scenario study: every
conv runs as an N-EUREKA job (dense3x3 / dw3x3 / pw1x1 via
kernels.ops.neureka_conv2d), weights live packed in a WeightStore, and the
execution schedule is the same job list the memsys model walks — so the
measured functional network and the analytical latency/energy model share
one source of truth (core/perf_model.mobilenet_v2_jobs).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.core.perf_model import mobilenet_v2_jobs
from repro.core.memsys import LayerShape
from repro.kernels import ops as kops


def init_params(key: jax.Array, weight_bits: int = 8,
                img: int = 224) -> Dict[str, Any]:
    """Float master weights for every job (to be frozen/packed)."""
    jobs = mobilenet_v2_jobs(weight_bits, img)
    params: Dict[str, Any] = {}
    keys = jax.random.split(key, len(jobs))
    for job, k in zip(jobs, keys):
        if job.op_kind == "dense3x3":
            shape = (job.cout, 3, 3, job.cin)
        elif job.op_kind == "dw3x3":
            shape = (job.cin, 3, 3)
        else:
            shape = (job.cout, job.cin)
        fan_in = int(np.prod(shape[1:]))
        params[job.name] = dict(
            w=jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5),
            bias=jnp.zeros((shape[0],), jnp.float32),
        )
    return params


def freeze_packed(params: Dict[str, Any], weight_bits: int = 8,
                  img: int = 224) -> Dict[str, Any]:
    """Quantize+pack every job's weights and fold requant params.

    Per-channel requant multipliers are calibrated analytically so each
    layer's int32 accumulator distribution maps onto the uint8 range
    (NEMO-style static calibration): acc_std ~ in_rms * levels_rms *
    sqrt(K); mult = target_std / acc_std with the output centered at 128
    (activations are unsigned, zp folded into the bias).
    """
    jobs = mobilenet_v2_jobs(weight_bits, img)
    out: Dict[str, Any] = {}
    in_rms = 128.0                     # running estimate of input-act RMS
    for job in jobs:
        p = params[job.name]
        if job.op_kind == "dense3x3":
            packed, scale = kops.prep_conv3x3(p["w"], weight_bits)
            k_red = 9 * job.cin
            lv = packing_levels(packed, weight_bits, (job.cout, 3, 3, job.cin))
        elif job.op_kind == "dw3x3":
            packed, scale = kops.prep_dw3x3(p["w"], weight_bits)
            k_red = 9
            lv = packing_levels(packed, weight_bits, (job.cin, 9))
        else:
            packed, scale = kops.prep_linear(p["w"], weight_bits)
            k_red = job.cin
            lv = packing_levels(packed, weight_bits, (job.cout, job.cin))
        lv_rms = jnp.sqrt(jnp.mean(
            lv.reshape(lv.shape[0], -1).astype(jnp.float32) ** 2, axis=1))
        acc_std = in_rms * jnp.maximum(lv_rms, 1e-3) * (k_red ** 0.5)
        mult = 40.0 / acc_std          # target output std ~ 40 LSB
        bias = jnp.full((lv.shape[0],), 128, jnp.int32)   # center unsigned
        out[job.name] = dict(packed=packed, mult=mult.astype(jnp.float32),
                             bias=bias + jnp.round(
                                 p["bias"]).astype(jnp.int32))
    return out


def packing_levels(packed: jax.Array, bits: int, shape) -> jax.Array:
    from repro.core import packing as _packing
    return _packing.unpack(packed, bits, shape[-1]).reshape(shape[0], -1)


def apply(packed_params: Dict[str, Any], image_q: jax.Array, *,
          weight_bits: int = 8, mode: str = "xla",
          img: int = 224) -> jax.Array:
    """Run int8 MobileNet-V2.  image_q: (H, W, 3) uint8 -> logits (1000,).

    Residual adds follow NEMO integer semantics: uint8 feature maps added
    in int32 then clipped back to uint8 (scales aligned by construction).
    """
    jobs = mobilenet_v2_jobs(weight_bits, img)
    x = image_q
    residual: Optional[jax.Array] = None
    res_cin = -1
    for job in jobs:
        p = packed_params[job.name]
        if job.name == "fc":
            x = jnp.mean(x.astype(jnp.float32), axis=(0, 1),
                         keepdims=True).astype(jnp.uint8)   # avg pool
        op = job.op_kind
        new_x = kops.neureka_conv2d(
            x, p["packed"], p["mult"], p["bias"], op=op,
            bits=weight_bits, cin=job.cin, stride=job.stride, mode=mode)
        # inverted-residual skip: around (pw_exp, dw, pw_proj) triples with
        # stride 1 and matching channels
        if job.name.endswith(".pw_exp"):
            residual, res_cin = x, job.cin
        if (job.name.endswith(".pw_proj") and residual is not None
                and job.stride == 1 and new_x.shape == residual.shape):
            s = residual.astype(jnp.int32) + new_x.astype(jnp.int32) - 128
            new_x = jnp.clip(s, 0, 255).astype(jnp.uint8)
        if job.name.endswith(".pw_proj"):
            residual = None
        x = new_x
    return x.reshape(-1)


def job_list(weight_bits: int = 8, img: int = 224) -> List[LayerShape]:
    return mobilenet_v2_jobs(weight_bits, img)

"""Model configuration shared by the model zoo and the arch configs."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 = attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    window: Optional[int] = None   # sliding-window size (None = full)
    n_global_layers: int = 0       # hymba: this many layers use full attn
    logit_softcap: float = 0.0

    # mlp / norm
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0           # shared-expert hidden size (qwen2-moe)
    dense_residual_d_ff: int = 0   # arctic: parallel dense FFN hidden size
    capacity_factor: float = 1.25

    # MoE execution: >1 enables DP-local grouped dispatch (see moe_apply)
    moe_groups: int = 0

    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    dt_rank: int = 0

    # hybrid (hymba)
    n_meta_tokens: int = 0

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 0        # stub frontend output length

    # vlm (llava)
    n_patches: int = 0

    # execution
    dtype: str = "float32"
    remat: bool = True
    attn_block: int = 1024         # chunked-attention KV block
    attn_dtype: str = "float32"    # score/AV compute dtype (bf16 = optimized)
    scan_dtype: str = "float32"    # selective-scan compute dtype
    ssm_shard_inner: bool = False  # constrain d_inner onto the model axis
    segmented_window_scan: bool = False  # static-window fast path (hymba)
    ssm_chunk: int = 256           # selective-scan sequence chunk
    weight_bits: int = 8           # packed-store precision for serving

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # reduced config of the same family for CPU smoke tests
    def smoke(self) -> "ModelConfig":
        return self.replace(
            n_layers=2,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            n_experts_active=min(self.n_experts_active, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            dense_residual_d_ff=64 if self.dense_residual_d_ff else 0,
            d_inner=128 if self.d_inner else 0,
            dt_rank=8 if self.dt_rank else 0,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_audio_frames=min(self.n_audio_frames, 32) if self.n_audio_frames else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            n_global_layers=min(self.n_global_layers, 1),
            window=min(self.window, 16) if self.window else None,
            remat=False,
        )

"""hymba-1.5b  [hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention+mamba heads per layer,
sliding-window attention (3 global layers), 128 meta tokens.
[arXiv:2411.13676; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    rope_theta=1e4, window=1024, n_global_layers=3, n_meta_tokens=128,
    mlp_act="swiglu", norm_type="rmsnorm", tie_embeddings=True,
    ssm_state=16, d_inner=3200, dt_rank=100,
)

"""The assigned input-shape cells (seq_len x global_batch) for every arch.

``train_*`` lowers train_step; ``prefill_*`` lowers the serve prefill;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV/SSM
cache of seq_len).  long_500k requires sub-quadratic attention: it runs for
the SSM/hybrid archs and is SKIPPED for pure full-attention archs
(DESIGN.md section 4).
"""

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeCell("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# archs with sub-quadratic sequence mixing (SSM / sliding-window hybrid)
SUBQUADRATIC = ("hymba-1.5b", "falcon-mamba-7b")


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True

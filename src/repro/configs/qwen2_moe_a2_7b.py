"""qwen2-moe-a2.7b  [moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 experts top-4 + 4 shared (shared hidden 5632 = 4x1408).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6,
    mlp_act="swiglu", norm_type="rmsnorm", tie_embeddings=False,
    n_experts=60, n_experts_active=4, moe_d_ff=1408, shared_d_ff=5632,
)

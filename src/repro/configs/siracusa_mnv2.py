"""The paper's own workload: int8 MobileNet-V2 1.0-224 on N-EUREKA with
2-8 bit packed weights in the At-MRAM store (paper section IV)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class MNV2Config:
    name: str = "siracusa-mnv2"
    img: int = 224
    weight_bits: int = 8
    scenario: str = "l1mram"


CONFIG = MNV2Config()

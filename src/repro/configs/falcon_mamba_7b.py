"""falcon-mamba-7b  [ssm] 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — pure Mamba-1 architecture.  [arXiv:2410.05355; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    norm_type="rmsnorm", tie_embeddings=False,
    ssm_state=16, d_inner=8192, dt_rank=256,
)

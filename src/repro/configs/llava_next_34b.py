"""llava-next-34b  [vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; vision tower STUB (input_specs provides
precomputed patch embeddings, 2880 = 5 tiles x 576 patches).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    rope_theta=5e6, mlp_act="swiglu", norm_type="rmsnorm",
    tie_embeddings=False, n_patches=2880,
)

"""Architecture registry: one module per assigned arch (+ the paper's own
MobileNet-V2 workload).  ``get_config(name)`` / ``ARCHS`` are the public API
(the --arch flag of the launchers resolves here)."""

from repro.configs import shapes
from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.gemma_7b import CONFIG as _gemma
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.llava_next_34b import CONFIG as _llava

ARCHS = {c.name: c for c in (
    _qwen3, _qwen25, _olmo, _gemma, _whisper, _qwen2moe, _arctic, _hymba,
    _falcon, _llava)}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

"""arctic-480b  [moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual (Dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=32000,
    rope_theta=1e6, mlp_act="swiglu", norm_type="rmsnorm",
    tie_embeddings=False,
    n_experts=128, n_experts_active=2, moe_d_ff=4864,
    dense_residual_d_ff=14336,
)

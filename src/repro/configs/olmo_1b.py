"""olmo-1b  [dense] 16L d_model=2048 16H (GQA kv=16) d_ff=8192
vocab=50304 — non-parametric LN.  [arXiv:2402.00838; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304,
    rope_theta=1e4, mlp_act="swiglu", norm_type="nonparam_ln",
    tie_embeddings=True,
)

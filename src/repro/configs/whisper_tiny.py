"""whisper-tiny  [audio] 4L d_model=384 6H d_ff=1536 vocab=51865 —
enc-dec, conv frontend STUB (input_specs provides precomputed frame
embeddings, 1500 frames = 30 s).  [arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_encoder_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    mlp_act="gelu", norm_type="layernorm", tie_embeddings=True,
    n_audio_frames=1500,
)

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — see dryrun.py,
which must set XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: AxisType (explicit-sharding API)
    only exists on newer jax; older releases default every axis to Auto
    anyway, so omitting the argument is semantically identical there.
    Releases predating jax.make_mesh itself fall back to constructing
    jax.sharding.Mesh directly over the device grid."""
    make = getattr(jax, "make_mesh", None)
    if make is None:
        import math
        import numpy as np
        n = math.prod(shape)
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return make(shape, axes)
    return make(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = one v5e pod (256 chips); multi_pod adds a 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count).

    Degrades instead of raising when the host exposes fewer devices than
    ``shape`` wants: each axis is clamped (left to right) to what remains
    of ``jax.device_count()``, keeping the axis NAMES intact so sharding
    rules still resolve — a 1-device host simply gets a (1, 1) mesh."""
    import math
    have = jax.device_count()
    if math.prod(shape) > have:
        import warnings
        clamped = []
        remaining = have
        for s in shape:
            use = min(s, remaining)
            clamped.append(use)
            remaining = max(1, remaining // use)
        warnings.warn(
            f"make_test_mesh: shape {tuple(shape)} wants "
            f"{math.prod(shape)} devices but only {have} present; "
            f"clamping to {tuple(clamped)}", stacklevel=2)
        shape = tuple(clamped)
    return _make_mesh(shape, axes)

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — see dryrun.py,
which must set XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = one v5e pod (256 chips); multi_pod adds a 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))

"""Step builders + input_specs for every (arch x shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins (with
NamedShardings attached) for every model input — params, optimizer state,
batches, caches — so the dry-run lowers and compiles with **zero device
allocation**.  The same builders produce the real jitted callables for the
end-to-end examples (small configs, real arrays).

Cell kinds:
  train   — full train_step: loss, grads, clip, optimizer update
  prefill — serve prefill: fill the KV/SSM cache from a prompt
  decode  — serve_step: ONE new token against a seq_len-deep cache
"""

from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell
from repro.core.placement import PlacementPlan
from repro.models import encdec, transformer as tfm, vlm as vlm_lib
from repro.models.config import ModelConfig
from repro.optim import clip_by_global_norm, pick_optimizer
from repro.parallel import sharding as shd


# Legacy dict form, kept for callers that merge overrides into it
# (launch/microbench.py); serve-step builders normalize everything to a
# PlacementPlan via placement.as_plan.
DEFAULT_SERVE_ENGINE = dict(scenario="l1mram", mode="xla", bits=8)
DEFAULT_SERVE_PLAN = PlacementPlan.uniform()


def _loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.seq2seq_loss
    return tfm.lm_loss


def _init_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.init_params
    return tfm.init_params


def param_specs(cfg: ModelConfig, key=None) -> Any:
    """ShapeDtypeStruct tree of the parameters (eval_shape — no alloc)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(_init_fn(cfg), cfg), key)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer, lr: float = 3e-4,
                    engine: Optional[Dict] = None) -> Callable:
    loss_fn = _loss_fn(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                                  engine=engine)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               jnp.asarray(lr, jnp.float32))
        return new_params, new_opt, dict(loss=loss, grad_norm=gnorm)

    return train_step


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> Dict:
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    bspec2 = NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=1))
    bspec3 = NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=2))
    batch: Dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["frames"] = shd.sds((b, cfg.n_audio_frames, cfg.d_model), dt,
                                  bspec3)
        batch["tokens"] = shd.sds((b, s), jnp.int32, bspec2)
        batch["labels"] = shd.sds((b, s), jnp.int32, bspec2)
    elif cfg.family == "vlm":
        s_text = s - cfg.n_patches
        batch["patches"] = shd.sds((b, cfg.n_patches, cfg.d_model), dt, bspec3)
        batch["tokens"] = shd.sds((b, s_text), jnp.int32, bspec2)
        batch["labels"] = shd.sds((b, s_text), jnp.int32, bspec2)
    else:
        batch["tokens"] = shd.sds((b, s), jnp.int32, bspec2)
        batch["labels"] = shd.sds((b, s), jnp.int32, bspec2)
    return batch


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, engine: Optional[Any] = None):
    """``engine``: PlacementPlan, legacy engine dict (passed through
    verbatim so sharding hints like dp_axes survive), or None (uniform
    l1mram plan)."""
    engine = engine if engine is not None else DEFAULT_SERVE_PLAN
    if cfg.family == "encdec":
        def prefill(params, frames, tokens, cache):
            enc_out = encdec.encode(params, frames, cfg, engine=engine)
            cache = encdec.precompute_cross_kv(params, enc_out, cfg, cache,
                                               engine=engine)
            return encdec.step(params, tokens, cache, jnp.int32(0), cfg,
                               engine=engine)
        return prefill
    if cfg.family == "vlm":
        def prefill(params, patches, tokens, cache):
            return tfm.step(params, tokens, cache, jnp.int32(0), cfg,
                            engine=engine, extra_embeds=patches)
        return prefill

    def prefill(params, tokens, cache):
        return tfm.step(params, tokens, cache, jnp.int32(0), cfg,
                        engine=engine)
    return prefill


def make_decode_step(cfg: ModelConfig, engine: Optional[Any] = None):
    """``engine``: PlacementPlan, legacy engine dict (passed through
    verbatim so sharding hints like dp_axes survive), or None (uniform
    l1mram plan)."""
    engine = engine if engine is not None else DEFAULT_SERVE_PLAN
    if cfg.family == "encdec":
        def decode(params, token, cache, pos):
            return encdec.step(params, token, cache, pos, cfg, engine=engine)
        return decode

    def decode(params, token, cache, pos):
        return tfm.step(params, token, cache, pos, cfg, engine=engine)
    return decode


def serve_param_specs(cfg: ModelConfig, bits: int = 8,
                      plan: Optional[PlacementPlan] = None) -> Any:
    """Packed At-MRAM store specs (uint8 carriers + f32 scales); ``plan``
    overrides bits per parameter path (mixed-precision plans)."""
    return shd.serve_spec_like(param_specs(cfg), bits=bits, plan=plan)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    if cfg.family == "encdec":
        fn = functools.partial(encdec.init_serve_cache, cfg, batch, max_len)
    else:
        fn = functools.partial(tfm.init_serve_cache, cfg, batch, max_len)
    return jax.eval_shape(fn)


def serve_input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                      bits: int = 8,
                      plan: Optional[PlacementPlan] = None) -> Dict[str, Any]:
    """Specs for prefill/decode cells: params (packed), inputs, cache."""
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    pspecs = serve_param_specs(cfg, bits, plan=plan)
    pshard = shd.param_shardings(pspecs, mesh)
    pspecs = shd.with_shardings(pspecs, pshard)

    cspecs = cache_specs(cfg, b, s)
    cshard = shd.cache_shardings(cspecs, mesh, b)
    cspecs = shd.with_shardings(cspecs, cshard)

    bspec2 = NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=1))
    bspec3 = NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=2))

    out: Dict[str, Any] = dict(params=pspecs, cache=cspecs)
    if cell.kind == "prefill":
        prompt = s if cfg.family != "vlm" else s - cfg.n_patches
        prompt = prompt - cfg.n_meta_tokens
        out["tokens"] = shd.sds((b, prompt), jnp.int32, bspec2)
        if cfg.family == "encdec":
            out["frames"] = shd.sds((b, cfg.n_audio_frames, cfg.d_model), dt,
                                    bspec3)
        if cfg.family == "vlm":
            out["patches"] = shd.sds((b, cfg.n_patches, cfg.d_model), dt,
                                     bspec3)
    else:  # decode: one token against a seq_len-deep cache
        out["tokens"] = shd.sds((b, 1), jnp.int32, bspec2)
        out["pos"] = shd.sds((), jnp.int32, NamedSharding(mesh, P()))
        if cfg.family == "encdec":
            pass  # cross-KV already inside the cache specs
    return out


# ---------------------------------------------------------------------------
# full cell assembly for the dry-run
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
               serve_bits: int = 8,
               engine: Optional[Any] = None
               ) -> Tuple[Callable, Tuple, Dict[str, Any]]:
    """Returns (fn, example_args_specs, out_shardings_hint).

    ``engine``: for serve cells a PlacementPlan or legacy dict of
    overrides; for train cells a dict (may carry dp_axes sharding hints).
    """
    cfg = cfg.replace(dtype="bfloat16")
    if cell.kind == "train":
        pspecs = param_specs(cfg)
        pshard = shd.param_shardings(pspecs, mesh)
        pspecs_sh = shd.with_shardings(pspecs, pshard)
        # math.prod: shape products overflow int32 under jnp (arctic's
        # expert tensors are 1.5e11 elements)
        opt = pick_optimizer(sum(math.prod(l.shape)
                                 for l in jax.tree_util.tree_leaves(pspecs)),
                             n_chips=mesh.size)
        ospecs = jax.eval_shape(opt.init, pspecs)
        oshard = shd.opt_state_shardings(ospecs, mesh, pspecs)
        ospecs_sh = shd.with_shardings(ospecs, oshard)
        batch = train_batch_specs(cfg, cell, mesh)
        train_engine = dict(engine or {})
        train_engine.setdefault("dp_axes", shd.dp_axes(mesh))
        fn = make_train_step(cfg, opt, engine=train_engine)
        return fn, (pspecs_sh, ospecs_sh, batch), {}

    if isinstance(engine, PlacementPlan):
        # the plan owns the bit widths; specs mirror it per parameter
        serve_engine: Any = engine
        specs = serve_input_specs(cfg, cell, mesh, plan=engine)
    else:
        serve_engine = dict(DEFAULT_SERVE_ENGINE)
        serve_engine["bits"] = serve_bits
        if engine:
            serve_engine.update(engine)
        specs = serve_input_specs(cfg, cell, mesh, bits=serve_bits)
    if cell.kind == "prefill":
        fn = make_prefill_step(cfg, engine=serve_engine)
        if cfg.family == "encdec":
            args = (specs["params"], specs["frames"], specs["tokens"],
                    specs["cache"])
        elif cfg.family == "vlm":
            args = (specs["params"], specs["patches"], specs["tokens"],
                    specs["cache"])
        else:
            args = (specs["params"], specs["tokens"], specs["cache"])
        return fn, args, {}

    fn = make_decode_step(cfg, engine=serve_engine)
    args = (specs["params"], specs["tokens"], specs["cache"], specs["pos"])
    return fn, args, {}

"""Single-layer + inner-loop cost microbenchmarks for the roofline.

XLA's HLO cost analysis counts a while-loop body ONCE, not trip-count
times (verified empirically — a scan of 28 layers reports ~1 layer of
flops).  Buffer/memory analysis is unaffected, but FLOPs / HBM bytes /
collective traffic must be reconstructed:

    total = full_program
          + (L - 1) * layer1                      # layer-scan body
          + L * (n_attn_blk - 1) * attn1          # attention kv-block scan
          + L * (n_ssm_chunk - 1) * ssm1          # selective-scan chunks
          (+ encoder terms for whisper)

where layer1 / attn1 / ssm1 are dedicated single-iteration programs that
REUSE the real model code (chunked_attention with one kv block;
selective_scan with one chunk), compiled at the cell's exact shapes and
shardings.  Train variants take value_and_grad and add one extra forward
for the remat recompute, mirroring the full program's checkpoint policy.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell
from repro.launch import hlo_analysis
from repro.launch.steps import (DEFAULT_SERVE_ENGINE, param_specs,
                                serve_param_specs, cache_specs)
from repro.models import encdec
from repro.models.attention import chunked_attention
from repro.models.config import ModelConfig
from repro.models.ssm import selective_scan
from repro.models.transformer import _layer_apply
from repro.parallel import sharding as shd

Cost = Dict[str, float]


def _zero() -> Cost:
    return dict(flops=0.0, hbm_bytes=0.0, collective_bytes=0.0)


def _add(a: Cost, b: Cost, mult: float = 1.0) -> Cost:
    return {k: a[k] + mult * b[k] for k in a}


def _compile_cost(fn, args, mesh: Mesh) -> Cost:
    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
    fl, by = hlo_analysis.extract_cost(compiled)
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return dict(flops=fl, hbm_bytes=by, collective_bytes=coll.total_bytes)


def _slice_layer_specs(stacked_specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), stacked_specs)


def _attach_layer_shardings(layer_specs: Any, stacked_specs: Any,
                            mesh: Mesh) -> Any:
    stacked_shards = shd.param_shardings(stacked_specs, mesh)

    def strip(spec_leaf, shard_leaf):
        pspec = shard_leaf.spec
        return jax.ShapeDtypeStruct(
            spec_leaf.shape, spec_leaf.dtype,
            sharding=NamedSharding(mesh, P(*pspec[1:])))

    return jax.tree_util.tree_map(strip, layer_specs, stacked_shards)


def _x_spec(cfg: ModelConfig, b: int, s: int, mesh: Mesh):
    return shd.sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype),
                   NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=2)))


# ---------------------------------------------------------------------------
# inner-loop single-iteration programs (reuse real model code)
# ---------------------------------------------------------------------------

def _attn_block_cost(cfg: ModelConfig, b: int, sq: int, mesh: Mesh, *,
                     grad: bool, kv_heads: Optional[int] = None) -> Cost:
    """One kv-block of the chunked-attention scan at the cell's shapes."""
    bk = min(cfg.attn_block, sq)
    dt = jnp.dtype(cfg.dtype)
    hq = max(cfg.n_heads, 1)
    hkv = kv_heads if kv_heads is not None else max(cfg.n_kv_heads, 1)
    # mirror the sharding GSPMD picks inside the real layer: q heads over
    # "model" when divisible, else the query sequence dim (both flop-split
    # the attention by the model axis, as the full-layer HLO shows).
    nmod = mesh.shape["model"]
    bdp = shd.batch_pspec(b, mesh, extra_dims=0)[0]
    if hq % nmod == 0:
        qspec = P(bdp, "model", None, None)
    else:
        qspec = P(bdp, None, "model" if sq % nmod == 0 else None, None)
    bsh = NamedSharding(mesh, P(bdp, None, None, None))
    q = shd.sds((b, hq, sq, cfg.hd), dt, NamedSharding(mesh, qspec))
    k = shd.sds((b, hkv, bk, cfg.hd), dt, bsh)
    v = shd.sds((b, hkv, bk, cfg.hd), dt, bsh)

    def fwd(q, k, v):
        o = chunked_attention(q, k, v, causal=False, block=bk)
        return jnp.sum(o.astype(jnp.float32)) * 1e-6

    cost = _compile_cost(lambda q, k, v: chunked_attention(
        q, k, v, causal=False, block=bk), (q, k, v), mesh)
    if grad:
        vag = _compile_cost(jax.value_and_grad(fwd, argnums=(0, 1, 2)),
                            (q, k, v), mesh)
        cost = _add(cost, vag)          # remat: fwd recompute + (fwd+bwd)
    return cost


def _ssm_chunk_cost(cfg: ModelConfig, b: int, mesh: Mesh, *,
                    grad: bool) -> Cost:
    """One chunk of the selective-scan at the cell's shapes."""
    chunk = cfg.ssm_chunk
    di, n = cfg.d_inner, cfg.ssm_state
    dt_ = jnp.dtype(cfg.dtype)
    dsh = NamedSharding(mesh, P(shd.dp_axes(mesh) or None, None,
                                "model" if di % mesh.shape["model"] == 0
                                else None))
    x = shd.sds((b, chunk, di), dt_, dsh)
    dts = shd.sds((b, chunk, di), dt_, dsh)
    bc = shd.sds((b, chunk, n), dt_,
                 NamedSharding(mesh, shd.batch_pspec(b, mesh, extra_dims=2)))
    A = shd.sds((di, n), jnp.float32, NamedSharding(mesh, P(
        "model" if di % mesh.shape["model"] == 0 else None, None)))
    D = shd.sds((di,), jnp.float32, NamedSharding(mesh, P(None)))

    sdt = jnp.dtype(cfg.scan_dtype)

    def fwd(x, dt, A, B, C, D):
        y, _ = selective_scan(x, dt, A, B, C, D, chunk=chunk,
                              compute_dtype=sdt)
        return jnp.sum(y.astype(jnp.float32)) * 1e-6

    cost = _compile_cost(
        lambda x, dt, A, B, C, D: selective_scan(
            x, dt, A, B, C, D, chunk=chunk, compute_dtype=sdt)[0],
        (x, dts, A, bc, bc, D), mesh)
    if grad:
        vag = _compile_cost(jax.value_and_grad(fwd, argnums=(0, 1, 3, 4)),
                            (x, dts, A, bc, bc, D), mesh)
        cost = _add(cost, vag)
    return cost


# ---------------------------------------------------------------------------
# per-cell assembly
# ---------------------------------------------------------------------------

def loop_corrections(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> Cost:
    """L * (trip_count - 1) * single-iteration cost, for every inner loop."""
    total = _zero()
    b = cell.global_batch
    s = cell.seq_len
    grad = cell.kind == "train"
    if cell.kind == "decode":
        return total                       # decode paths have no inner loops

    has_attn = cfg.family in ("dense", "moe", "hybrid", "vlm", "encdec")
    if cfg.segmented_window_scan:
        # windowed fast path has no kv-block scan (vmap, fully counted in
        # the layer program); only the few global layers keep the loop —
        # their (n_blk-1) undercount is accepted and noted in EXPERIMENTS.
        has_attn = False
    if has_attn:
        n_blk = -(-s // cfg.attn_block)
        if n_blk > 1:
            attn1 = _attn_block_cost(cfg, b, s, mesh, grad=grad)
            total = _add(total, attn1, cfg.n_layers * (n_blk - 1))
        if cfg.family == "encdec":
            # encoder self-attention (n_audio_frames kv) + decoder cross
            n_enc_blk = -(-cfg.n_audio_frames // cfg.attn_block)
            if n_enc_blk > 1:
                enc1 = _attn_block_cost(cfg, b, cfg.n_audio_frames, mesh,
                                        grad=grad)
                total = _add(total, enc1,
                             cfg.n_encoder_layers * (n_enc_blk - 1))
                cross1 = _attn_block_cost(cfg, b, s, mesh, grad=grad)
                total = _add(total, cross1,
                             cfg.n_layers * (n_enc_blk - 1))
    if cfg.family in ("ssm", "hybrid"):
        n_chunk = -(-s // cfg.ssm_chunk)
        if n_chunk > 1:
            ssm1 = _ssm_chunk_cost(cfg, b, mesh, grad=grad)
            total = _add(total, ssm1, cfg.n_layers * (n_chunk - 1))
    return total


def layer_cost(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
               serve_bits: int = 8,
               engine_overrides: Optional[Dict] = None) -> Cost:
    """(L-1) x one-layer cost + inner-loop corrections, per device."""
    cfg = cfg.replace(dtype="bfloat16", remat=False)
    b = cell.global_batch
    s = cell.seq_len if cell.kind != "decode" else 1
    win = cfg.window

    results = _zero()

    if cell.kind == "train":
        pspecs = param_specs(cfg)
        xs = _x_spec(cfg, b, s, mesh)
        if cfg.family == "encdec":
            dec_specs = _attach_layer_shardings(
                _slice_layer_specs(pspecs["dec_layers"]),
                pspecs["dec_layers"], mesh)
            xe = _x_spec(cfg, b, cfg.n_audio_frames, mesh)

            def fn_dec(x, enc_out, p):
                fwd = jax.checkpoint(
                    lambda x, e, p: encdec.dec_train_layer_apply(x, e, p, cfg))

                def loss(x, e, p):
                    return jnp.sum(fwd(x, e, p).astype(jnp.float32)) * 1e-6

                return jax.value_and_grad(loss, argnums=(0, 1, 2))(x, enc_out, p)

            results = _add(results, _compile_cost(fn_dec, (xs, xe, dec_specs),
                                                  mesh),
                           cfg.n_layers - 1)
            enc_specs = _attach_layer_shardings(
                _slice_layer_specs(pspecs["enc_layers"]),
                pspecs["enc_layers"], mesh)

            def fn_enc(x, p):
                fwd = jax.checkpoint(
                    lambda x, p: encdec.enc_layer_apply(x, p, cfg))

                def loss(x, p):
                    return jnp.sum(fwd(x, p).astype(jnp.float32)) * 1e-6

                return jax.value_and_grad(loss, argnums=(0, 1))(x, p)

            results = _add(results, _compile_cost(fn_enc, (xe, enc_specs),
                                                  mesh),
                           cfg.n_encoder_layers - 1)
            return _add(results, loop_corrections(cfg, cell, mesh))

        layer_specs = _attach_layer_shardings(
            _slice_layer_specs(pspecs["layers"]), pspecs["layers"], mesh)
        train_engine = {"dp_axes": shd.dp_axes(mesh)}
        stat_win = cfg.window if cfg.segmented_window_scan else None
        eff_win = None if cfg.segmented_window_scan else win

        def fn(x, p):
            # jax.checkpoint reproduces the full program's remat policy so
            # the per-layer flops include the recomputed forward.
            fwd = jax.checkpoint(
                lambda x, p: _layer_apply(x, p, cfg, window=eff_win,
                                          static_window=stat_win,
                                          engine=train_engine)[0])

            def loss(x, p):
                return jnp.sum(fwd(x, p).astype(jnp.float32)) * 1e-6

            return jax.value_and_grad(loss, argnums=(0, 1))(x, p)

        results = _add(results, _compile_cost(fn, (xs, layer_specs), mesh),
                       cfg.n_layers - 1)
        return _add(results, loop_corrections(cfg, cell, mesh))

    # ---- serve (prefill/decode) ----
    serve_engine = dict(DEFAULT_SERVE_ENGINE, bits=serve_bits)
    if engine_overrides:
        serve_engine.update(engine_overrides)
    pspecs = serve_param_specs(cfg, serve_bits)
    cspecs = cache_specs(cfg, b, cell.seq_len)
    cshard = shd.cache_shardings(cspecs, mesh, b)
    cspecs = shd.with_shardings(cspecs, cshard)

    key = "dec_layers" if cfg.family == "encdec" else "layers"
    layer_specs = _attach_layer_shardings(
        _slice_layer_specs(pspecs[key]), pspecs[key], mesh)
    xs = _x_spec(cfg, b, s, mesh)
    pos_spec = shd.sds((), jnp.int32, NamedSharding(mesh, P()))

    def slice_cache(tree):
        return jax.tree_util.tree_map(
            lambda sp: jax.ShapeDtypeStruct(
                sp.shape[1:], sp.dtype,
                sharding=NamedSharding(mesh, P(*sp.sharding.spec[1:]))),
            tree)

    if cfg.family == "encdec":
        layer_cache = slice_cache(cspecs["kv"])
        xk = slice_cache(cspecs["xk"])
        xv = slice_cache(cspecs["xv"])

        def fn(x, p, kv, xk, xv, pos):
            return encdec.dec_layer_apply(x, p, kv, xk, xv, pos, cfg,
                                          engine=serve_engine)

        results = _add(results, _compile_cost(
            fn, (xs, layer_specs, layer_cache, xk, xv, pos_spec), mesh),
            cfg.n_layers - 1)
        if cell.kind == "prefill":
            enc_specs = _attach_layer_shardings(
                _slice_layer_specs(pspecs["enc_layers"]),
                pspecs["enc_layers"], mesh)
            xe = _x_spec(cfg, b, cfg.n_audio_frames, mesh)

            def fn_enc(x, p):
                return encdec.enc_layer_apply(x, p, cfg, engine=serve_engine)

            results = _add(results, _compile_cost(fn_enc, (xe, enc_specs),
                                                  mesh),
                           cfg.n_encoder_layers - 1)
        return _add(results, loop_corrections(cfg, cell, mesh))

    layer_cache = {k: slice_cache(v) for k, v in cspecs.items()}

    def fn(x, p, cache, pos):
        y, new_cache = _layer_apply(x, p, cfg, window=win, cache=cache,
                                    cache_pos=pos, engine=serve_engine)
        return y, new_cache

    results = _add(results, _compile_cost(
        fn, (xs, layer_specs, layer_cache, pos_spec), mesh),
        cfg.n_layers - 1)
    return _add(results, loop_corrections(cfg, cell, mesh))

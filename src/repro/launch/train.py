"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 50 --batch 8 --seq 256 --smoke

``--smoke`` runs the arch's reduced config on CPU; without it the full
config is used (intended for real TPU slices via the production mesh).
The loop is the fault-tolerant Trainer: step-indexed data, async atomic
checkpoints, straggler monitor, automatic restart.
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step, _init_fn
from repro.optim import adamw, cosine_schedule
from repro.runtime import Trainer, TrainerConfig, FailureInjector


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    init_fn = _init_fn(cfg)

    opt = adamw()
    train_step = jax.jit(make_train_step(cfg, opt, lr=args.lr))

    def init_state():
        params = init_fn(cfg, jax.random.PRNGKey(0))
        return dict(params=params, opt_state=opt.init(params))

    dataset = SyntheticLMDataset(
        cfg.vocab_size, args.seq, args.batch, family=cfg.family,
        d_model=cfg.d_model, n_frames=cfg.n_audio_frames,
        n_patches=cfg.n_patches)

    injector = (FailureInjector([args.inject_failure_at])
                if args.inject_failure_at >= 0 else None)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                      checkpoint_dir=args.ckpt_dir),
        train_step, init_state, dataset, failure_injector=injector)
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
          f"({len(losses)} steps, {out['restarts']} restarts)")
    return out


if __name__ == "__main__":
    main()

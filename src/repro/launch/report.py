"""Render EXPERIMENTS.md tables from dryrun_results/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir dryrun_results]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= scale:
            return f"{x/scale:.2f}{unit}"
    return f"{x*1e6:.3f}us"


def fmt_b(x: float) -> str:
    for unit, scale in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= scale:
            return f"{x/scale:.2f}{unit}"
    return f"{x:.0f}B"


def load(results_dir: Path):
    recs = {}
    for f in sorted(results_dir.glob("*.json")):
        recs[f.stem] = json.loads(f.read_text())
    return recs


def dryrun_table(recs) -> str:
    lines = ["| cell | mesh | status | compile | peak mem/chip | args/chip | collectives (per-chip bytes) |",
             "|---|---|---|---|---|---|---|"]
    for name, r in recs.items():
        if r.get("serve_bits", 8) != 8 or "_opt" in name:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {name} | - | SKIP (sub-quadratic-only shape) "
                         f"| - | - | - | - |")
            continue
        mem = r.get("memory", {})
        peak = mem.get("peak_memory_in_bytes", 0)
        args = mem.get("argument_size_in_bytes", 0)
        cc = r.get("collectives", {}).get("counts", {})
        cb = r.get("collectives", {}).get("total_bytes", 0)
        cstr = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
        lines.append(
            f"| {name} | {r.get('mesh','')} | {r['status']} "
            f"| {r.get('compile_s', 0):.1f}s | {fmt_b(peak)} | {fmt_b(args)} "
            f"| {cstr} ({fmt_b(cb)}) |")
    return "\n".join(lines)


def roofline_table(recs, multi_pod=False) -> str:
    lines = ["| arch x shape | compute | memory | collective | bound | "
             "MODEL_FLOPS | useful frac | lever |",
             "|---|---|---|---|---|---|---|---|"]
    for name, r in recs.items():
        if r["status"] != "ok" or r.get("serve_bits", 8) != 8 or "_opt" in name:
            continue
        if r.get("multi_pod", False) != multi_pod:
            continue
        rf = r["roofline"]
        tot = r["cost"].get("total_flops", 0)
        uf = r["model_flops"] / tot if tot else 0
        bound = rf["bound"]
        lever = {
            "compute": "more chips / lower precision matmuls",
            "memory": "fuse f32 converts, bf16 softmax/scan, cut activation round-trips",
            "collective": "resharding: drop FSDP gather for small params, EP all-to-all, DP-only batch axes",
        }[bound]
        lines.append(
            f"| {name.replace('_1pod','').replace('_2pod','')} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | **{bound}** "
            f"| {r['model_flops']:.2e} | {uf:.3f} | {lever} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    ap.add_argument("--section", default="all",
                    choices=("all", "dryrun", "roofline"))
    args = ap.parse_args()
    recs = load(Path(args.dir))
    if args.section in ("all", "dryrun"):
        print("## Dry-run table (both meshes)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline (single-pod 16x16, per chip)\n")
        print(roofline_table(recs, multi_pod=False))
        print()
        print("## Roofline (multi-pod 2x16x16, per chip)\n")
        print(roofline_table(recs, multi_pod=True))


if __name__ == "__main__":
    main()

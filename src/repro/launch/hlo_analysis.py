"""Post-compile HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic;
we parse the optimized HLO text and sum the *output* shapes of every
communication op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), splitting intra-pod ("data"/"model" axes, ICI) traffic
from cross-pod traffic by replica-group span when available.

Hardware constants: TPU v5e per chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link (~per-chip usable, 1 axis)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|\S+?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    bytes_by_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in line:
            continue                      # avoid double counting async pairs
        b = _shape_bytes(shape_str)
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
    return CollectiveStats(counts, bytes_by_kind)


@dataclasses.dataclass
class Roofline:
    """Roofline terms.  XLA's cost_analysis and the SPMD-partitioned HLO are
    PER-DEVICE programs (verified empirically in EXPERIMENTS.md §Dry-run), so
    the spec formula  total / (chips * rate)  reduces to  per_device / rate.
    """
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    n_chips: int

    @property
    def total_flops(self) -> float:
        return self.flops_per_chip * self.n_chips

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bound(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: overlapped terms -> max; the bound-term
        fraction of this is what hillclimbing drives down."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return dict(flops_per_chip=self.flops_per_chip,
                    hbm_bytes_per_chip=self.hbm_bytes_per_chip,
                    collective_bytes_per_chip=self.collective_bytes_per_chip,
                    total_flops=self.total_flops,
                    n_chips=self.n_chips, compute_s=self.compute_s,
                    memory_s=self.memory_s, collective_s=self.collective_s,
                    bound=self.bound, step_time_s=self.step_time_s)


def extract_cost(compiled) -> Tuple[float, float]:
    """(flops, bytes) from compiled.cost_analysis(), tolerant of backends."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return flops, byts


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:           # some backends don't implement it
        return dict(error=str(e))
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count on first backend init, and the production meshes need 512
placeholder host devices.

Per cell this driver records: memory_analysis (per-device bytes — proves it
fits), cost_analysis (FLOPs/bytes for the roofline), the collective-op
census parsed from the optimized HLO, and the three roofline terms.
Results go to dryrun_results/<cell>.json (resumable; failures recorded,
not fatal).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --serve-bits 4   # hillclimb
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.models.transformer import active_param_count, total_param_count

RESULTS_DIR = Path("dryrun_results")


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (per decode/prefill token)."""
    n_active = active_param_count(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cfg.family == "vlm" and cell.kind != "decode":
        tokens = cell.global_batch * cell.seq_len   # patches count as tokens
    mult = 6 if cell.kind == "train" else 2
    return float(mult * n_active * tokens)


def run_cell(arch: str, shape: str, multi_pod: bool, serve_bits: int = 8,
             tag: str = "", overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = dict(arch=arch, shape=shape, mesh="x".join(map(str, mesh.devices.shape)),
               multi_pod=multi_pod, n_chips=mesh.size, serve_bits=serve_bits,
               kind=cell.kind, status="start")
    t0 = time.time()
    fn, args, _ = build_cell(cfg, cell, mesh, serve_bits=serve_bits)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    flops, hbm_bytes = hlo_analysis.extract_cost(compiled)   # per-device
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo)                # per-device
    # XLA counts loop bodies once: add (L-1) x layer + inner-loop terms
    # measured from dedicated single-iteration programs (see microbench.py).
    from repro.launch.microbench import layer_cost
    lc = layer_cost(cfg, cell, mesh, serve_bits=serve_bits)
    roof = hlo_analysis.Roofline(
        flops_per_chip=flops + lc["flops"],
        hbm_bytes_per_chip=hbm_bytes + lc["hbm_bytes"],
        collective_bytes_per_chip=coll.total_bytes + lc["collective_bytes"],
        n_chips=mesh.size)
    mf = model_flops(cfg, cell)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=hlo_analysis.memory_analysis_dict(compiled),
        cost=dict(flops_per_chip_raw=flops, hbm_bytes_per_chip_raw=hbm_bytes,
                  layer_corrections=lc,
                  flops_per_chip=flops + lc["flops"],
                  hbm_bytes_per_chip=hbm_bytes + lc["hbm_bytes"],
                  total_flops=(flops + lc["flops"]) * mesh.size),
        collectives=dict(counts=coll.counts, bytes=coll.bytes_by_kind,
                         total_bytes=coll.total_bytes),
        roofline=roof.as_dict(),
        model_flops=mf,
        useful_flops_frac=(mf / ((flops + lc["flops"]) * mesh.size)
                           if flops else None),
        params_total=total_param_count(cfg),
        params_active=active_param_count(cfg),
        hlo_n_lines=hlo.count("\n"),
    )
    return rec


def cell_name(arch, shape, multi_pod, serve_bits, tag=""):
    pod = "2pod" if multi_pod else "1pod"
    suffix = f"_w{serve_bits}" if serve_bits != 8 else ""
    tag = f"_{tag}" if tag else ""
    return f"{arch}_{shape}_{pod}{suffix}{tag}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--serve-bits", type=int, default=8)
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. attn_dtype=bfloat16")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    RESULTS_DIR.mkdir(exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = cell_name(arch, shape, mp, args.serve_bits, args.tag)
                out = RESULTS_DIR / f"{name}.json"
                if out.exists() and not args.force:
                    print(f"[cached] {name}")
                    n_ok += 1
                    continue
                if not applicable(arch, shape):
                    rec = dict(arch=arch, shape=shape, multi_pod=mp,
                               status="skipped",
                               reason="long_500k requires sub-quadratic "
                                      "attention (DESIGN.md section 4)")
                    out.write_text(json.dumps(rec, indent=1))
                    print(f"[skip]   {name}")
                    n_skip += 1
                    continue
                try:
                    rec = run_cell(arch, shape, mp, args.serve_bits, args.tag,
                                   overrides=overrides)
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[ok]     {name}: compile {rec['compile_s']:.1f}s "
                          f"bound={r['bound']} compute={r['compute_s']:.2e}s "
                          f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s")
                except Exception as e:
                    rec = dict(arch=arch, shape=shape, multi_pod=mp,
                               status="error", error=str(e),
                               traceback=traceback.format_exc())
                    n_fail += 1
                    print(f"[FAIL]   {name}: {e}")
                out.write_text(json.dumps(rec, indent=1))
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

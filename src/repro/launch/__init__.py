# NOTE: dryrun must be imported/run as a fresh process (it sets XLA_FLAGS
# before importing jax); do not import repro.launch.dryrun from here.
from repro.launch import hlo_analysis, mesh, steps
__all__ = ["hlo_analysis", "mesh", "steps"]

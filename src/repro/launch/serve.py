"""Serving launcher: deadline-aware scheduling over the packed At-MRAM store.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --bits 4 --budget-mb 2 --deadline-ms 20

Freezes trained/random params into the packed WeightStore (the "MRAM
programming" step) and serves through the deadline-aware Scheduler
(repro.serving.sched): ``--scenario`` gives the legacy uniform placement,
``--budget-mb`` runs the greedy hot-set solver instead (hot params pinned
l1mram-resident, the rest paged l3flash — §II-B2 against the budget) and
attaches the live HostPagedStore so the cold pages stream host->device
between ticks, swap/miss counters included.

Multi-model tenancy (the paper's §V concurrent-workload story):

    PYTHONPATH=src python -m repro.launch.serve --smoke \
        --models qwen3-0.6b,falcon-mamba-7b --shared-budget-mb 0.05

serves every listed model through ONE MultiScheduler — a single
EDF-with-priority admission loop across tenants — with all models' cold
pages contending for one SharedPagePool device-bytes budget
(``--shared-budget-mb``; default 60% of the combined cold bytes, so the
pool genuinely churns).  Each tenant is verified bit-exact against
serving that model alone on a private pager.

Paged runs stream **asynchronously** by default (``--async-io``): the
scheduler begins tick t+1's host->device page stream while tick t
computes and fences at first use, so only the *exposed* wait lands on
the tick (``--sync-io`` restores the blocking stream-then-step tick).
When a plan pages, single-model runs are verified bit-exact against the
fully resident uniform plan AND — in async mode — against the
synchronous streaming path (disable with ``--no-verify``).  Metrics are
emitted as the ``repro.serving.metrics/v9`` JSON (stdout, and
``--metrics-json PATH`` to persist).

Mesh-sharded paging (ROADMAP 1(a); Siracusa's parallel memory-port
concurrency): ``--mesh N`` (or ``NxM``) builds an in-process
("data", "model") device mesh — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` to get K host
devices — and shards the paged store across the model axis: each device
streams ONLY its shard's pages over its own link
(:class:`repro.core.paging.ShardedPagedStore`), the tick's fence joins
all the per-device streams, and the ``ShardedPoolLedger`` aggregates the
per-device byte counters into one global ledger.  The greedy plan then
charges sharded params at 1/N per device (``shard_factors``).  The
verify leg re-serves single-device and asserts tokens BIT-EXACT plus the
ledger identities: global counters equal the static per-device
``kv_pass_counters`` prediction, global wire bytes equal the
single-device wire bytes, and every per-device link moves strictly
fewer.

Encoded (compressed) cold pages: ``--page-bits {8,4,2}`` stamps the
plan's paged placements with a page wire encoding, so every cold page
streams blockwise-quantized intN bytes + scales instead of the device
format, and the fetch path dequantizes back into the packed device
buffer.  ``--page-bits`` equal to ``--bits`` is the run-quantized
identity (wire form IS the device form) and stays bit-exact against the
resident plan; a narrower ``--page-bits`` is lossy, so the verify leg
compares against a resident engine whose cold weights took the same
encode->decode round trip (:func:`repro.core.paging.page_roundtrip_param`
— deterministic, hence bit-exact again).  The metrics' paging section
reports the split ledger: ``bytes_streamed_wire`` (link traffic) vs
``bytes_streamed_raw`` (fp32-dense equivalent).

Continuous batching (the 10–20 ms XR deadline machinery):
``--token-budget N`` re-plans a shared per-tick token budget across all
live slots (and, with ``--models``, across all tenants);
``--preemptive`` lets an urgent stream evict a strictly-lower-priority
slot mid-request (the victim checkpoints and later resumes bit-exactly);
``--admission reject|degrade`` refuses — or shortens — requests whose
predicted completion already misses their deadline.
"""

from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core.paging import (SharedPagePool, kv_pass_counters,
                               packed_tree_store, page_roundtrip_param,
                               page_sizes, thread_packed)
from repro.core.placement import (Placement, PlacementPlan, packed_sizes,
                                  plan_for_budget)
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MultiScheduler, Request, Scheduler,
                           ServingEngine, Tracer)
from repro.serving.trace import validate as validate_trace


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        8 + uid % 5).astype(np.int32),
                    max_new_tokens=max_new)
            for uid in range(n)]


def _fault_plan(args):
    """--fault-seed's seeded FaultPlan, or None when chaos is off."""
    if args.fault_seed is None:
        return None
    from repro.core.faults import FaultPlan
    return FaultPlan(seed=args.fault_seed, fail_rate=args.fault_rate,
                     bitflip_rate=args.fault_bitflip)


def _fetch_timeout_s(args):
    return (None if args.fetch_timeout_ms is None
            else args.fetch_timeout_ms / 1e3)


def _build_serve_mesh(spec):
    """--mesh's ("data", "model") mesh: "N" puts all N devices on the
    model axis ((1, N)); "DxM" is an explicit (data, model) grid.  Built
    through make_test_mesh, so a host with fewer devices clamps (with a
    warning) instead of crashing."""
    if spec is None:
        return None
    from repro.launch.mesh import make_test_mesh
    parts = spec.lower().split("x")
    try:
        dims = [int(p) for p in parts]
    except ValueError:
        raise SystemExit(f"--mesh wants N or DxM, got {spec!r}")
    if len(dims) == 1:
        shape = (1, dims[0])
    elif len(dims) == 2:
        shape = tuple(dims)
    else:
        raise SystemExit(f"--mesh wants N or DxM, got {spec!r}")
    if any(d < 1 for d in shape):
        raise SystemExit(f"--mesh dims must be >= 1, got {spec!r}")
    return make_test_mesh(shape, ("data", "model"))


def _mesh_shard_factors(packed, mesh):
    """{param name: n_shards} under the mesh's sharding rules — what
    plan_for_budget charges per device (computed pre-plan, so it covers
    every packable group; bits-independent, the shard axis is never the
    packed last dim)."""
    from repro.core.paging import store_shard_axes
    if mesh is None or "model" not in tuple(mesh.axis_names) \
            or int(mesh.shape["model"]) < 2:
        return None
    store = packed_tree_store(packed, None)
    return {name: n
            for name, (_ax, n) in store_shard_axes(store, None, mesh).items()}


def _serve(cfg, packed, plan, args, paged: bool,
           async_io: bool = None, kv_paged: bool = False, tracer=None,
           faults=None, mesh=None):
    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan, seed=args.seed)
    if paged:
        eng.attach_paging(faults=faults, mesh=mesh)
    if kv_paged:
        eng.attach_kv_paging(args.kv_block, faults=faults)
    sched = Scheduler(eng, prefill_chunk=args.prefill_chunk,
                      async_io=args.async_io if async_io is None
                      else async_io,
                      token_budget=args.token_budget,
                      preemptive=args.preemptive,
                      admission=args.admission,
                      fetch_timeout_s=(_fetch_timeout_s(args)
                                       if faults is not None else None),
                      tracer=tracer, trace_track=args.arch)
    sched.add_stream("xr", priority=1, deadline_ms=args.deadline_ms)
    sched.add_stream("background")
    for req in _requests(cfg, args.requests, args.max_new, seed=args.seed):
        sched.submit(req, stream="xr" if req.uid % 2 == 0 else "background")
    done = sched.run_until_done()
    return done, sched, eng


def _build_model(arch: str, args):
    """(cfg, packed, plan) for one tenant: smoke-scaled config, packed
    store, and a half-resident greedy plan (or --budget-mb's budget)."""
    cfg = get_config(arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "encdec":
        raise SystemExit(f"{arch}: serve launcher covers decoder-only "
                         f"archs; see examples/xr_pipeline.py for enc-dec")
    import zlib
    params = tfm.init_params(cfg, jax.random.PRNGKey(zlib.crc32(
        arch.encode()) % (1 << 31)))
    packed = freeze_for_serving(params, bits=args.bits)
    sizes = packed_sizes(packed)
    budget = (int(args.budget_mb * 1024 * 1024)
              if args.budget_mb is not None else sum(sizes.values()) // 2)
    plan = plan_for_budget(
        sizes, budget,
        hot=Placement("l1mram", args.bits, "resident"),
        cold=Placement("l3flash", args.bits, "paged", args.page_bits),
        sizes_bits=args.bits)
    return cfg, packed, plan


def _reference_packed(packed, plan, args):
    """Packed tree the resident reference engine serves.

    fp and run-quantized-identity page encodings are lossless, so the
    reference is the original tree.  A lossy ``--page-bits`` (narrower
    than ``--bits``) distorts every cold weight deterministically at
    encode time, so the reference's cold params take the same
    encode->decode round trip — the verify stays bit-exact."""
    if args.page_bits is None or args.page_bits == args.bits:
        return packed
    store = packed_tree_store(packed, plan)
    rt = {}
    for name, p in store.params.items():
        pl = plan.placement_for(name)
        if pl.residency == "paged" and pl.page_bits not in (None, pl.weight_bits):
            rt[name] = page_roundtrip_param(p, pl.page_bits)
    return thread_packed(packed, rt) if rt else packed


def _tenant_requests(cfg, args, salt):
    return _requests(cfg, args.requests, args.max_new,
                     seed=args.seed + salt)


def _serve_tenants(models, args, pool, tracer=None):
    """One MultiScheduler pass over every tenant; returns (ms, done)."""
    ms = MultiScheduler(pool=pool, async_io=args.async_io,
                        token_budget=args.token_budget,
                        preemptive=args.preemptive,
                        admission=args.admission,
                        fetch_timeout_s=_fetch_timeout_s(args),
                        faults=_fault_plan(args),
                        tracer=tracer)
    for name, (cfg, packed, plan) in models.items():
        eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                            max_len=args.max_len, plan=plan,
                            seed=args.seed)
        ms.add_model(name, eng, prefill_chunk=args.prefill_chunk,
                     kv_paged=args.kv_paged, kv_block_rows=args.kv_block)
        ms.add_stream(name, "xr", priority=1, deadline_ms=args.deadline_ms)
        ms.add_stream(name, "background")
    for salt, (name, (cfg, _p, _pl)) in enumerate(models.items()):
        for req in _tenant_requests(cfg, args, salt):
            ms.submit(name, req,
                      stream="xr" if req.uid % 2 == 0 else "background")
    done = ms.run_until_done()
    return ms, done


def _serve_solo(name, cfg, packed, plan, args, salt):
    """The tenant served ALONE on a private pager — the bit-exactness
    reference the shared pool must not perturb."""
    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan, seed=args.seed)
    sizes = packed_sizes(packed)
    if plan.paged_bytes(sizes) > 0:
        eng.attach_paging()
    if args.kv_paged and "kv" in eng.cache:
        eng.attach_kv_paging(args.kv_block)
    sched = Scheduler(eng, prefill_chunk=args.prefill_chunk,
                      async_io=args.async_io)
    sched.add_stream("xr", priority=1, deadline_ms=args.deadline_ms)
    sched.add_stream("background")
    for req in _tenant_requests(cfg, args, salt):
        sched.submit(req, stream="xr" if req.uid % 2 == 0 else "background")
    done = sched.run_until_done()
    if eng.pager is not None:
        eng.pager.close()
    if eng.kv_table is not None:
        eng.kv_table.close()
    return {r.uid: r.generated for r in done}


def _main_multi(args):
    archs = [a.strip() for a in args.models.split(",") if a.strip()]
    if len(archs) < 2:
        raise SystemExit("--models wants >= 2 comma-separated archs")
    models = {}
    for arch in archs:
        name = arch
        i = 2
        while name in models:            # same arch twice = two tenants
            name = f"{arch}#{i}"
            i += 1
        models[name] = _build_model(arch, args)

    cold = {name: plan.paged_bytes(packed_sizes(packed))
            for name, (_c, packed, plan) in models.items()}
    total_cold = sum(cold.values())
    if args.shared_budget_mb is not None:
        budget = int(args.shared_budget_mb * 1024 * 1024)
    else:
        budget = max(int(total_cold * 0.6), 1)
    print(f"tenants: {', '.join(models)}; cold bytes "
          f"{ {n: c for n, c in cold.items()} }, shared pool budget "
          f"{budget} B")

    pool = SharedPagePool(budget) if total_cold > 0 else None
    tracer = Tracer() if args.trace_json else None
    ms, done = _serve_tenants(models, args, pool, tracer=tracer)
    doc = ms.summary()
    for name in models:
        reqs = doc["models"][name]["requests"]
        dl = doc["models"][name]["deadlines"]
        print(f"  {name}: {reqs['count']} requests, {reqs['tokens_out']} "
              f"tokens, deadline misses {dl['missed']}/{dl['with_deadline']}")
    if pool is not None:
        ps = doc["shared_pool"]
        print(f"  shared pool: {ps['cached_pages']} pages cached "
              f"({ps['live_bytes']}/{ps['budget_bytes']} B device, "
              f"{ps['live_wire_bytes']} B wire), "
              f"{ps['evictions']} cross-model evictions; "
              f"{ps['bytes_streamed_wire']} B wire streamed for "
              f"{ps['bytes_streamed_raw']} B raw")
        # kv_pass_counters replays the pool's full event log (weight
        # passes AND kv batches/drops), so one prediction covers every
        # member; on a weights-only run it equals shared_pass_counters.
        # page_sizes hands it (device, wire, raw) triples, so the replay
        # predicts the wire/raw byte ledgers too, not just swap counts.
        pred = kv_pass_counters(
            {name: page_sizes(ms.model(name).engine.pager.pages)
             for name in models
             if ms.model(name).engine.pager is not None},
            pool.budget_bytes, events=pool.events)
        pred_ok = all(
            all(ps["models"][m][k] == pred[m][k]
                for k in ("swaps", "misses", "pool_hits", "evicted"))
            and ps["models"][m]["bytes_streamed_wire"] == pred[m]["bytes_wire"]
            and ps["models"][m]["bytes_streamed_raw"] == pred[m]["bytes_raw"]
            for m in pred)
        print("  pool counters (incl. wire/raw bytes) "
              + ("MATCH" if pred_ok else "DIVERGE FROM")
              + " the static kv_pass_counters prediction")
    else:
        pred_ok = True

    ok = pred_ok
    if not args.no_verify:
        for salt, (name, (cfg, packed, plan)) in enumerate(models.items()):
            want = _serve_solo(name, cfg, packed, plan, args, salt)
            got = {r.uid: r.generated for r in done.get(name, [])}
            exact = got == want
            ok = ok and exact
            print(f"  verify {name}: tokens "
                  + ("BIT-EXACT vs solo private pager" if exact
                     else "MISMATCH vs solo private pager"))

    print(ms.to_json())
    if args.metrics_json:
        ms.write(args.metrics_json)
        print(f"metrics written to {args.metrics_json}")
    if tracer is not None:
        validate_trace(tracer.to_dict())
        tracer.write(args.trace_json)
        print(f"trace written to {args.trace_json} "
              f"({tracer.event_count} events on "
              f"{len(tracer.track_names)} tracks); load it in "
              f"chrome://tracing or https://ui.perfetto.dev")
    ms.close()
    if not ok:
        sys.exit(1)
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--models", default=None,
                    help="comma-separated archs served as tenants of ONE "
                         "MultiScheduler + SharedPagePool (overrides "
                         "--arch/--scenario)")
    ap.add_argument("--shared-budget-mb", type=float, default=None,
                    help="SharedPagePool device budget in MiB for --models "
                         "runs; default 60%% of the combined cold bytes")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--bits", type=int, default=8, choices=(2, 4, 8))
    ap.add_argument("--page-bits", type=int, default=None,
                    choices=(2, 4, 8),
                    help="wire encoding for COLD pages: stream blockwise-"
                         "quantized intN payload + scales and dequantize "
                         "at fetch (default: stream the packed device "
                         "format verbatim). Equal to --bits is the zero-"
                         "decode identity; narrower is lossy and verified "
                         "against a codec-round-tripped resident "
                         "reference")
    ap.add_argument("--scenario", default="l1mram",
                    choices=("l1mram", "l2mram", "l3mram", "l3flash"))
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="resident MRAM budget in MiB; enables the greedy "
                         "hot-set plan (mixed placement) and live paged-"
                         "weight streaming instead of the uniform "
                         "--scenario")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for the 'xr' stream (EDF "
                         "admission; misses are reported, not dropped)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max prompt tokens absorbed per tick per slot")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="continuous batching: per-tick token budget "
                         "re-planned every tick across prefill chunks "
                         "and decode steps (with --models, ONE budget "
                         "shared across all tenants)")
    ap.add_argument("--preemptive", action="store_true",
                    help="allow an urgent stream to evict a strictly-"
                         "lower-priority slot mid-request; the victim "
                         "checkpoints and later resumes bit-exactly")
    ap.add_argument("--admission", default=None,
                    choices=("reject", "degrade"),
                    help="admission control: refuse (or shorten to fit) "
                         "requests whose predicted completion already "
                         "misses their deadline")
    ap.add_argument("--kv-paged", action="store_true",
                    help="page the per-slot KV cache through the same "
                         "budgeted page stream as the weights (one memory "
                         "hierarchy; with --models, KV blocks join the "
                         "SharedPagePool as <model>/kv members)")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="KV page size in cache rows (vLLM-style fixed "
                         "blocks)")
    ap.add_argument("--mesh", default=None, metavar="N|DxM",
                    help="shard the paged store across an in-process "
                         "('data', 'model') device mesh: N devices on "
                         "the model axis (or an explicit DxM grid), each "
                         "streaming only its shard's pages over its own "
                         "link, joined at the tick fence under one "
                         "global byte ledger.  Run with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=K; "
                         "shapes clamp (with a warning) to the devices "
                         "present.  The verify leg re-serves single-"
                         "device and asserts tokens bit-exact plus the "
                         "ledger/prediction identities")
    io = ap.add_mutually_exclusive_group()
    io.add_argument("--async-io", dest="async_io", action="store_true",
                    default=True,
                    help="overlap the next tick's page stream with this "
                         "tick's compute, fencing at first use (default)")
    io.add_argument("--sync-io", dest="async_io", action="store_false",
                    help="block the tick on the full page stream (the "
                         "pre-overlap schedule the async path is "
                         "verified bit-exact against)")
    ap.add_argument("--metrics-json", default=None,
                    help="also write the metrics JSON to this path")
    ap.add_argument("--trace-json", default=None,
                    help="record the tick pipeline as a Chrome Trace "
                         "Event JSON at this path (per-tenant fence/"
                         "admit/begin/compute spans, per-page I/O spans, "
                         "preempt/evict instants, and the predicted-vs-"
                         "measured stall overlay); open in "
                         "chrome://tracing or ui.perfetto.dev")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="chaos mode: run every page fetch under a "
                         "seeded FaultPlan (transient failures retried "
                         "with backoff, wire bit-flips caught by the "
                         "page CRC and re-fetched); the verify leg then "
                         "demonstrates tokens stay bit-exact vs the "
                         "fault-free resident reference")
    ap.add_argument("--fault-rate", type=float, default=0.15,
                    help="transient fetch-failure probability per "
                         "(page, attempt) under --fault-seed")
    ap.add_argument("--fault-bitflip", type=float, default=0.15,
                    help="wire bit-flip probability per (page, attempt) "
                         "under --fault-seed")
    ap.add_argument("--fetch-timeout-ms", type=float, default=None,
                    help="fence deadline per tick: a page stream that "
                         "exceeds it defers that model's tick (the pass "
                         "stays resumable) instead of blocking the "
                         "scheduler; counted as faults.fetch_timeouts/"
                         "deferred_ticks")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the bit-exact check of the paged run "
                         "against the fully resident plan")
    args = ap.parse_args(argv)

    if args.models is not None:
        return _main_multi(args)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        if args.budget_mb is not None:
            # the default smoke net packs < 0.1 MiB — nothing would page.
            # Scale it so a MiB-order budget genuinely splits the store and
            # the §II-B2 streaming path is exercised.
            cfg = cfg.replace(n_layers=6, d_model=256, n_heads=4,
                              n_kv_heads=2, head_dim=64, d_ff=1024)
    if cfg.family == "encdec":
        raise SystemExit("serve launcher covers decoder-only archs; "
                         "see examples/xr_pipeline.py for enc-dec")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=args.bits)
    mesh = _build_serve_mesh(args.mesh)
    shard_factors = _mesh_shard_factors(packed, mesh)
    mesh_active = shard_factors is not None
    if args.mesh is not None and not mesh_active:
        print("--mesh: model axis clamped to 1 device; serving unsharded")
    if args.budget_mb is not None:
        # greedy hot-set plan over exactly the packed leaves the serving
        # dispatch reads (PACKABLE matmul weights; embed/norms never page)
        sizes = packed_sizes(packed)
        plan = plan_for_budget(
            sizes, int(args.budget_mb * 1024 * 1024),
            hot=Placement("l1mram", args.bits, "resident"),
            cold=Placement("l3flash", args.bits, "paged", args.page_bits),
            sizes_bits=args.bits, shard_factors=shard_factors)
        print(plan.summary(sizes))
        paged = plan.paged_bytes(sizes) > 0
    else:
        plan = PlacementPlan.uniform(args.scenario, bits=args.bits)
        paged = False
    if mesh_active and not paged:
        print("--mesh: nothing paged under this plan; serving unsharded")
        mesh_active = False

    tracer = Tracer() if args.trace_json else None
    done, sched, eng = _serve(cfg, packed, plan, args, paged,
                              kv_paged=args.kv_paged, tracer=tracer,
                              faults=_fault_plan(args),
                              mesh=mesh if mesh_active else None)
    total_tokens = sum(len(r.generated) for r in done)
    place = ("mixed:" + "+".join(plan.scenarios_used())
             if not plan.is_uniform else plan.default.scenario)
    summary = sched.metrics.summary(paging=eng.paging_summary(),
                                    faults=sched.faults_summary())
    thr = summary["throughput"]
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{thr['wall_s']:.2f}s ({thr['tok_per_s']:.1f} tok/s) "
          f"[W{args.bits}, {place}] over {sched.ticks} ticks")
    if paged:
        pg = summary["paging"]
        enc = "fp" if args.page_bits is None else f"int{args.page_bits}"
        wire, raw = pg["bytes_streamed_wire"], pg["bytes_streamed_raw"]
        print(f"live paging ({'async' if args.async_io else 'sync'}): "
              f"{len(eng.pager.pages)} pages, "
              f"{eng.swap_count} swaps, {eng.miss_count} demand misses, "
              f"{pg['exposed_s'] * 1e3:.1f} ms exposed + "
              f"{pg['hidden_s'] * 1e3:.1f} ms hidden behind compute "
              f"(overlap {pg['overlap_frac'] * 100:.0f}%)")
        if wire:
            print(f"page wire ({enc}): {wire} B streamed for {raw} B raw "
                  f"(x{raw / wire:.2f} compression vs fp32 dense)")
    mesh_doc = None
    if paged and mesh_active:
        # the ledger's determinism contract: runtime per-device counters,
        # summed, equal the static per-device kv_pass_counters replay
        pred = eng.pager.predict(eng.page_resident_slots)
        led = eng.pager.ledger.summary()
        pred_ok = (led["swap_count"] == pred["swaps"]
                   and led["miss_count"] == pred["misses"]
                   and led["bytes_streamed_wire"] == pred["bytes_wire"]
                   and led["bytes_streamed_raw"] == pred["bytes_raw"])
        shape_s = "x".join(str(int(mesh.shape[a])) for a in mesh.axis_names)
        link_wire = [d["bytes_streamed_wire"] for d in led["per_device"]]
        print(f"mesh {shape_s}: {eng.pager.n_shards} device links, "
              f"{len(eng.pager.shard_axes)} params sharded; per-link wire "
              f"{link_wire} B; global ledger "
              + ("MATCHES" if pred_ok else "DIVERGES FROM")
              + " the static kv_pass_counters prediction")
        mesh_doc = dict(shape=shape_s, n_devices=eng.pager.n_shards,
                        sharded_params=len(eng.pager.shard_axes),
                        ledger=led, predicted=pred, predicted_ok=pred_ok)
    if args.kv_paged:
        pg = summary["paging"]
        print(f"kv paging: {pg['kv_block_rows']}-row blocks, "
              f"{pg['kv_swaps']} swaps, {pg['kv_pool_hits']} pool hits, "
              f"{pg['kv_writebacks']} writebacks, "
              f"{pg['kv_dropped']} dropped; "
              f"{pg['kv_exposed_s'] * 1e3:.1f} ms exposed + "
              f"{pg['kv_hidden_s'] * 1e3:.1f} ms hidden")
    if args.deadline_ms is not None:
        dl = summary["deadlines"]
        print(f"deadlines: {dl['missed']}/{dl['with_deadline']} missed "
              f"({dl['miss_rate'] * 100:.0f}% at {args.deadline_ms} ms)")
    if args.fault_seed is not None or args.fetch_timeout_ms is not None:
        ft = summary["faults"]
        print(f"faults: {ft['injected']} injected, {ft['retries']} "
              f"retries, {ft['checksum_failures']} checksum failures "
              f"(all re-fetched: {ft['refetches']}), "
              f"{ft['fetch_timeouts']} fetch timeouts, "
              f"{ft['deferred_ticks']} ticks deferred")
    if args.token_budget or args.preemptive or args.admission:
        sc = summary["scheduler"]
        print(f"scheduler: {sc['preemptions']} preemptions / "
              f"{sc['restores']} restores, {sc['rejected']} rejected, "
              f"{sc['degraded']} degraded"
              + (f"; budget use {sc['budget_used_mean']:.1f}"
                 f"/{sc['budget_tokens_per_tick']} tok/tick"
                 if args.token_budget else ""))

    ok = mesh_doc is None or mesh_doc["predicted_ok"]
    if (paged or args.kv_paged) and not args.no_verify:
        # the resident reference serves with fully resident weights AND a
        # fully resident KV cache — the pre-paging engine the paged runs
        # must match token for token
        ref, _sched2, _eng2 = _serve(
            cfg, _reference_packed(packed, plan, args),
            PlacementPlan.uniform("l1mram", bits=args.bits), args,
            paged=False)
        got = {r.uid: r.generated for r in done}
        want = {r.uid: r.generated for r in ref}
        ok = got == want
        lossy = (paged and args.page_bits is not None
                 and args.page_bits != args.bits)
        ref_name = ("resident plan (codec round-tripped cold weights)"
                    if lossy else "resident plan")
        print("verify: paged tokens "
              + (f"BIT-EXACT vs {ref_name}" if ok
                 else f"MISMATCH vs {ref_name}"))
        if args.async_io:
            # the overlapped pipeline must change WHEN pages move, never
            # what the step computes: re-serve on the blocking sync path
            # (on a mesh, the sync leg is ALSO meshed — same N links,
            # demand-fenced)
            sref, ssched, seng = _serve(cfg, packed, plan, args,
                                        paged=paged, async_io=False,
                                        kv_paged=args.kv_paged,
                                        faults=_fault_plan(args),
                                        mesh=mesh if mesh_active else None)
            sync_tokens = {r.uid: r.generated for r in sref}
            sync_ok = got == sync_tokens
            ctr_ok = (seng.swap_count == eng.swap_count
                      and seng.miss_count == eng.miss_count
                      and ssched.ticks == sched.ticks)
            ok = ok and sync_ok and ctr_ok
            print("verify: async tokens "
                  + ("BIT-EXACT vs sync streaming" if sync_ok
                     else "MISMATCH vs sync streaming")
                  + (", counters unchanged by overlap" if ctr_ok
                     else f", counters DIVERGED (sync "
                          f"{seng.swap_count}/{seng.miss_count} vs async "
                          f"{eng.swap_count}/{eng.miss_count})"))
            if seng.pager is not None:
                seng.pager.close()
            if seng.kv_table is not None:
                seng.kv_table.close()
        if paged and mesh_active:
            # the headline guarantee: the mesh changes WHERE pages live
            # and WHICH link moves them, never what the step computes —
            # the single-device paged run (same plan) must match token
            # for token, tick for tick, and the byte ledgers must obey
            # the sharding algebra: global wire/raw EQUAL (every shard's
            # rows cross exactly one link, replicated params page once on
            # device 0), per-link wire STRICTLY SMALLER when anything
            # shards.
            uref, usched, ueng = _serve(cfg, packed, plan, args,
                                        paged=True,
                                        kv_paged=args.kv_paged,
                                        faults=_fault_plan(args))
            uni_tokens = {r.uid: r.generated for r in uref}
            mesh_exact = (got == uni_tokens
                          and usched.ticks == sched.ticks)
            single_wire = ueng.pager.bytes_streamed_wire
            single_raw = ueng.pager.bytes_streamed_raw
            link_max = max(d["bytes_streamed_wire"]
                           for d in mesh_doc["ledger"]["per_device"])
            ledger_ok = (eng.pager.bytes_streamed_wire == single_wire
                         and eng.pager.bytes_streamed_raw == single_raw
                         and (not eng.pager.shard_axes
                              or link_max < single_wire))
            ok = ok and mesh_exact and ledger_ok
            print("verify: mesh tokens "
                  + ("BIT-EXACT vs single-device paged run" if mesh_exact
                     else "MISMATCH vs single-device paged run")
                  + (", byte ledger obeys the sharding algebra"
                     if ledger_ok else
                     f", ledger VIOLATION (global {eng.pager.bytes_streamed_wire}"
                     f"/{eng.pager.bytes_streamed_raw} B vs single "
                     f"{single_wire}/{single_raw} B, link max {link_max} B)"))
            mesh_doc.update(
                bit_exact=mesh_exact, ledger_ok=ledger_ok,
                per_link_max_wire=int(link_max),
                single_device=dict(bytes_streamed_wire=int(single_wire),
                                   bytes_streamed_raw=int(single_raw),
                                   swaps=int(ueng.pager.swap_count),
                                   ticks=int(usched.ticks)))
            ueng.pager.close()
            if ueng.kv_table is not None:
                ueng.kv_table.close()

    print(sched.metrics.to_json(paging=eng.paging_summary(),
                                trace=sched.trace_summary(),
                                faults=sched.faults_summary()))
    if args.metrics_json:
        sched.metrics.write(args.metrics_json,
                            paging=eng.paging_summary(),
                            trace=sched.trace_summary(),
                            faults=sched.faults_summary(),
                            **({"mesh": mesh_doc} if mesh_doc else {}))
        print(f"metrics written to {args.metrics_json}")
    if tracer is not None:
        validate_trace(tracer.to_dict())
        tracer.write(args.trace_json)
        print(f"trace written to {args.trace_json} "
              f"({tracer.event_count} events on "
              f"{len(tracer.track_names)} tracks); load it in "
              f"chrome://tracing or https://ui.perfetto.dev")
    if not ok:
        sys.exit(1)
    return done


if __name__ == "__main__":
    main()

"""Serving launcher: batched requests over the packed At-MRAM store.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --bits 4 --budget-mb 2

Freezes trained/random params into the packed WeightStore (the "MRAM
programming" step) and runs the continuous-batching engine under a
PlacementPlan: ``--scenario`` gives the legacy uniform placement,
``--budget-mb`` runs the greedy hot-set solver instead (hot params pinned
l1mram-resident, the rest paged l3flash — §II-B2 against the budget).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.placement import PlacementPlan, packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--bits", type=int, default=8, choices=(2, 4, 8))
    ap.add_argument("--scenario", default="l1mram",
                    choices=("l1mram", "l2mram", "l3mram", "l3flash"))
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="resident MRAM budget in MiB; enables the greedy "
                         "hot-set plan (mixed placement) instead of the "
                         "uniform --scenario")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "encdec":
        raise SystemExit("serve launcher covers decoder-only archs; "
                         "see examples/xr_pipeline.py for enc-dec")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=args.bits)
    if args.budget_mb is not None:
        # greedy hot-set plan over exactly the packed leaves the serving
        # dispatch reads (PACKABLE matmul weights; embed/norms never page)
        from repro.core.placement import Placement
        sizes = packed_sizes(packed)
        plan = plan_for_budget(
            sizes, int(args.budget_mb * 1024 * 1024),
            hot=Placement("l1mram", args.bits, "resident"),
            cold=Placement("l3flash", args.bits, "paged"))
        print(plan.summary(sizes))
    else:
        plan = PlacementPlan.uniform(args.scenario, bits=args.bits)

    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               8 + uid % 5).astype(np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    place = ("mixed:" + "+".join(plan.scenarios_used())
             if not plan.is_uniform else plan.default.scenario)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s) [W{args.bits}, {place}]")
    return done


if __name__ == "__main__":
    main()

"""Serving launcher: batched requests over the packed At-MRAM store.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --bits 4 --paged

Freezes trained/random params into the packed WeightStore (the "MRAM
programming" step), optionally pages them through a resident budget
(core/paging), and runs the continuous-batching engine.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--bits", type=int, default=8, choices=(2, 4, 8))
    ap.add_argument("--scenario", default="l1mram",
                    choices=("l1mram", "l2mram", "l3mram", "l3flash"))
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "encdec":
        raise SystemExit("serve launcher covers decoder-only archs; "
                         "see examples/xr_pipeline.py for enc-dec")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=args.bits)
    engine = dict(scenario=args.scenario, mode="xla", bits=args.bits)

    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, engine=engine)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               8 + uid % 5).astype(np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s) [W{args.bits}, {args.scenario}]")
    return done


if __name__ == "__main__":
    main()

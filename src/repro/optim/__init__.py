from repro.optim.optimizers import (Optimizer, adamw, adafactor,
                                    clip_by_global_norm, pick_optimizer)
from repro.optim.schedules import cosine_schedule, linear_warmup

__all__ = ["Optimizer", "adamw", "adafactor", "clip_by_global_norm",
           "pick_optimizer", "cosine_schedule", "linear_warmup"]

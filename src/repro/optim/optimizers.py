"""Optimizers (pure JAX; no external deps).

AdamW for standard sizes; Adafactor (factored second moment, no first
moment) for the 100B+ archs where AdamW state would blow the per-chip HBM
budget at the assigned mesh (DESIGN.md §3).  Both are functional:
``init(params) -> state``, ``update(grads, state, params, lr) ->
(new_params, new_state)``; states are pytrees that shard exactly like the
parameters they mirror.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return dict(mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params),
                    count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"],
                                     params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return new_params, dict(mu=mu, nu=nu, count=count)

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moments for >= 2-D params
# ---------------------------------------------------------------------------

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_rate: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    # State is kept as a FLAT LIST aligned with tree_flatten(params) order —
    # per-param factored/unfactored dicts must not be traversed as pytrees
    # alongside the param tree.
    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        states = []
        for p in leaves:
            if _factored(p):
                states.append(dict(vr=jnp.zeros(p.shape[:-1], jnp.float32),
                                   vc=jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                                jnp.float32)))
            else:
                states.append(dict(v=jnp.zeros(p.shape, jnp.float32)))
        return dict(v=states, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay_rate

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]
                v_est = (vr[..., None] * vc[..., None, :]) / denom
                step = g * jax.lax.rsqrt(v_est + eps)
                new_s = dict(vr=vr, vc=vc)
            else:
                v = beta * s["v"] + (1 - beta) * g2
                step = g * jax.lax.rsqrt(v + eps)
                new_s = dict(v=v)
            # update clipping (RMS of step <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_s

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        results = [upd(g, s, p)
                   for g, s, p in zip(leaves_g, state["v"], leaves_p)]
        new_params = treedef.unflatten([r[0] for r in results])
        return new_params, dict(v=[r[1] for r in results], count=count)

    return Optimizer("adafactor", init, update)


def pick_optimizer(total_params: int, hbm_budget_per_chip: float = 16e9,
                   n_chips: int = 256) -> Optimizer:
    """AdamW (12 B/param incl. bf16 grads) if it fits; else Adafactor."""
    adamw_bytes = total_params * 12
    if adamw_bytes / n_chips < 0.6 * hbm_budget_per_chip:
        return adamw()
    return adafactor()

"""Calibrated analytical model of the Siracusa memory system + N-EUREKA.

This container has no 16 nm silicon; the paper's SoC-level numbers (Tables
I-III, Figs 7-11) are reproduced with an analytical model whose *structure*
follows the architecture (double-buffered tiled execution, per-interface
bandwidths, per-component energies) and whose constants are calibrated to
the paper's published anchor measurements.  Tests assert the model
reproduces the paper's end-to-end claims within tolerance; the same model
drives the scenario study and the layer-wise regime analysis.

All bandwidths in bytes/s, energies in J, times in s.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.placement import SCENARIOS, PlacementPlan, ScenarioCost

# ---------------------------------------------------------------------------
# Operating points (paper Table I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    name: str
    voltage: float
    cluster_hz: float
    mram_hz: float
    cluster_power_w: float        # incl. MRAM (Table I)
    mram_power_w: float


NOMINAL = OperatingPoint("nominal", 0.80, 360e6, 180e6, 0.332, 0.069)
LOW_POWER = OperatingPoint("low_power", 0.65, 210e6, 105e6, 0.151, 0.040)

TABLE_I = [
    OperatingPoint("0.65V", 0.65, 210e6, 105e6, 0.151, 0.040),
    OperatingPoint("0.70V", 0.70, 250e6, 125e6, 0.196, 0.047),
    OperatingPoint("0.75V", 0.75, 310e6, 155e6, 0.261, 0.058),
    OperatingPoint("0.80V", 0.80, 360e6, 180e6, 0.332, 0.069),
]

# ---------------------------------------------------------------------------
# Interface bandwidths (paper §II) at an operating point
# ---------------------------------------------------------------------------

def mram_port_Bps(op: OperatingPoint) -> float:
    """Dedicated N-EUREKA<-MRAM port: 256 bit/cluster-cycle (92 Gbit/s @360)."""
    return 256 / 8 * op.cluster_hz


def l1_neureka_Bps(op: OperatingPoint) -> float:
    """N-EUREKA shallow-branch port to L1 TCDM: 256 useful bits/cycle."""
    return 256 / 8 * op.cluster_hz


def l1_total_Bps(op: OperatingPoint) -> float:
    """Full L1 TCDM: 16 banks x 32 bit/cycle = 184 Gbit/s @ 360 MHz."""
    return 16 * 32 / 8 * op.cluster_hz


def cluster_dma_Bps(op: OperatingPoint) -> float:
    """64-bit AXI Cluster-DMA (L2<->L1, and AXI access to neural mem):
    23 Gbit/s @ 360 MHz; DMA_EFFICIENCY models 2D strided tile bursts."""
    return 64 / 8 * op.cluster_hz * DMA_EFFICIENCY


def io_dma_Bps(op: OperatingPoint) -> float:
    """32-bit AXI CDC used by the IO-DMA for background weight pages."""
    return IO_DMA_32B_BPS_AT_NOMINAL * (op.cluster_hz / 360e6)


# Off-chip HyperBus flash read bandwidth.  Calibrated (with the energy
# constants below) so the L3FLASH MobileNet-V2 walk reproduces the paper's
# 12.6 ms / 3.8 mJ; a 16-bit DDR HyperBus at ~200 MT/s lands in this range.
HYPERBUS_BPS = 550e6          # bytes/s, voltage-independent (IO domain)

# ---------------------------------------------------------------------------
# Energy constants (J/byte moved, J/op computed).  Sources:
#   * off-chip: calibrated so off-chip share of L3FLASH = 55% of 3.8 mJ
#   * MRAM read: 69 mW at 5.76 GB/s streaming (Table I) ~ 12 pJ/B incl.
#     periphery; background (L3/L2) use adds AXI+DMA hop energy
#   * compute: 698 GOp/s @ (332-69) mW burn ~ 0.35 pJ/Op core datapath at
#     0.8 V; scaled by V^2 at other points
# ---------------------------------------------------------------------------

E_OFFCHIP_PER_B = 560e-12     # HyperBus + IO pads + L2 write
E_MRAM_READ_PER_B = 40e-12    # MRAM array + periphery read
E_AXI_HOP_PER_B = 20e-12      # background-memory access adds interconnect hop
E_DMA_L2L1_PER_B = 9e-12      # Cluster-DMA transfer L2<->L1
E_L1_ACCESS_PER_B = 11e-12    # TCDM/tile access incl. engine-side load
E_OP = 0.350e-12              # N-EUREKA datapath J/Op (1 MAC = 2 Op) @ 0.8 V
P_CLUSTER_BASE_W = 0.110      # non-datapath cluster power (clock tree, cores idle)

# 2D strided HWC tile transfers interrupt AXI bursts at row boundaries;
# sustained DMA efficiency on feature-map tiles (calibration: Fig 10/11).
DMA_EFFICIENCY = 0.65
# IO-DMA 32-bit AXI CDC used for background (L3) page traffic (paper II-B2)
IO_DMA_32B_BPS_AT_NOMINAL = 32 / 8 * 360e6


def _vscale(op: OperatingPoint, ref: OperatingPoint = NOMINAL) -> float:
    """Dynamic energy scales ~ V^2 (same tech, same caps)."""
    return (op.voltage / ref.voltage) ** 2


# ---------------------------------------------------------------------------
# N-EUREKA throughput model (paper Fig. 8 anchors)
#
# Bit-serial execution: a weight-bit plane costs one pass; per-pass overhead
# (prefetch/streamout handshake) o is calibrated from the two published
# dense-3x3 anchors: 698 GOp/s @ 8 b and 1947 GOp/s @ 2 b (360 MHz):
#     T(w) = P / (w + o)   =>  o = 1.353,  P = 6529 GOp/s*bit
# Ideal (datapath-limited) dense-3x3 throughput at 8 b is 738 GOp/s (paper),
# giving utilization 0.946.
# ---------------------------------------------------------------------------

_BITSERIAL_OVERHEAD = 1.3529
_DENSE3X3_P = 698e9 * (8 + _BITSERIAL_OVERHEAD)          # GOp/s * bits @ 360MHz

# Pointwise runs bit-parallel (weights of all precisions fetched at once,
# §II-C3): throughput is bandwidth/datapath-limited, ~flat in bits for
# latency but weight *traffic* still scales with bits.
_PW_GOPS_8B = 580e9
# Depthwise: 1 input channel per column group, datapath mostly idle.
_DW_GOPS_8B = 58e9


def neureka_gops(op_kind: str, weight_bits: int,
                 oppoint: OperatingPoint = NOMINAL) -> float:
    """Sustained GOp/s (1 MAC = 2 Op) for an optimally-shaped job."""
    f = oppoint.cluster_hz / NOMINAL.cluster_hz
    if op_kind == "dense3x3":
        return f * _DENSE3X3_P / (weight_bits + _BITSERIAL_OVERHEAD)
    if op_kind == "pw1x1":
        return f * _PW_GOPS_8B
    if op_kind == "dw3x3":
        return f * _DW_GOPS_8B * (8 + _BITSERIAL_OVERHEAD) / (
            weight_bits + _BITSERIAL_OVERHEAD)
    raise ValueError(op_kind)


def neureka_ideal_gops(op_kind: str, weight_bits: int) -> float:
    if op_kind == "dense3x3":
        return 738e9 * (8 + _BITSERIAL_OVERHEAD) / (weight_bits + _BITSERIAL_OVERHEAD)
    return neureka_gops(op_kind, weight_bits) / 0.946


# ---------------------------------------------------------------------------
# NVM integration scenarios (paper §IV, Fig 9): where weights live and which
# interfaces they cross per inference.
# ---------------------------------------------------------------------------

def scenario_costs(op: OperatingPoint = NOMINAL) -> Dict[str, ScenarioCost]:
    v = _vscale(op)
    return {
        # 1: off-chip flash -> L2 -> (DMA) -> L1 -> engine
        "l3flash": ScenarioCost(
            "l3flash", HYPERBUS_BPS,
            E_OFFCHIP_PER_B + v * (E_DMA_L2L1_PER_B + E_L1_ACCESS_PER_B),
            weights_through_l1=True, shared_port_crossings=1),
        # 2: on-chip MRAM as background L3 -> (IO-DMA, 32b CDC) -> L2 -> L1
        "l3mram": ScenarioCost(
            "l3mram", io_dma_Bps(op),
            v * (E_MRAM_READ_PER_B + 2 * E_AXI_HOP_PER_B
                 + E_DMA_L2L1_PER_B + E_L1_ACCESS_PER_B),
            weights_through_l1=True, shared_port_crossings=2),
        # 3: MRAM on the shared L2 interconnect; DMA pulls weights to L1
        "l2mram": ScenarioCost(
            "l2mram", cluster_dma_Bps(op),
            v * (E_MRAM_READ_PER_B + E_AXI_HOP_PER_B + E_L1_ACCESS_PER_B),
            weights_through_l1=True, shared_port_crossings=1),
        # 4: Siracusa At-MRAM: dedicated contention-free 256-bit port
        "l1mram": ScenarioCost(
            "l1mram", mram_port_Bps(op),
            v * E_MRAM_READ_PER_B,
            weights_through_l1=False, shared_port_crossings=0),
    }


# ---------------------------------------------------------------------------
# Tiled layer walk: double-buffered latency/energy for one DNN layer.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One N-EUREKA job in a network walk."""
    name: str
    op_kind: str                 # dense3x3 | dw3x3 | pw1x1
    h: int
    w: int
    cin: int
    cout: int
    stride: int = 1
    weight_bits: int = 8

    @property
    def macs(self) -> int:
        ho, wo = -(-self.h // self.stride), -(-self.w // self.stride)
        if self.op_kind == "dense3x3":
            return ho * wo * self.cin * self.cout * 9
        if self.op_kind == "dw3x3":
            return ho * wo * self.cin * 9
        return ho * wo * self.cin * self.cout

    @property
    def ops(self) -> int:
        return 2 * self.macs

    @property
    def weight_bytes(self) -> int:
        if self.op_kind == "dw3x3":
            n = self.cin * 9
        elif self.op_kind == "dense3x3":
            n = self.cin * self.cout * 9
        else:
            n = self.cin * self.cout
        return -(-n * self.weight_bits // 8)

    @property
    def act_in_bytes(self) -> int:
        return self.h * self.w * self.cin

    @property
    def act_out_bytes(self) -> int:
        ho, wo = -(-self.h // self.stride), -(-self.w // self.stride)
        return ho * wo * self.cout


@dataclasses.dataclass
class LayerTiming:
    name: str
    compute_s: float
    weight_s: float
    act_s: float
    latency_s: float             # max of the three (double-buffered pipeline)
    energy_j: float
    regime: str                  # balanced | compute | weight-memory


def layer_timing(layer: LayerShape, scenario: str,
                 op: OperatingPoint = NOMINAL) -> LayerTiming:
    sc = scenario_costs(op)[scenario]
    v = _vscale(op)

    compute_s = layer.ops / neureka_gops(layer.op_kind, layer.weight_bits, op)
    weight_s = layer.weight_bytes / sc.weight_bw_Bps

    # activation movement: L2 -> L1 in, L1 -> L2 out over the Cluster-DMA;
    # if weights share the DMA (scenarios 1-3) the effective act bandwidth
    # halves while weight transfers are in flight.
    act_bytes = layer.act_in_bytes + layer.act_out_bytes
    act_bw = cluster_dma_Bps(op)
    act_s = act_bytes / act_bw
    if sc.shared_port_crossings:
        # weight bytes cross the shared 64-bit cluster port (round-robin
        # arbitration): model as serialized occupancy of the shared port.
        shared_s = (act_bytes
                    + sc.shared_port_crossings * layer.weight_bytes) / act_bw
        act_s = shared_s
        weight_s = max(weight_s, shared_s)

    latency_s = max(compute_s, weight_s, act_s)

    # energies
    e = (layer.weight_bytes * sc.weight_energy_per_B
         + act_bytes * v * (E_DMA_L2L1_PER_B + E_L1_ACCESS_PER_B)
         + layer.ops * E_OP * v
         + latency_s * P_CLUSTER_BASE_W * v)

    terms = dict(compute=compute_s, weight=weight_s, act=act_s)
    dom = max(terms, key=terms.get)
    second = sorted(terms.values())[-2]
    if terms[dom] < 1.35 * second:
        regime = "balanced"
    elif dom == "compute":
        regime = "compute"
    else:
        regime = "weight-memory" if dom == "weight" else "act-memory"

    return LayerTiming(layer.name, compute_s, weight_s, act_s, latency_s, e,
                       regime)


# ---------------------------------------------------------------------------
# Proactive-swap overlap identity (paper §II-B2).
#
# A swap started while independent compute runs hides min(swap, compute) of
# its latency; only the remainder lands on the critical path:
#     stall += swap - hidden,   hidden = min(swap, compute)
# This single identity drives three consumers that must agree: the
# analytical StallModel walk (core.paging.StallModel), the static schedule
# prediction, and the *measured* async-paging counters of the serving
# runtime (AsyncPageStream records swap wall time and the compute window it
# overlapped; its exposed/hidden split must equal this closed form).
# ---------------------------------------------------------------------------

def overlap_stall(swap_s: float, compute_s: float) -> Dict[str, float]:
    """Exposed/hidden split of a ``swap_s`` transfer overlapped with
    ``compute_s`` of independent compute.

    ``exposed_s`` is the wait actually blocking the critical path,
    ``hidden_s`` the part absorbed behind the MACs — the At-MRAM reading
    of §II-B2, and the check the serving runtime's measured per-tick
    counters are asserted against (predicted-vs-measured agreement)."""
    swap_s = max(float(swap_s), 0.0)
    compute_s = max(float(compute_s), 0.0)
    hidden = min(swap_s, compute_s)
    exposed = swap_s - hidden
    return dict(swap_s=swap_s, compute_s=compute_s, hidden_s=hidden,
                exposed_s=exposed,
                overlap_frac=(hidden / swap_s) if swap_s > 0 else 0.0)


def kv_stream_bytes(valid_rows: int, block_rows: int,
                    row_bytes: int) -> int:
    """Host->device bytes ONE tick's KV page stream moves for a slot
    whose valid cache prefix is ``valid_rows`` rows, under the
    completed-block policy of :class:`repro.core.paging.KVPageTable`:
    only full blocks stream (the partially written frontier block stays
    device-resident — it is still being appended to), so the tick's KV
    traffic is ``floor(valid / block) * block * row_bytes``.  This is
    the KV analogue of a weight pass's page traffic, and the quantity
    that contends for the same shared At-MRAM budget in the paper's §V
    concurrent-workload story — tests assert the runtime's
    ``kv_swaps * page_nbytes`` against sums of this closed form."""
    if block_rows < 1:
        raise ValueError(f"block_rows must be >= 1, got {block_rows}")
    if valid_rows < 0 or row_bytes < 0:
        raise ValueError("valid_rows and row_bytes must be >= 0")
    return (valid_rows // block_rows) * block_rows * row_bytes


def encoded_wire_bytes(rows: int, k: int, page_bits: int,
                       block: int = 32) -> int:
    """Closed-form wire bytes of one (rows, k) weight tensor crossing the
    host->device link under the intN page encoding of
    :mod:`repro.core.paging`: packed levels at ``page_bits`` per weight
    (byte-aligned per row, like an MRAM row) plus one float32 scale per
    (row, block) group — the per-block scales travel *inside* the page
    payload, so they are wire bytes, not a side channel.

    This is the §II-B2 swap-term model for encoded pages: wire bytes (not
    the device-resident packed form, not the fp32-dense-equivalent "raw"
    bytes) divided by the swap bandwidth is what the StallModel charges
    per page.  Tests assert the runtime codec's actual buffer sizes equal
    this closed form.
    """
    if rows < 0 or k < 0:
        raise ValueError("rows and k must be >= 0")
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    payload = rows * (-(-k * page_bits // 8))
    scales = rows * (-(-k // block)) * 4
    return payload + scales


Scenarios = Union[str, Sequence[str], PlacementPlan]


def resolve_scenarios(layers: Sequence[LayerShape],
                      scenario: Scenarios) -> List[str]:
    """Per-layer scenario list from a global name, an explicit per-layer
    sequence, or a PlacementPlan keyed by layer name."""
    if isinstance(scenario, str):
        return [scenario] * len(layers)
    if isinstance(scenario, PlacementPlan):
        return [scenario.scenario_for(l.name) for l in layers]
    names = list(scenario)
    if len(names) != len(layers):
        raise ValueError(f"got {len(names)} scenarios for {len(layers)} "
                         "layers")
    return names


def network_walk(layers: Sequence[LayerShape], scenario: Scenarios,
                 op: OperatingPoint = NOMINAL) -> Tuple[float, float, List[LayerTiming]]:
    """End-to-end latency/energy of a network under a weight placement.

    ``scenario`` is a single global scenario name (the paper's Fig 10
    setup), an explicit per-layer sequence, or a
    :class:`~repro.core.placement.PlacementPlan` matched against layer
    names — the mixed-residency case where hot layers stream from At-MRAM
    while cold layers come through the background path.

    Double buffering across layers: per-layer latency is the max of its
    pipeline stages (paper §IV-C: "overall latency is determined by the
    latency of the slowest step").
    """
    per_layer = resolve_scenarios(layers, scenario)
    timings = [layer_timing(l, s, op) for l, s in zip(layers, per_layer)]
    total_s = sum(t.latency_s for t in timings)
    total_j = sum(t.energy_j for t in timings)
    return total_s, total_j, timings

"""Deterministic fault injection for the paged-weight I/O layer.

The paper's At-MRAM path gets integrity and bounded latency from the
hardware (ECC-protected MRAM reads); our software analogue of that
memory hierarchy (`HostPagedStore` / `SharedPagePool` / `KVPageTable`)
has to *earn* the same guarantees.  This module provides the adversary:
a seeded, replayable fault model for host->device page fetches.

Every fault decision is a pure function of ``(seed, kind, model, page,
attempt)`` so a run with a given :class:`FaultPlan` replays exactly --
the property tests rely on this to assert that decode output is
bit-exact vs the fault-free run for *any* plan that stays within the
retry budget.

Fault kinds
-----------
``fail``     transient fetch failure (the worker raises; the store retries
             with deterministic exponential backoff).
``bitflip``  wire-payload corruption (one bit of the fetched copy flips;
             the CRC32 stamped by ``build_pages`` catches it before the
             page is installed and the store re-fetches from host).
``spike``    one-off latency spike on the fetch worker thread.
``stuck``    a permanently-slow page: *every* attempt sleeps ``stuck_s``,
             modelling a degraded lane.  Used to exercise fetch
             deadlines (``fence(timeout_s=...)``) and tick deferral.

Transient faults (fail/bitflip/spike) are only injected while
``attempt < max_faulty_attempts``, which bounds the damage below the
store's ``max_attempts`` retry budget and makes eventual success a
structural guarantee rather than a probabilistic one.  Stuck delays are
exempt -- they model a slow lane, not a transient error, and fire on
every attempt so only a fetch deadline can route around them.
"""

from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Tuple, Union


# --------------------------------------------------------------------------
# Typed paging errors.
#
# Callers need to distinguish programming errors (a schedule that evicts an
# in-use page) from fault-path errors (a fetch that exhausted its retry
# budget).  Everything derives from PagingError so "anything the paging
# layer can raise" is one except clause.
# --------------------------------------------------------------------------


class PagingError(Exception):
    """Base class for all paged-weight I/O errors."""


class ScheduleError(PagingError):
    """A page schedule violates its own invariants (programming error)."""

    def __init__(self, message: str, *, page: Optional[int] = None,
                 model: Optional[str] = None):
        self.page = page
        self.model = model
        super().__init__(message)


class PageFetchError(PagingError):
    """A page fetch exhausted its retry budget."""

    def __init__(self, *, model: str, page: int, attempts: int,
                 last_error: Optional[BaseException] = None):
        self.model = model
        self.page = page
        self.attempts = attempts
        self.last_error = last_error
        detail = f": {last_error}" if last_error is not None else ""
        super().__init__(
            f"page fetch failed for model={model!r} page={page} "
            f"after {attempts} attempts{detail}")


class PageChecksumError(PagingError):
    """Fetched wire bytes fail CRC32 verification (caught pre-install)."""

    def __init__(self, *, model: str, page: int, expected: int, got: int):
        self.model = model
        self.page = page
        self.expected = expected
        self.got = got
        super().__init__(
            f"page checksum mismatch for model={model!r} page={page}: "
            f"expected {expected:#010x}, got {got:#010x}")


class PageFetchTimeout(PagingError):
    """A fence exceeded its I/O deadline; the pass is left resumable."""

    def __init__(self, *, model: str, timeout_s: float,
                 pending: Optional[int] = None):
        self.model = model
        self.timeout_s = timeout_s
        self.pending = pending
        extra = f" ({pending} fetches pending)" if pending is not None else ""
        super().__init__(
            f"fence for model={model!r} exceeded fetch deadline of "
            f"{timeout_s * 1e3:.1f} ms{extra}")


class TransientFetchFault(PagingError):
    """An injected transient fetch failure (internal; always retried)."""

    def __init__(self, *, model: str, page: int, attempt: int):
        self.model = model
        self.page = page
        self.attempt = attempt
        super().__init__(
            f"injected transient fetch fault: model={model!r} "
            f"page={page} attempt={attempt}")


# --------------------------------------------------------------------------
# Fault plan + injector.
# --------------------------------------------------------------------------

# Store-level fault reaction counters (HostPagedStore / KVPageTable each
# keep one dict of these; the scheduler adds "deferred_ticks" on top when
# the metrics `faults` section is assembled).
FAULT_COUNTER_KEYS: Tuple[str, ...] = (
    "injected", "retries", "checksum_failures", "refetches",
    "fetch_timeouts",
)


def new_fault_counters() -> Dict[str, int]:
    return {k: 0 for k in FAULT_COUNTER_KEYS}


@dataclass(frozen=True)
class FaultPlan:
    """Seeded description of which faults to inject, and the retry budget.

    Rates are per (model, page, attempt) fetch; ``stuck_pages`` lists
    ``(model, page)`` pairs whose every fetch attempt sleeps ``stuck_s``.
    """

    seed: int = 0
    fail_rate: float = 0.0
    bitflip_rate: float = 0.0
    spike_rate: float = 0.0
    spike_s: float = 0.002
    stuck_pages: Tuple[Tuple[str, int], ...] = ()
    stuck_s: float = 0.05
    # Transient faults only fire while attempt < max_faulty_attempts, so a
    # retry budget of max_attempts > max_faulty_attempts always succeeds.
    max_faulty_attempts: int = 2
    max_attempts: int = 4
    backoff_s: float = 0.0005
    backoff_cap_s: float = 0.01

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.max_faulty_attempts >= self.max_attempts:
            raise ValueError(
                "max_faulty_attempts must be < max_attempts so a fetch "
                "within the retry budget is guaranteed to succeed")
        for rate in (self.fail_rate, self.bitflip_rate, self.spike_rate):
            if not 0.0 <= rate <= 1.0:
                raise ValueError("fault rates must be in [0, 1]")
        object.__setattr__(self, "stuck_pages",
                           tuple((str(m), int(p)) for m, p in self.stuck_pages))

    def backoff(self, attempt: int) -> float:
        """Deterministic exponential backoff before retry `attempt`."""
        return min(self.backoff_s * (2 ** max(0, attempt - 1)),
                   self.backoff_cap_s)


class FaultInjector:
    """Applies a :class:`FaultPlan` to individual fetch attempts.

    Stateless beyond the plan (decisions are pure hashes), so one injector
    can be shared across several stores (e.g. a tenant's weight pager and
    its KV table).  The *stores* keep the fault counters
    (:func:`new_fault_counters`) -- the injector only decides and acts.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._stuck = frozenset(plan.stuck_pages)

    # -- deterministic decisions ------------------------------------------

    def _unit(self, kind: str, model: str, page: int, attempt: int) -> float:
        """Uniform [0, 1) value, pure in (seed, kind, model, page, attempt).

        blake2s rather than crc32: CRC is linear, so near-identical keys
        (same page, next attempt) produce correlated values and low rates
        would never fire; a cryptographic mix gives proper avalanche."""
        key = f"{self.plan.seed}:{kind}:{model}:{page}:{attempt}".encode()
        word = hashlib.blake2s(key, digest_size=4).digest()
        return int.from_bytes(word, "little") / 2.0 ** 32

    def _transient(self, kind: str, rate: float, model: str, page: int,
                   attempt: int) -> bool:
        if rate <= 0.0 or attempt >= self.plan.max_faulty_attempts:
            return False
        return self._unit(kind, model, page, attempt) < rate

    # -- injection hooks (called from the store's fetch worker) -----------

    def pre_fetch(self, model: str, page: int, attempt: int) -> int:
        """Latency faults + transient failures, before any bytes move.

        Sleeps for spikes/stuck lanes; raises :class:`TransientFetchFault`
        for an injected failure.  Runs on the fetch worker thread, so the
        sleeps model real I/O latency seen by ``fence()``.  Returns the
        number of *latency* faults injected (the caller folds it into its
        ``injected`` counter; an injected failure is counted by catching
        the raise).  Stuck-lane delays are a standing property of the
        page, not an injected event, and are not counted.
        """
        injected = 0
        delay = 0.0
        if (model, page) in self._stuck:
            delay += self.plan.stuck_s
        if self._transient("spike", self.plan.spike_rate, model, page, attempt):
            injected += 1
            delay += self.plan.spike_s
        if delay > 0.0:
            time.sleep(delay)
        if self._transient("fail", self.plan.fail_rate, model, page, attempt):
            raise TransientFetchFault(model=model, page=page, attempt=attempt)
        return injected

    def corrupt(self, model: str, page: int, attempt: int,
                buf: bytes) -> Optional[bytes]:
        """Maybe flip one bit of `buf`; returns the corrupted copy or None.

        The caller must apply the corruption to a *transient* copy of the
        wire bytes -- never to the pristine host store -- so a re-fetch
        observes clean data.
        """
        if not buf or not self._transient("bitflip", self.plan.bitflip_rate,
                                          model, page, attempt):
            return None
        bit = int(self._unit("bitpos", model, page, attempt) * len(buf) * 8)
        bit = min(bit, len(buf) * 8 - 1)
        out = bytearray(buf)
        out[bit // 8] ^= 1 << (bit % 8)
        return bytes(out)


FaultsArg = Union[None, FaultPlan, FaultInjector]


def as_injector(faults: FaultsArg) -> Optional[FaultInjector]:
    """Normalise a ``faults=`` argument: plan -> fresh injector, pass through."""
    if faults is None:
        return None
    if isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultPlan):
        return FaultInjector(faults)
    raise TypeError(f"faults must be a FaultPlan or FaultInjector, "
                    f"got {type(faults).__name__}")


def merge_fault_counters(parts: Iterable[Dict[str, int]]) -> Dict[str, int]:
    """Sum fault-counter dicts (missing keys count as zero)."""
    out = new_fault_counters()
    for part in parts:
        for k in FAULT_COUNTER_KEYS:
            out[k] += int(part.get(k, 0))
    return out

# The paper's primary contribution — the At-MRAM neural engine substrate:
# NEMO quantization, sub-byte packing, the packed WeightStore (MRAM
# analogue), per-layer weight placement + virtual weight paging, the four
# NVM integration scenarios, and the calibrated Siracusa memory-system
# model.
from repro.core import (engine, memsys, packing, paging, perf_model,
                        placement, quantize, scenarios, weight_store)
from repro.core.placement import (Placement, PlacementPlan, SCENARIOS,
                                  plan_for_budget)

__all__ = ["engine", "memsys", "packing", "paging", "perf_model",
           "placement", "quantize", "scenarios", "weight_store",
           "Placement", "PlacementPlan", "SCENARIOS", "plan_for_budget"]

# The paper's primary contribution — the At-MRAM neural engine substrate:
# NEMO quantization, sub-byte packing, the packed WeightStore (MRAM
# analogue), virtual weight paging, the four NVM integration scenarios,
# and the calibrated Siracusa memory-system model.
from repro.core import (engine, memsys, packing, paging, perf_model,
                        quantize, scenarios, weight_store)

__all__ = ["engine", "memsys", "packing", "paging", "perf_model",
           "quantize", "scenarios", "weight_store"]

"""Executable NVM-integration scenarios (paper §IV Fig 9) for the LM stack.

`repro.core.memsys` models the *SoC*'s four integration points analytically;
this module gives each scenario an **executable weight path** in the JAX
framework so the same comparison can be made on the TPU target:

  l1mram  — At-Memory (Siracusa): packed weights stream straight into the
            fused dequant-matmul kernel; no full-width materialization.
  l2mram  — shared background memory: weights are unpacked/dequantized by a
            *separate* op into a full-width buffer that then feeds a plain
            matmul (one extra full-width HBM round-trip).
  l3mram  — background L3: like l2mram plus an optimization barrier, forcing
            the dequantized copy to be materialized (no fusion), i.e. the
            store-and-forward L3->L2 staging hop.
  l3flash — weights are not resident at all: the serving loop re-stages each
            page from host memory ("off-chip flash") every inference via
            `repro.core.paging.HostPagedStore`.  Inside jit it degrades to
            l3mram semantics (host transfers can't be expressed in-graph).

All four produce identical numerics (tested); they differ in bytes moved,
which the roofline/bench harness measures — mirroring the paper's method.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.placement import SCENARIOS, PlacementPlan
from repro.core.weight_store import PackedParam
from repro.kernels import ops as kops

__all__ = ["SCENARIOS", "linear_apply", "plan_apply", "weight_path_bytes"]


def linear_apply(x: jax.Array, p: PackedParam, *, scenario: str = "l1mram",
                 mode: str = "xla", out_dtype=None) -> jax.Array:
    """y = x @ W^T with W stored packed; path selected by scenario.

    x: (..., K) float; p.orig_shape = (N, K).  Returns (..., N).
    """
    out_dtype = out_dtype or x.dtype
    if scenario == "l1mram":
        out = kops.quant_matmul(x, p.packed, p.scale, bits=p.bits,
                                k_orig=p.orig_shape[-1], mode=mode)
    elif scenario in ("l2mram", "l3mram", "l3flash"):
        w = p.dequantize(jnp.float32)               # full-width buffer
        if scenario in ("l3mram", "l3flash"):
            # force materialization (store-and-forward staging hop)
            w = jax.lax.optimization_barrier(w)
        out = jnp.matmul(x.astype(jnp.float32), w.T)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return out.astype(out_dtype)


def plan_apply(x: jax.Array, p: PackedParam, plan: PlacementPlan,
               path: Optional[str] = None, *, out_dtype=None) -> jax.Array:
    """:func:`linear_apply` with the scenario resolved per parameter path
    from a :class:`~repro.core.placement.PlacementPlan`."""
    return linear_apply(x, p, scenario=plan.scenario_for(path),
                        mode=plan.mode, out_dtype=out_dtype)


def weight_path_bytes(p: PackedParam, scenario: str) -> int:
    """HBM bytes the weight crosses per use under each scenario (for the
    analytical comparison; the roofline measures the real compiled value)."""
    packed = p.nbytes_packed
    # static host-side constant: math.prod, NOT jnp (a device round-trip
    # for a python shape tuple)
    full = math.prod(p.orig_shape) * 4
    if scenario == "l1mram":
        return packed                      # read packed once
    if scenario == "l2mram":
        return packed + full               # read packed + write full (fusable read)
    if scenario in ("l3mram", "l3flash"):
        return packed + 2 * full           # read packed + write full + read full
    raise ValueError(scenario)

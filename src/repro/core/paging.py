"""Software-assisted virtual weight paging (paper §II-B2).

For networks whose packed weights exceed the resident budget (on Siracusa:
4 MiB MRAM + 4 MiB tile SRAM = two live pages), the neural memory subsystem
becomes a page cache over background memory.  A tiny page handler compares
each access's page index against the live-page registers; on a miss the FC
programs the IO-DMA to swap the page.  Because DNN weight access order is
*deterministic*, pages can be swapped **proactively**, hiding swap latency
behind compute.

TPU-native realization: layer-granular weight pages live in host memory
("off-chip flash"); a double-buffered prefetcher moves page k+1 host->HBM
while page k's layers execute.  The same schedule object also drives the
analytical stall model used by the memsys benchmarks.
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.placement import PlacementPlan
from repro.core.weight_store import WeightStore, PackedParam, SIRACUSA_MRAM_BYTES


@dataclasses.dataclass(frozen=True)
class Page:
    index: int
    param_names: Tuple[str, ...]
    nbytes: int


def build_pages(store: WeightStore, page_bytes: int = SIRACUSA_MRAM_BYTES,
                order: Optional[Sequence[str]] = None,
                plan: Optional[PlacementPlan] = None) -> List[Page]:
    """Greedy first-fit pagination preserving access (layer) order.

    Keeping pages contiguous in access order is what makes proactive
    prefetch a *static* schedule — the paper's "typically deterministic
    weight access pattern".

    When ``plan`` is given, only its ``paged`` parameters are paginated;
    the plan's resident hot set stays pinned outside the page cache (the
    §II-B2 split between live MRAM contents and background pages).
    """
    names = list(order) if order is not None else list(store.params.keys())
    if plan is not None:
        names = [n for n in names if plan.placement_for(n).paged]
    pages: List[Page] = []
    cur: List[str] = []
    cur_bytes = 0
    for name in names:
        nb = store.params[name].nbytes_packed
        if nb > page_bytes:
            raise ValueError(
                f"param {name} ({nb} B packed) exceeds page size {page_bytes} B; "
                f"increase page size or split the parameter")
        if cur and cur_bytes + nb > page_bytes:
            pages.append(Page(len(pages), tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
        cur.append(name)
        cur_bytes += nb
    if cur:
        pages.append(Page(len(pages), tuple(cur), cur_bytes))
    return pages


@dataclasses.dataclass
class PageScheduleEntry:
    page: int
    prefetch_next: Optional[int]     # page to start swapping in while this runs
    evicts: Optional[int]            # page slot being overwritten


@dataclasses.dataclass
class StallModel:
    """Analytical stall accounting for a paged execution.

    swap_time(page)   = page.nbytes / swap_bandwidth
    compute_time(page) given by the caller per page;  a swap started at the
    beginning of page k's compute hides min(compute_k, swap_{k+1}).
    """
    swap_bandwidth_bytes_per_s: float

    def run(self, pages: Sequence[Page],
            compute_time_s: Sequence[float]) -> Dict[str, float]:
        assert len(pages) == len(compute_time_s)
        total_compute = float(sum(compute_time_s))
        stall = 0.0
        # first page: cold miss, full swap cost
        stall += pages[0].nbytes / self.swap_bandwidth_bytes_per_s
        for k in range(1, len(pages)):
            swap = pages[k].nbytes / self.swap_bandwidth_bytes_per_s
            hidden = min(swap, compute_time_s[k - 1])
            stall += swap - hidden
        return dict(total_compute_s=total_compute, stall_s=stall,
                    total_s=total_compute + stall,
                    stall_fraction=stall / max(total_compute + stall, 1e-12))


def make_schedule(n_pages: int, resident_slots: int = 2) -> List[PageScheduleEntry]:
    """Static proactive-prefetch schedule over a linear page access order."""
    entries: List[PageScheduleEntry] = []
    for k in range(n_pages):
        nxt = k + 1 if k + 1 < n_pages else None
        # with S slots, prefetching page k+1 evicts page k+1-S
        ev = (k + 1 - resident_slots) if (nxt is not None and k + 1 - resident_slots >= 0) else None
        entries.append(PageScheduleEntry(page=k, prefetch_next=nxt, evicts=ev))
    return entries


def validate_schedule(entries: Sequence[PageScheduleEntry],
                      resident_slots: int = 2) -> None:
    """Invariants (property-tested): every page resident before use, the
    in-use page is never evicted, residency never exceeds the slot count."""
    resident: List[int] = []
    for e in entries:
        if e.page not in resident:
            resident.append(e.page)      # demand fetch (cold miss)
        if e.evicts is not None:
            if e.evicts == e.page:
                raise AssertionError("schedule evicts the in-use page")
            if e.evicts in resident:
                resident.remove(e.evicts)
        if e.prefetch_next is not None and e.prefetch_next not in resident:
            resident.append(e.prefetch_next)
        if len(resident) > resident_slots:
            raise AssertionError(
                f"residency {resident} exceeds {resident_slots} slots")


class HostPagedStore:
    """Runtime paged weight streaming: host RAM = background flash, device
    HBM = the two live pages.  Double-buffered with a worker thread — the
    software analogue of the FC+IO-DMA proactive swap.

    With a ``plan``, the plan's resident parameters are uploaded once and
    stay pinned in ``self.resident`` (the live MRAM image); only the paged
    parameters flow through the page cache.
    """

    def __init__(self, store: WeightStore, page_bytes: int,
                 device: Optional[jax.Device] = None,
                 plan: Optional[PlacementPlan] = None):
        self.store = store
        self.plan = plan
        self.pages = build_pages(store, page_bytes, plan=plan)
        self.device = device or jax.devices()[0]
        # evacuate packed params to host numpy (off-chip flash image)
        self._host: Dict[str, Tuple[np.ndarray, np.ndarray, PackedParam]] = {}
        self.resident: Dict[str, PackedParam] = {}
        for name, p in store.params.items():
            if plan is not None and not plan.placement_for(name).paged:
                self.resident[name] = PackedParam(
                    packed=jax.device_put(p.packed, self.device),
                    scale=jax.device_put(p.scale, self.device),
                    bits=p.bits, orig_shape=p.orig_shape)
            else:
                self._host[name] = (np.asarray(p.packed), np.asarray(p.scale),
                                    p)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self.swap_count = 0
        self.miss_count = 0
        self._live: Dict[int, Dict[str, PackedParam]] = {}

    def _fetch_page(self, idx: int) -> Dict[str, PackedParam]:
        out = {}
        for name in self.pages[idx].param_names:
            hp, hs, proto = self._host[name]
            out[name] = PackedParam(
                packed=jax.device_put(hp, self.device),
                scale=jax.device_put(hs, self.device),
                bits=proto.bits, orig_shape=proto.orig_shape)
        self.swap_count += 1
        return out

    def stream(self, resident_slots: int = 2) -> Iterable[Tuple[Page, Dict[str, PackedParam]]]:
        """Yield (page, device params) in order with proactive prefetch."""
        sched = make_schedule(len(self.pages), resident_slots)
        inflight: Dict[int, Future] = {}
        for e in sched:
            if e.page in self._live:
                page_params = self._live[e.page]
            elif e.page in inflight:
                page_params = inflight.pop(e.page).result()
                self._live[e.page] = page_params
            else:
                self.miss_count += 1          # demand miss (cold start)
                page_params = self._fetch_page(e.page)
                self._live[e.page] = page_params
            if e.prefetch_next is not None and e.prefetch_next not in self._live:
                inflight[e.prefetch_next] = self._pool.submit(
                    self._fetch_page, e.prefetch_next)
            if e.evicts is not None:
                self._live.pop(e.evicts, None)
            yield self.pages[e.page], page_params

    def close(self):
        self._pool.shutdown(wait=False)

"""Software-assisted virtual weight paging (paper §II-B2).

For networks whose packed weights exceed the resident budget (on Siracusa:
4 MiB MRAM + 4 MiB tile SRAM = two live pages), the neural memory subsystem
becomes a page cache over background memory.  A tiny page handler compares
each access's page index against the live-page registers; on a miss the FC
programs the IO-DMA to swap the page.  Because DNN weight access order is
*deterministic*, pages can be swapped **proactively**, hiding swap latency
behind compute.

TPU-native realization: layer-granular weight pages live in host memory
("off-chip flash"); a double-buffered prefetcher moves page k+1 host->HBM
while page k's layers execute.  The same schedule object also drives the
analytical stall model used by the memsys benchmarks.

Two streaming modes share one schedule and one set of counters:

  * :meth:`HostPagedStore.stream` — the synchronous pass (iterate pages
    in access order, prefetch one ahead);
  * :meth:`HostPagedStore.begin_pass` -> :class:`AsyncPageStream` — the
    *overlapped* pass: the whole fetch loop is kicked up front and runs
    while the caller computes; ``fence()`` joins at first use and splits
    the pass wall into *exposed* wait (blocked the caller) and *hidden*
    overlap, the measured counterpart of the analytical
    ``stall += swap - hidden`` identity (:func:`memsys.overlap_stall`).
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from collections import OrderedDict
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import packing, quantize
from repro.core.faults import (FaultsArg, PageChecksumError, PageFetchError,
                               PageFetchTimeout, ScheduleError,
                               TransientFetchFault, as_injector,
                               new_fault_counters)
from repro.core.placement import Placement, PlacementPlan, path_key, \
    wire_served_bits
from repro.core.weight_store import WeightStore, PackedParam, SIRACUSA_MRAM_BYTES

# Scale-group width of the intN page wire codec (weights per f32 scale).
PAGE_ENC_BLOCK = quantize.PAGE_SCALE_BLOCK


@dataclasses.dataclass(frozen=True)
class Page:
    """One unit of host->device streaming.

    A page's "bytes" are deliberately NOT one number:

      * ``nbytes``      — *device* bytes: the packed device-format payload
        the page occupies while cached (what the pool budget charges);
      * ``wire_nbytes`` — *wire* bytes: what actually crosses the
        host->device link per swap — the encoded payload plus the scales
        that travel with it (drives stall predictions);
      * ``raw_nbytes``  — the fp32-dense-equivalent bytes an *unencoded*
        fp stream would have moved (``== wire_nbytes`` for the ``"fp"``
        encoding, which declares no compression).

    ``encoding`` is the wire encoding shared by every param on the page
    (:attr:`repro.core.placement.Placement.page_encoding`); mixed
    encodings never share a page, so scales stay with their payload.
    """
    index: int
    param_names: Tuple[str, ...]
    nbytes: int
    wire_nbytes: Optional[int] = None
    raw_nbytes: Optional[int] = None
    encoding: str = "fp"
    # CRC32 over the page's wire image (the ECC analogue of the At-MRAM
    # read path): a chain over the member params' own wire checksums,
    # stamped by build_pages(host=...) and verified by the fetch path
    # BEFORE decode/install.  None = unchecksummed (no host image given).
    crc32: Optional[int] = None

    def __post_init__(self):
        if self.wire_nbytes is None:
            object.__setattr__(self, "wire_nbytes", self.nbytes)
        if self.raw_nbytes is None:
            object.__setattr__(self, "raw_nbytes", self.wire_nbytes)


def page_sizes(pages: Sequence[Page]) -> List[Tuple[int, int, int]]:
    """``[(device, wire, raw), ...]`` byte triples in page order — the
    form the counter-prediction replays (:func:`shared_pass_counters` /
    :func:`kv_pass_counters`) take so their byte counters are exact in
    wire bytes while admission still charges device bytes."""
    return [(p.nbytes, p.wire_nbytes, p.raw_nbytes) for p in pages]


def _param_page_sizes(p: PackedParam, placement: Optional[Placement]
                      ) -> Tuple[str, int, int, int]:
    """(encoding, device, wire, raw) bytes for one paged param.

    Device bytes are the packed device payload (the pool-budget
    convention shared with ``plan_for_budget``'s resident accounting).
    Wire bytes add the scales — per-channel for the verbatim/identity
    encodings, per-block for a re-encoded page (the closed form
    :func:`repro.core.memsys.encoded_wire_bytes`).  Raw bytes are the
    fp32 dense equivalent for intN encodings and equal wire for fp.
    """
    dev = p.nbytes_packed
    n_weights = 1
    for d in p.orig_shape:
        n_weights *= int(d)
    enc = placement.page_encoding if placement is not None else "fp"
    page_bits = placement.page_bits if placement is not None else None
    scale_nb = int(np.prod(p.scale.shape)) * 4
    if page_bits is None or page_bits == p.bits:
        # verbatim device-format stream (fp), or run-quantized identity:
        # the wire form IS the device form (+ its per-channel scales)
        wire = dev + scale_nb
        raw = wire if page_bits is None else n_weights * 4
        return enc, dev, wire, raw
    from repro.core.memsys import encoded_wire_bytes
    rows = n_weights // int(p.orig_shape[-1])
    wire = encoded_wire_bytes(rows, int(p.orig_shape[-1]), page_bits,
                              PAGE_ENC_BLOCK)
    return enc, dev, wire, n_weights * 4


def page_crc(host_params: Sequence["HostParam"]) -> Optional[int]:
    """Chain the member params' wire CRCs into one page-level checksum.

    Chaining the 4-byte CRC words (rather than re-hashing the concatenated
    payloads) lets the fetch path verify per-param buffers it already
    holds without materialising one contiguous wire image."""
    acc = 0
    for hp in host_params:
        if hp is None or hp.crc32 is None:
            return None
        acc = zlib.crc32(int(hp.crc32).to_bytes(4, "little"), acc)
    return acc & 0xFFFFFFFF


def build_pages(store: WeightStore, page_bytes: int = SIRACUSA_MRAM_BYTES,
                order: Optional[Sequence[str]] = None,
                plan: Optional[PlacementPlan] = None,
                host: Optional[Dict[str, "HostParam"]] = None) -> List[Page]:
    """Greedy first-fit pagination preserving access (layer) order.

    Keeping pages contiguous in access order is what makes proactive
    prefetch a *static* schedule — the paper's "typically deterministic
    weight access pattern".

    When ``plan`` is given, only its ``paged`` parameters are paginated;
    the plan's resident hot set stays pinned outside the page cache (the
    §II-B2 split between live MRAM contents and background pages).  Each
    param's placement also fixes its wire *encoding*; params of different
    encodings never share a page (a page is decoded as one unit, and its
    scales travel inside its payload), so an encoding change closes the
    current page even when bytes would still fit.

    When ``host`` is given (the store's :class:`HostParam` wire images,
    fp and encoded alike), each page is stamped with a CRC32 over its
    wire bytes (:func:`page_crc`) and the fetch path verifies it before
    installing the page — corruption on the link re-fetches instead of
    silently decoding garbage.
    """
    names = list(order) if order is not None else list(store.params.keys())
    if plan is not None:
        names = [n for n in names if plan.placement_for(n).paged]
    pages: List[Page] = []
    cur: List[str] = []
    cur_dev = cur_wire = cur_raw = 0
    cur_enc = "fp"

    def _close():
        nonlocal cur, cur_dev, cur_wire, cur_raw
        crc = (page_crc([host.get(n) for n in cur])
               if host is not None else None)
        pages.append(Page(len(pages), tuple(cur), cur_dev, cur_wire,
                          cur_raw, cur_enc, crc))
        cur, cur_dev, cur_wire, cur_raw = [], 0, 0, 0

    for name in names:
        placement = plan.placement_for(name) if plan is not None else None
        enc, dev, wire, raw = _param_page_sizes(store.params[name],
                                                placement)
        if dev > page_bytes:
            where = (f"plan path {name!r} -> {placement.scenario}/"
                     f"{placement.weight_bits}b/{enc}" if placement
                     is not None else f"param {name!r} ({enc})")
            raise ValueError(
                f"{where}: {dev} B packed exceeds page size {page_bytes} B;"
                f" set page_bytes >= {dev} or split the parameter")
        if cur and (cur_dev + dev > page_bytes or enc != cur_enc):
            _close()
        cur.append(name)
        cur_enc = enc
        cur_dev += dev
        cur_wire += wire
        cur_raw += raw
    if cur:
        _close()
    return pages


@dataclasses.dataclass
class PageScheduleEntry:
    page: int
    prefetch_next: Optional[int]     # page to start swapping in while this runs
    evicts: Optional[int]            # page slot being overwritten


@dataclasses.dataclass
class StallModel:
    """Analytical stall accounting for a paged execution.

    swap_time(page)   = page.wire_nbytes / swap_bandwidth — the link moves
    the page's *wire* form (encoded payload + scales), not its decoded
    device footprint, so a compressed cold page stalls ~bits/32 of its fp
    cost.  compute_time(page) given by the caller per page; a swap started
    at the beginning of page k's compute hides min(compute_k, swap_{k+1}).
    """
    swap_bandwidth_bytes_per_s: float

    def run(self, pages: Sequence[Page],
            compute_time_s: Sequence[float]) -> Dict[str, float]:
        from repro.core.memsys import overlap_stall
        assert len(pages) == len(compute_time_s)
        total_compute = float(sum(compute_time_s))
        stall = 0.0
        # first page: cold miss, full swap cost
        stall += pages[0].wire_nbytes / self.swap_bandwidth_bytes_per_s
        for k in range(1, len(pages)):
            swap = pages[k].wire_nbytes / self.swap_bandwidth_bytes_per_s
            stall += overlap_stall(swap, compute_time_s[k - 1])["exposed_s"]
        return dict(total_compute_s=total_compute, stall_s=stall,
                    total_s=total_compute + stall,
                    stall_fraction=stall / max(total_compute + stall, 1e-12))


def make_schedule(n_pages: int, resident_slots: int = 2) -> List[PageScheduleEntry]:
    """Static proactive-prefetch schedule over a linear page access order.

    With a single live slot there is nowhere to double-buffer: prefetching
    page k+1 would evict the in-use page k (the schedule the old code
    emitted, which ``validate_schedule`` rightly rejects).  Single-slot
    passes therefore disable proactive prefetch and demand-fetch every
    page, evicting the previous one first — ``pass_counters`` then
    predicts ``swaps == misses == n_pages``.
    """
    if resident_slots < 1:
        raise ValueError(f"resident_slots must be >= 1, got {resident_slots}")
    entries: List[PageScheduleEntry] = []
    if resident_slots == 1:
        for k in range(n_pages):
            entries.append(PageScheduleEntry(
                page=k, prefetch_next=None,
                evicts=k - 1 if k > 0 else None))
        return entries
    for k in range(n_pages):
        nxt = k + 1 if k + 1 < n_pages else None
        # with S slots, prefetching page k+1 evicts page k+1-S
        ev = (k + 1 - resident_slots) if (nxt is not None and k + 1 - resident_slots >= 0) else None
        entries.append(PageScheduleEntry(page=k, prefetch_next=nxt, evicts=ev))
    return entries


def validate_schedule(entries: Sequence[PageScheduleEntry],
                      resident_slots: int = 2) -> None:
    """Invariants (property-tested): every page resident before use, the
    in-use page is never evicted, residency never exceeds the slot count.

    Violations raise :class:`repro.core.faults.ScheduleError` (with the
    offending page attached) — a *programming* error, distinct from the
    fault-path :class:`~repro.core.faults.PageFetchError` family a caller
    may want to retry or degrade on."""
    resident: List[int] = []
    for e in entries:
        if e.page not in resident:
            resident.append(e.page)      # demand fetch (cold miss)
        if e.evicts is not None:
            if e.evicts == e.page:
                raise ScheduleError(
                    f"schedule evicts the in-use page {e.page}",
                    page=e.page)
            if e.evicts in resident:
                resident.remove(e.evicts)
        if e.prefetch_next is not None and e.prefetch_next not in resident:
            resident.append(e.prefetch_next)
        if len(resident) > resident_slots:
            raise ScheduleError(
                f"residency {resident} exceeds {resident_slots} slots at "
                f"page {e.page}", page=e.page)


class SharedPagePool:
    """One device-bytes budget shared by every tenant's paged store.

    The §V concurrent-workload story: N models (hand tracking, gaze, an
    assistant LM) share ONE memory hierarchy, so their cold pages must
    contend for one pool of device bytes rather than each model assuming
    a private cache.  Members are :class:`HostPagedStore` instances that
    register under a model name; every page any member fetches is admitted
    here, and admission evicts least-recently-used pages of *other* models
    until the new page fits (the fetching model's own pages are never
    evicted mid-pass — its live window must survive).  A page still cached
    from an earlier pass satisfies a re-fetch without a host->device swap
    (a *pool hit*), so the counters expose exactly the cross-model
    contention: a tenant that fits alone starts thrashing when a
    co-tenant's working set squeezes it out.

    All bookkeeping is deterministic for a given pass order even when the
    passes are *overlapped* (:meth:`HostPagedStore.begin_pass`): every
    member store routes its page fetches through the pool's single shared
    fetch worker, so fetches execute serialized in begin order — the same
    lookup/admit sequence the sequential sync passes produce, which is why
    the per-model counters follow the static :func:`shared_pass_counters`
    prediction exactly with or without async overlap.
    """

    def __init__(self, budget_bytes: int):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.members: "OrderedDict[str, HostPagedStore]" = OrderedDict()
        self._lock = threading.RLock()
        # (model, page) -> (nbytes, wire_nbytes, {name: PackedParam});
        # insertion/touch order IS the LRU order (front = coldest)
        self._cache: "OrderedDict[Tuple[str, int], Tuple[int, int, Dict[str, PackedParam]]]" = OrderedDict()
        self.live_bytes = 0           # device bytes held (what budget charges)
        self.live_wire_bytes = 0      # wire bytes those pages cost to re-swap
        self.counters: Dict[str, Dict[str, Any]] = {}
        # every member event in BEGIN order — which, because all member
        # fetches funnel through the single worker below, is also the
        # order the pool actually executes them in.  Events are
        #   ("pass", model)                       one full weight pass
        #   ("kv", model, ((page, nbytes), ...))  one KV fetch batch
        #   ("kvdrop", model, (page, ...))        slot-reuse invalidation
        # — the exact sequence :func:`kv_pass_counters` replays (and,
        # filtered to weight passes, the ``passes=`` argument
        # :func:`shared_pass_counters` needs), even when live submissions
        # make tenants begin out of registration rotation (an idle tenant
        # demand-begins only when it next ticks)
        self.events: List[Tuple] = []
        # ONE fetch worker for every member store: overlapped passes of
        # different tenants serialize here in begin order, keeping the
        # pool's lookup/admit sequence identical to the sync pass order
        self._exec = ThreadPoolExecutor(max_workers=1)
        # models whose pass fetches are still in flight — the async
        # extension of the "fetcher's own pages are protected" guard:
        # admit() never evicts pages of a model that is mid-fetch, so an
        # overlapped pass's live window survives co-tenant admissions
        self._active_fetch: set = set()
        # opt-in chrome-trace hook (duck-typed — see serving.trace; set
        # by ServingEngine.set_tracer): evictions become instant events,
        # live_bytes a counter track
        self.tracer = None

    def register(self, name: str, store: Any) -> None:
        """Join the pool.  ``store`` is a :class:`HostPagedStore` (weight
        pages) or a :class:`KVPageTable` (KV-cache pages) — both expose
        ``swap_count`` / ``miss_count`` / ``pages`` / ``close``, and both
        kinds of page contend for the SAME budget (one eviction domain)."""
        with self._lock:
            if name in self.members:
                raise ValueError(f"model {name!r} already joined this pool")
            self.members[name] = store
            self.counters[name] = dict(pool_hits=0, evicted=0,
                                       exposed_s=0.0, hidden_s=0.0)

    @property
    def pass_log(self) -> List[str]:
        """One entry per full WEIGHT streaming pass in begin order — the
        ``passes=`` view of :attr:`events` that ``shared_pass_counters``
        consumes (KV batches carry their own event kind)."""
        with self._lock:
            return [m for kind, m, *_rest in self.events if kind == "pass"]

    def log_event(self, *event) -> None:
        with self._lock:
            self.events.append(tuple(event))

    def _pass_begin(self, name: str) -> None:
        """Mark ``name``'s pass fetches in flight (eviction-protected)."""
        with self._lock:
            self._active_fetch.add(name)

    def _pass_end(self, name: str) -> None:
        """Release the fetch guard (idempotent — also called on cancel)."""
        with self._lock:
            self._active_fetch.discard(name)

    def lookup(self, name: str, page_idx: int
               ) -> Optional[Dict[str, PackedParam]]:
        """Device params for a page still cached from an earlier fetch, or
        None (the caller must then swap host->device and :meth:`admit`)."""
        with self._lock:
            key = (name, page_idx)
            entry = self._cache.get(key)
            if entry is None:
                return None
            self._cache.move_to_end(key)
            self.counters[name]["pool_hits"] += 1
            return entry[2]

    def admit(self, name: str, page_idx: int, nbytes: int,
              params: Dict[str, PackedParam],
              wire_nbytes: Optional[int] = None,
              raw_nbytes: Optional[int] = None) -> None:
        """Cache a freshly swapped page under the shared budget, evicting
        other models' LRU pages to make room.  If the budget cannot fit
        the page even after evicting every foreign page (the fetching
        model's own pages are protected), the page is simply not cached —
        it lives only as long as the pass's live window references it, and
        the next access swaps again.

        ``nbytes`` is the page's decoded *device* footprint — what the
        budget charges and eviction frees.  ``wire_nbytes`` (default:
        ``nbytes``) is what the swap moved across the link; the pool only
        tracks it (``live_wire_bytes``, the ``pool_bytes`` trace counter)
        — admission decisions never depend on it.  ``raw_nbytes`` is
        accepted for signature symmetry with the :class:`Page` ledger."""
        del raw_nbytes               # per-member ledgers live in the stores
        with self._lock:
            if nbytes > self.budget_bytes:
                return              # can NEVER fit: don't flush co-tenants
            wire = int(wire_nbytes) if wire_nbytes is not None else nbytes
            tr = self.tracer
            for key in list(self._cache.keys()):
                if self.live_bytes + nbytes <= self.budget_bytes:
                    break
                victim_model, victim_page = key
                if victim_model == name or victim_model in self._active_fetch:
                    # the fetching model's own pages — and any model whose
                    # overlapped pass is still mid-fetch — keep their live
                    # window intact
                    continue
                freed, freed_wire, _ = self._cache.pop(key)
                self.live_bytes -= freed
                self.live_wire_bytes -= freed_wire
                self.counters[victim_model]["evicted"] += 1
                if tr is not None:
                    tr.instant("evict", track="io", model=victim_model,
                               page=victim_page, nbytes=freed, by=name)
            if self.live_bytes + nbytes <= self.budget_bytes:
                self._cache[(name, page_idx)] = (nbytes, wire, params)
                self.live_bytes += nbytes
                self.live_wire_bytes += wire
            if tr is not None:
                tr.counter("pool_bytes", track="io", bytes=self.live_bytes,
                           wire_bytes=self.live_wire_bytes)

    def invalidate(self, name: str, page_idx: int) -> bool:
        """Drop ``name``'s cached page (owner-initiated, e.g. a KV block
        whose batch slot was handed to a new request).  Unlike pressure
        eviction this does NOT touch the victim's ``evicted`` counter —
        the owner declared the bytes dead; returns whether the page was
        present."""
        with self._lock:
            entry = self._cache.pop((name, page_idx), None)
            if entry is None:
                return False
            self.live_bytes -= entry[0]
            self.live_wire_bytes -= entry[1]
            if self.tracer is not None:
                self.tracer.counter("pool_bytes", track="io",
                                    bytes=self.live_bytes,
                                    wire_bytes=self.live_wire_bytes)
            return True

    def add_stall(self, name: str, exposed_s: float,
                  hidden_s: float = 0.0) -> None:
        """Account one pass's stall split for ``name``: ``exposed_s`` is
        the wait that actually blocked a tick, ``hidden_s`` the stream
        time overlapped behind compute (sync passes hide nothing)."""
        with self._lock:
            self.counters[name]["exposed_s"] += float(exposed_s)
            self.counters[name]["hidden_s"] += float(hidden_s)

    def summary(self) -> Dict[str, Any]:
        """Per-model swap/miss/pool-hit/evict counters, the wire/raw
        streamed-bytes ledger, and the exposed/hidden stall split + pool
        state — the ``shared_pool`` section of the metrics/v8 JSON.  The
        stall seconds here are the pool's per-model *view* of the same
        wall time the engines report in their own ``paging`` sections;
        totals must sum ONE of the two, never both.  ``bytes_streamed_*``
        are the member stores' own swap ledgers (wire = what crossed the
        link, raw = the fp32-equivalent an unencoded stream would have
        moved), surfaced here so one summary shows every tenant's
        compression ratio against one budget."""
        with self._lock:
            models = {}
            for name, store in self.members.items():
                c = self.counters[name]
                models[name] = dict(
                    swaps=store.swap_count, misses=store.miss_count,
                    pool_hits=c["pool_hits"], evicted=c["evicted"],
                    exposed_s=c["exposed_s"], hidden_s=c["hidden_s"],
                    n_pages=len(store.pages),
                    bytes_streamed_wire=getattr(store, "bytes_streamed_wire",
                                                0),
                    bytes_streamed_raw=getattr(store, "bytes_streamed_raw",
                                               0))
            return dict(
                budget_bytes=self.budget_bytes,
                live_bytes=self.live_bytes,
                live_wire_bytes=self.live_wire_bytes,
                cached_pages=len(self._cache),
                evictions=sum(c["evicted"] for c in self.counters.values()),
                bytes_streamed_wire=sum(m["bytes_streamed_wire"]
                                        for m in models.values()),
                bytes_streamed_raw=sum(m["bytes_streamed_raw"]
                                       for m in models.values()),
                models=models)

    def close(self, wait: bool = True) -> None:
        with self._lock:
            members = list(self.members.values())
            self._cache.clear()
            self.live_bytes = 0
            self.live_wire_bytes = 0
        for store in members:
            store.close(wait=wait)
        self._exec.shutdown(wait=wait, cancel_futures=not wait)

    def __enter__(self) -> "SharedPagePool":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def shared_pass_counters(page_nbytes: Dict[str, Sequence[int]],
                         budget_bytes: int, resident_slots: int = 2,
                         passes: Optional[Sequence[str]] = None,
                         ticks: int = 1) -> Dict[str, Dict[str, int]]:
    """Static per-model counter prediction for SharedPagePool streaming.

    ``page_nbytes`` maps each model name to its page sizes in access
    order — plain device-byte ints, or ``(device, wire, raw)`` triples
    (:func:`page_sizes`) to also predict each model's streamed
    ``bytes_wire``/``bytes_raw`` ledger exactly; ``passes`` is the exact
    sequence of full streaming passes (one
    entry per model tick, e.g. ``MultiScheduler.pass_log``), defaulting to
    ``ticks`` round-robin rounds over the models in dict order.  The
    actual replay — demand/prefetch fetch order per :func:`make_schedule`,
    pool lookup before swap, LRU admission that never evicts the fetching
    model's pages — lives in :func:`kv_pass_counters` (one copy of the
    admit semantics, shared with the KV event replay); this is its
    weights-only view, so the runtime ``SharedPagePool.summary()``
    counters must match it pass for pass (the multi-tenant analogue of
    :func:`pass_counters`)."""
    order = list(page_nbytes.keys())
    if passes is None:
        passes = [m for _ in range(ticks) for m in order]
    out = kv_pass_counters(page_nbytes, budget_bytes,
                           [("pass", m) for m in passes],
                           resident_slots=resident_slots)
    for m in order:
        out.setdefault(m, dict(swaps=0, misses=0, pool_hits=0, evicted=0,
                               dropped=0, bytes_wire=0, bytes_raw=0))
    # weight passes never drop pages; keep the historical key set
    return {m: {k: n for k, n in c.items() if k != "dropped"}
            for m, c in out.items()}


@dataclasses.dataclass
class HostParam:
    """Host-side ("background flash") image of ONE paged parameter, held
    in its page *wire* encoding.

    Two regimes, chosen by :attr:`identity`:

      * **identity** — ``page_bits`` is None (``"fp"``: stream the device
        format verbatim) or equals the param's own ``bits`` (the
        run-quantized case: the wire form IS the device form).  The
        payload is the device packed carrier, the scales the per-channel
        device scales; decode is a no-op.
      * **re-encoded** — the host keeps only blockwise-quantized
        ``page_bits`` levels (packed) + per-(row, ``PAGE_ENC_BLOCK``)
        f32 scales; :meth:`decode` reconstructs the per-channel device
        format at fetch: dequantize the blocks, re-quantize per channel
        at ``bits``, re-pack.  The round trip is deterministic, so a
        paged serve is bit-exact against a resident engine whose weights
        took the same trip (:func:`page_roundtrip_param`).
    """
    bits: int                         # device weight bits
    orig_shape: Tuple[int, ...]
    packed_shape: Tuple[int, ...]     # device carrier shape to rebuild
    scale_shape: Tuple[int, ...]      # device per-channel scale shape
    page_bits: Optional[int]          # wire bits (None = fp/verbatim)
    payload: np.ndarray
    scales: np.ndarray
    # CRC32 over (payload, scales) bytes — the param's share of its page's
    # wire checksum (:func:`page_crc`); stamped by encode_host_param
    crc32: Optional[int] = None

    @property
    def identity(self) -> bool:
        return self.page_bits is None or self.page_bits == self.bits

    @property
    def encoding(self) -> str:
        return "fp" if self.page_bits is None else f"int{self.page_bits}"

    @property
    def wire_nbytes(self) -> int:
        return int(self.payload.nbytes) + int(self.scales.nbytes)

    def wire_crc(self, payload: Optional[np.ndarray] = None,
                 scales: Optional[np.ndarray] = None) -> int:
        """CRC32 of the wire image — of the stored buffers, or of the
        buffers a fetch actually received (to verify before decode)."""
        payload = self.payload if payload is None else payload
        scales = self.scales if scales is None else scales
        crc = zlib.crc32(np.ascontiguousarray(payload).tobytes())
        crc = zlib.crc32(np.ascontiguousarray(scales).tobytes(), crc)
        return crc & 0xFFFFFFFF

    def decode(self, payload: Optional[np.ndarray] = None,
               scales: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Wire form -> device form ``(packed, scale)``, host-side.

        Identity encodings return the stored buffers untouched (zero
        decode cost — the fetch path device_puts them directly).  The
        optional ``payload``/``scales`` overrides decode a *transferred*
        copy of the wire buffers instead of the pristine host image — the
        fault-injection path uses this so a simulated in-flight bit-flip
        genuinely reaches the decode (and, absent checksums, the device)."""
        payload = self.payload if payload is None else payload
        scales = self.scales if scales is None else scales
        if self.identity:
            return payload, scales
        k = int(self.orig_shape[-1])
        levels = np.asarray(packing.unpack(payload, self.page_bits, k))
        dense = quantize.dequantize_blockwise(levels, scales,
                                              block=PAGE_ENC_BLOCK)
        qt = quantize.quantize_weights(dense, self.bits, channel_axis=0)
        packed = np.asarray(packing.pack(qt.values, self.bits))
        return (packed.reshape(self.packed_shape),
                np.asarray(qt.scale, np.float32).reshape(self.scale_shape))


def encode_host_param(p: PackedParam, page_bits: Optional[int]) -> HostParam:
    """Evacuate one paged param to its host wire image (see
    :class:`HostParam`).  For a re-encoded param the dense weights are
    reconstructed once (host-side, at store build) and blockwise-quantized
    to ``page_bits``; the original device carrier is NOT retained — the
    host truly holds only the compressed bytes the wire will move."""
    packed = np.asarray(p.packed)
    scale = np.asarray(p.scale)
    hp = HostParam(bits=p.bits, orig_shape=tuple(p.orig_shape),
                   packed_shape=tuple(packed.shape),
                   scale_shape=tuple(scale.shape),
                   page_bits=page_bits, payload=packed, scales=scale)
    if not hp.identity:
        k = int(p.orig_shape[-1])
        levels = np.asarray(packing.unpack(packed.reshape(-1,
                                                          packed.shape[-1]),
                                           p.bits, k), np.float32)
        dense = levels * scale.reshape(-1, 1).astype(np.float32)
        wire_levels, wire_scales = quantize.quantize_blockwise(
            dense, page_bits, block=PAGE_ENC_BLOCK)
        hp.payload = np.asarray(packing.pack(wire_levels, page_bits))
        hp.scales = wire_scales
    hp.crc32 = hp.wire_crc()
    return hp


def page_roundtrip_param(p: PackedParam, page_bits: Optional[int]
                         ) -> PackedParam:
    """One param encode->decode through the page wire codec — the exact
    transform :meth:`HostPagedStore._fetch_page` applies, exposed so a
    *resident* reference engine can pre-distort its weights identically
    and a lossy-encoded paged serve becomes bit-exact against it."""
    packed, scale = encode_host_param(p, page_bits).decode()
    return PackedParam(packed=packed, scale=scale, bits=p.bits,
                       orig_shape=tuple(p.orig_shape))


def page_crc_of_buffers(wire: Sequence[Tuple[str, "HostParam", np.ndarray,
                                             np.ndarray]]) -> int:
    """Page CRC recomputed from the buffers a fetch actually received —
    the verify-side counterpart of :func:`page_crc`."""
    acc = 0
    for _name, hp, payload, scales in wire:
        c = hp.wire_crc(payload=payload, scales=scales)
        acc = zlib.crc32(c.to_bytes(4, "little"), acc)
    return acc & 0xFFFFFFFF


def retry_fetch(store: Any, idx: int, attempt_fn: Callable[[int], Any]) -> Any:
    """Run one logical page fetch under the store's retry policy.

    ``attempt_fn(attempt)`` performs attempt number ``attempt`` (0-based)
    and either returns the fetched result or raises
    :class:`~repro.core.faults.TransientFetchFault` (injected failure) /
    :class:`~repro.core.faults.PageChecksumError` (wire corruption caught
    before install).  Both retry with the plan's bounded deterministic
    exponential backoff; exhausting ``max_attempts`` raises a typed
    :class:`~repro.core.faults.PageFetchError` naming model/page/attempts.
    Runs on the fetch worker thread — the backoff sleeps are I/O latency,
    visible to ``fence()`` like any other stream time.  Counters land on
    ``store.fault_counters``; a store with no fault plan has a budget of
    one attempt (nothing injects faults into it, and a genuine checksum
    mismatch would re-read the same host bytes anyway)."""
    inj = store.faults
    plan = inj.plan if inj is not None else None
    max_attempts = plan.max_attempts if plan is not None else 1
    attempt = 0
    while True:
        try:
            return attempt_fn(attempt)
        except (TransientFetchFault, PageChecksumError) as e:
            if isinstance(e, TransientFetchFault):
                store.fault_counters["injected"] += 1
                if store.tracer is not None:
                    store.tracer.instant("fault", track="io",
                                         model=store.name, page=idx,
                                         kind="fail", attempt=attempt)
            else:
                store.fault_counters["checksum_failures"] += 1
                store.fault_counters["refetches"] += 1
            attempt += 1
            if attempt >= max_attempts:
                raise PageFetchError(model=store.name, page=idx,
                                     attempts=attempt, last_error=e) from e
            store.fault_counters["retries"] += 1
            if store.tracer is not None:
                store.tracer.instant("retry", track="io", model=store.name,
                                     page=idx, attempt=attempt,
                                     cause=type(e).__name__)
            time.sleep(plan.backoff(attempt))


class HostPagedStore:
    """Runtime paged weight streaming: host RAM = background flash, device
    HBM = the two live pages.  Double-buffered with a worker thread — the
    software analogue of the FC+IO-DMA proactive swap.

    With a ``plan``, the plan's resident parameters are uploaded once and
    stay pinned in ``self.resident`` (the live MRAM image); only the paged
    parameters flow through the page cache — each held host-side in its
    plan-assigned wire encoding (:class:`HostParam`) and decoded back to
    the device format at fetch, so a quantized cold page crosses the link
    compressed.  ``bytes_streamed_wire`` / ``bytes_streamed_raw``
    accumulate per swap what the link moved vs the fp32-equivalent an
    unencoded stream would have moved; ``decode_s`` is the cumulative
    fetch-side decode wall time.

    With a ``pool`` (:class:`SharedPagePool`), the store *joins* a shared
    device-bytes budget under ``name``: every fetched page is admitted to
    the pool (cross-model LRU eviction), and pages still pooled from an
    earlier pass are reused without a host->device swap.

    With ``faults`` (a :class:`~repro.core.faults.FaultPlan` or a shared
    :class:`~repro.core.faults.FaultInjector`), every fetch attempt runs
    under seeded fault injection; transient failures and checksum
    mismatches retry with bounded deterministic backoff
    (:func:`retry_fetch`), and ``fault_counters`` ledgers what was
    injected and survived.  Because every page carries a CRC32 over its
    wire bytes and a corrupted fetch re-reads the pristine host image,
    decode output stays bit-exact vs the fault-free run for any plan
    within the retry budget.
    """

    def __init__(self, store: WeightStore, page_bytes: int,
                 device: Optional[jax.Device] = None,
                 plan: Optional[PlacementPlan] = None,
                 pool: Optional[SharedPagePool] = None,
                 name: str = "default",
                 faults: FaultsArg = None):
        self.store = store
        self.plan = plan
        self.pool = pool
        self.name = name
        self.device = device or jax.devices()[0]
        # evacuate packed params to the host wire image (off-chip flash)
        # BEFORE paginating, so build_pages can stamp each page's CRC32
        # over the wire bytes it will actually move
        self._host: Dict[str, HostParam] = {}
        self.resident: Dict[str, PackedParam] = {}
        for name, p in store.params.items():
            if plan is not None and not plan.placement_for(name).paged:
                self.resident[name] = PackedParam(
                    packed=jax.device_put(p.packed, self.device),
                    scale=jax.device_put(p.scale, self.device),
                    bits=p.bits, orig_shape=p.orig_shape)
            else:
                pb = (plan.placement_for(name).page_bits
                      if plan is not None else None)
                self._host[name] = encode_host_param(p, pb)
        self.pages = build_pages(store, page_bytes, plan=plan,
                                 host=self._host)
        # wire-serve (plan.wire_serve=True): cold params whose fetch skips
        # the host decode entirely — the blockscale matmul consumes the
        # page's wire form directly (placement.wire_served_bits is the
        # single predicate the store and the model's `linear` both obey)
        self.wire_served = {n for n in self._host
                            if wire_served_bits(plan, n) is not None}
        self._pool = ThreadPoolExecutor(max_workers=1)
        self.swap_count = 0
        self.miss_count = 0
        self.bytes_streamed_wire = 0
        self.bytes_streamed_raw = 0
        self.decode_s = 0.0
        self.decode_skipped_bytes = 0
        self.faults = as_injector(faults)
        self.fault_counters = new_fault_counters()
        self._closed = False
        self._live: Dict[int, Dict[str, PackedParam]] = {}
        # opt-in chrome-trace hook (ServingEngine.set_tracer): per-page
        # fetch spans on the "io" track, emitted from the fetch worker
        self.tracer = None
        if pool is not None:
            pool.register(self.name, self)

    @property
    def _fetch_exec(self) -> ThreadPoolExecutor:
        """The worker page fetches run on: the shared pool worker for pool
        members (so overlapped tenant passes serialize in begin order and
        the pool bookkeeping stays deterministic), the store's private
        worker otherwise."""
        return self._pool if self.pool is None else self.pool._exec

    def _fetch_page(self, idx: int) -> Dict[str, PackedParam]:
        tr = self.tracer
        t0 = tr.now() if tr is not None else 0.0
        if self._closed:
            raise CancelledError(f"{self.name}: store closed before fetch "
                                 f"of page {idx} started")
        if self.pool is not None:
            cached = self.pool.lookup(self.name, idx)
            if cached is not None:
                if tr is not None:       # pool hit: no host->device swap
                    tr.complete("page", tr.now() - t0, track="io",
                                model=self.name, page=idx, pool_hit=True)
                return cached
        page = self.pages[idx]
        out = retry_fetch(self, idx,
                          lambda attempt: self._fetch_page_once(idx, page,
                                                                attempt))
        if self._closed:
            # close(wait=False) landed while this fetch was decoding:
            # drop the page instead of installing into a closed store
            raise CancelledError(f"{self.name}: store closed during fetch "
                                 f"of page {idx}")
        self.swap_count += 1
        self.bytes_streamed_wire += page.wire_nbytes
        self.bytes_streamed_raw += page.raw_nbytes
        if self.pool is not None:
            self.pool.admit(self.name, idx, page.nbytes, out,
                            wire_nbytes=page.wire_nbytes,
                            raw_nbytes=page.raw_nbytes)
        if tr is not None:
            tr.complete("page", tr.now() - t0, track="io", model=self.name,
                        page=idx, nbytes=page.nbytes,
                        wire_nbytes=page.wire_nbytes,
                        encoding=page.encoding, pool_hit=False)
        return out

    def _fetch_page_once(self, idx: int, page: Page,
                         attempt: int) -> Dict[str, PackedParam]:
        """One fetch attempt: inject faults, transfer the wire buffers,
        verify the page CRC *before* decoding, decode, device_put.

        Corruption (an injected bit-flip) lands on a transient copy of
        the wire payload — the pristine host image is never touched, so
        the retry a checksum mismatch triggers re-reads clean bytes."""
        inj = self.faults
        if inj is not None:
            self.fault_counters["injected"] += inj.pre_fetch(self.name, idx,
                                                             attempt)
        wire: List[Tuple[str, HostParam, np.ndarray, np.ndarray]] = []
        for name in page.param_names:
            hp = self._host[name]
            payload = hp.payload
            if inj is not None:
                flipped = inj.corrupt(self.name, idx, attempt,
                                      np.ascontiguousarray(payload).tobytes())
                if flipped is not None:
                    self.fault_counters["injected"] += 1
                    if self.tracer is not None:
                        self.tracer.instant("fault", track="io",
                                            model=self.name, page=idx,
                                            kind="bitflip", param=name,
                                            attempt=attempt)
                    payload = np.frombuffer(
                        flipped, dtype=payload.dtype).reshape(payload.shape)
            wire.append((name, hp, payload, hp.scales))
        if page.crc32 is not None:
            got = page_crc_of_buffers(wire)
            if got != page.crc32:
                raise PageChecksumError(model=self.name, page=idx,
                                        expected=page.crc32, got=got)
        out: Dict[str, PackedParam] = {}
        for name, hp, payload, scales in wire:
            if name in self.wire_served:
                # wire-serve fast path: ship the blockwise wire form
                # (packed page_bits levels + per-block scales) as-is; the
                # blockscale matmul expands it adjacent to the compute.
                # CRC already verified above, so corrupted wire bytes
                # never reach the device on this path either.
                self.decode_skipped_bytes += hp.wire_nbytes
                # the codec flattens to (rows, k); restore the device
                # carrier's leading dims (stacked-layer params scan over
                # the leading axis)
                lead = hp.packed_shape[:-1]
                out[name] = PackedParam(
                    packed=jax.device_put(payload.reshape(*lead, -1),
                                          self.device),
                    scale=jax.device_put(scales.reshape(*lead, -1),
                                         self.device),
                    bits=hp.page_bits, orig_shape=hp.orig_shape)
                continue
            t_dec = time.perf_counter()
            packed, scale = hp.decode(payload=payload, scales=scales)
            self.decode_s += time.perf_counter() - t_dec
            out[name] = PackedParam(
                packed=jax.device_put(packed, self.device),
                scale=jax.device_put(scale, self.device),
                bits=hp.bits, orig_shape=hp.orig_shape)
        return out

    def template_view(self) -> Dict[str, PackedParam]:
        """Device-format template leaves for every PAGED param — what the
        engine threads into its params tree so the jitted step traces the
        exact shapes/dtypes a streamed page will later fill.  Wire-served
        params present their WIRE buffers (leading dims restored to the
        device carrier's, as the fetch path does); everything else decodes
        the host image back to the device layout once, host-side."""
        view: Dict[str, PackedParam] = {}
        for name, hp in self._host.items():
            if name in self.wire_served:
                lead = hp.packed_shape[:-1]
                view[name] = PackedParam(
                    packed=hp.payload.reshape(*lead, -1),
                    scale=hp.scales.reshape(*lead, -1),
                    bits=hp.page_bits, orig_shape=hp.orig_shape)
                continue
            packed, scale = hp.decode()
            view[name] = PackedParam(packed=packed, scale=scale,
                                     bits=hp.bits, orig_shape=hp.orig_shape)
        return view

    def stream(self, resident_slots: int = 2) -> "PageStream":
        """(page, device params) in access order with proactive prefetch.

        Returns a :class:`PageStream` — iterate it directly, or use it as a
        context manager so breaking out mid-pass cancels/drains in-flight
        swaps instead of leaking them past interpreter teardown.  Each pass
        reclaims the live page slots on completion (the next inference
        starts from a cold page cache — what the 2-slot budget dictates for
        any network with more than ``resident_slots`` pages), so per-pass
        counters follow the static :func:`pass_counters` prediction.
        """
        return PageStream(self, resident_slots)

    def begin_pass(self, resident_slots: int = 2) -> "AsyncPageStream":
        """Kick ONE full overlapped streaming pass and return immediately.

        The whole double-buffered fetch loop is submitted to the fetch
        worker up front (demand/prefetch order and counters identical to
        :meth:`stream`), so host->device page traffic proceeds while the
        caller computes; :meth:`AsyncPageStream.fence` joins the futures
        at first use and splits the pass wall time into the *exposed*
        wait (time the caller actually blocked) and the *hidden* overlap
        — the §II-B2 proactive swap, realized across ticks instead of
        across pages."""
        return AsyncPageStream(self, resident_slots)

    def close(self, wait: bool = True):
        """Shut the prefetch worker down.  ``wait=True`` (default) blocks
        until in-flight swaps finish — never leak a ``_fetch_page`` past
        interpreter teardown; ``wait=False`` cancels what it can instead.
        Either way the closed flag is raised FIRST, so a fetch already
        running on the worker (which ``cancel_futures`` cannot stop)
        aborts before installing its page into the store or pool."""
        self._closed = True
        self._pool.shutdown(wait=wait, cancel_futures=not wait)

    def __enter__(self) -> "HostPagedStore":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class PageStream:
    """One streaming pass over a :class:`HostPagedStore` — an iterable of
    ``(Page, {name: PackedParam})`` that is also a context manager.

    Closing (explicitly, via ``with``, or by exhausting the iterator)
    cancels or drains in-flight prefetches and reclaims the live page
    slots, so a consumer that stops early cannot leak a worker-thread
    fetch past teardown."""

    def __init__(self, store: HostPagedStore, resident_slots: int = 2):
        self._store = store
        self._sched = make_schedule(len(store.pages), resident_slots)
        self._inflight: Dict[int, Future] = {}
        if store.pool is not None:
            store.pool.log_event("pass", store.name)
        self._gen = self._iterate()

    def __iter__(self):
        return self._gen

    def __enter__(self) -> "PageStream":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def close(self):
        for fut in self._inflight.values():
            if not fut.cancel():
                try:
                    fut.result()    # already running: drain, don't leak
                except CancelledError:
                    pass            # store closed mid-fetch: nothing to keep
        self._inflight.clear()
        self._store._live.clear()   # slots reclaimed between passes
        self._gen.close()

    def _iterate(self):
        st = self._store
        try:
            for e in self._sched:
                if e.page in st._live:
                    page_params = st._live[e.page]
                elif e.page in self._inflight:
                    page_params = self._inflight.pop(e.page).result()
                    st._live[e.page] = page_params
                else:
                    st.miss_count += 1    # demand miss (cold start)
                    page_params = st._fetch_page(e.page)
                    st._live[e.page] = page_params
                if (e.prefetch_next is not None
                        and e.prefetch_next not in st._live):
                    self._inflight[e.prefetch_next] = st._fetch_exec.submit(
                        st._fetch_page, e.prefetch_next)
                if e.evicts is not None:
                    st._live.pop(e.evicts, None)
                yield st.pages[e.page], page_params
        finally:
            for fut in self._inflight.values():
                if not fut.cancel():
                    try:
                        fut.result()
                    except CancelledError:
                        pass        # store closed mid-fetch: drop the page
            self._inflight.clear()
            st._live.clear()


class AsyncPageStream:
    """One *overlapped* streaming pass over a :class:`HostPagedStore`.

    Construction (via :meth:`HostPagedStore.begin_pass`) submits every
    page fetch of the pass to the fetch worker in the exact order the
    synchronous :class:`PageStream` would perform them — same demand-miss
    accounting, same pool lookup/admit sequence, same swap counters; the
    only thing that changes is *when* the caller waits.  :meth:`fence`
    joins the futures at first use and records the stall split:

      * ``window_s``  — begin -> fence call: the compute the caller ran
        while the stream was in flight;
      * ``exposed_s`` — time the fence actually blocked (critical path);
      * ``hidden_s``  — stream wall time that genuinely overlapped the
        window: ``min(begin -> last-fetch-done, window)``;
      * ``swap_s``    — ``hidden_s + exposed_s``, the pass's full stream
        wall time, the traffic's cost wherever it lands.

    By construction ``exposed_s``/``hidden_s`` equal the analytical
    ``stall += swap - hidden`` identity of
    :func:`repro.core.memsys.overlap_stall` applied to (``swap_s``,
    ``window_s``) — tests assert the runtime against that closed form.

    For pool members the pass registers with the pool's fetch guard so
    co-tenant admissions cannot evict its in-flight pages mid-fetch; the
    guard releases automatically when the last fetch settles (finished OR
    cancelled), and :meth:`close` cancels/drains an unfenced pass without
    leaking worker fetches or guard entries.
    """

    def __init__(self, store: HostPagedStore, resident_slots: int = 2):
        self._store = store
        self._result: Optional[Dict[str, PackedParam]] = None
        self._closed = False
        self.swap_s = 0.0
        self.window_s = 0.0
        self.exposed_s = 0.0
        self.hidden_s = 0.0
        pool = store.pool
        self._t_ready: Optional[float] = None   # last fetch completion
        self._t_begin = time.perf_counter()
        # replay the schedule's live/inflight bookkeeping so demand-miss
        # counting matches the sync pass, then submit EVERY fetch up
        # front; the single fetch worker executes them in this exact
        # order, which is the order PageStream fetches in
        self._futures: List[Tuple[int, Future]] = []
        self._marks: List[Future] = []
        if pool is not None:
            pool.log_event("pass", store.name)
            # the eviction guard must bracket pass EXECUTION, not pass
            # submission: marker tasks on the serialized fetch worker set
            # the guard right before this pass's first fetch runs and
            # release it right after its last — a begun-but-still-queued
            # co-tenant pass is NOT yet protected, so eviction decisions
            # (and counters) stay identical to the sequential sync order
            self._marks.append(
                store._fetch_exec.submit(pool._pass_begin, store.name))
        live: set = set()
        inflight: set = set()
        for e in make_schedule(len(store.pages), resident_slots):
            if e.page in live:
                pass
            elif e.page in inflight:
                inflight.discard(e.page)
                live.add(e.page)
            else:
                store.miss_count += 1        # demand miss (cold start)
                self._futures.append(
                    (e.page, store._fetch_exec.submit(store._fetch_page,
                                                      e.page)))
                live.add(e.page)
            if e.prefetch_next is not None and e.prefetch_next not in live:
                inflight.add(e.prefetch_next)
                self._futures.append(
                    (e.prefetch_next,
                     store._fetch_exec.submit(store._fetch_page,
                                              e.prefetch_next)))
            if e.evicts is not None:
                live.discard(e.evicts)
        if pool is not None:
            self._marks.append(
                store._fetch_exec.submit(pool._pass_end, store.name))
        if self._futures:
            # stamp the moment the LAST page lands, so hidden time is
            # the stream's true wall, never the whole compute window
            self._futures[-1][1].add_done_callback(self._mark_ready)
        else:
            self._t_ready = self._t_begin

    def _mark_ready(self, _fut) -> None:
        self._t_ready = time.perf_counter()

    @property
    def done(self) -> bool:
        """True once fenced (or closed) — the pass can't be consumed twice."""
        return self._result is not None or self._closed

    def fence(self, timeout_s: Optional[float] = None
              ) -> Dict[str, PackedParam]:
        """Join the pass: block until every page is device-ready, thread
        nothing (the caller owns template threading), and record the
        exposed/hidden stall split.  Idempotent — a second fence returns
        the same params without re-waiting or re-accounting.

        ``timeout_s`` bounds the TOTAL wait across the pass's remaining
        fetches; exceeding it raises
        :class:`~repro.core.faults.PageFetchTimeout` and leaves the pass
        fully resumable — no futures are dropped, no stall is accounted,
        and a later ``fence()`` picks up exactly where this one gave up
        (the degradation hook the scheduler's tick deferral rides)."""
        if self._closed:
            raise RuntimeError("fence() after close(): the pass was "
                               "cancelled")
        if self._result is not None:
            return self._result
        t_fence = time.perf_counter()
        dev: Dict[str, PackedParam] = {}
        for n_done, (_idx, fut) in enumerate(self._futures):
            try:
                remaining = (None if timeout_s is None else
                             max(0.0, timeout_s - (time.perf_counter()
                                                   - t_fence)))
                dev.update(fut.result(timeout=remaining))
            except FuturesTimeout:
                self._store.fault_counters["fetch_timeouts"] += 1
                raise PageFetchTimeout(
                    model=self._store.name, timeout_s=timeout_s,
                    pending=len(self._futures) - n_done) from None
        jax.block_until_ready([p.packed for p in dev.values()])
        t_join = time.perf_counter()
        # a result() can return a hair before the completion callback
        # fires on the worker; fall back to the join timestamp then
        t_ready = self._t_ready if self._t_ready is not None else t_join
        self.window_s = t_fence - self._t_begin
        self.exposed_s = t_join - t_fence
        self.hidden_s = min(t_ready - self._t_begin, self.window_s)
        self.swap_s = self.hidden_s + self.exposed_s
        self._futures.clear()
        self._result = dev
        return dev

    def close(self) -> None:
        """Cancel what hasn't started, drain what has (never leak a fetch
        past teardown), and release the pool's fetch guard even when its
        end marker was cancelled.  Safe to call on a fenced pass (no-op)
        and idempotent."""
        for fut in [f for _i, f in self._futures] + self._marks:
            if not fut.cancel():
                try:
                    fut.result()
                except Exception:
                    pass             # executor already shut down mid-drain
        self._futures.clear()
        self._marks.clear()
        if self._result is None:
            self._closed = True
        if self._store.pool is not None:
            self._store.pool._pass_end(self._store.name)

    def __enter__(self) -> "AsyncPageStream":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def pass_counters(n_pages: int, resident_slots: int = 2) -> Dict[str, int]:
    """Static swap/miss counts for ONE full streaming pass starting from a
    cold page cache — the closed-form prediction the runtime counters of
    :class:`HostPagedStore` must match pass for pass (every page is fetched
    exactly once; only the first is a demand miss, the rest ride the
    proactive prefetch)."""
    live: set = set()
    inflight: set = set()
    swaps = misses = 0
    for e in make_schedule(n_pages, resident_slots):
        if e.page in live:
            pass
        elif e.page in inflight:
            inflight.discard(e.page)
            live.add(e.page)
        else:
            misses += 1
            swaps += 1
            live.add(e.page)
        if e.prefetch_next is not None and e.prefetch_next not in live:
            inflight.add(e.prefetch_next)
            swaps += 1
        if e.evicts is not None:
            live.discard(e.evicts)
    return dict(swaps=swaps, misses=misses)


# ---------------------------------------------------------------------------
# Mesh-sharded paging: one engine, N parallel memory links (ROADMAP 1(a))
# ---------------------------------------------------------------------------

def shard_packed_param(p: PackedParam, axis: int, n: int, i: int
                       ) -> PackedParam:
    """Shard ``i`` of ``n`` of a packed param, sliced along dense ``axis``.

    ``axis`` must be a NON-LAST dim of ``orig_shape``
    (:func:`repro.parallel.sharding.shard_axis` guarantees this): the
    packed carrier shares every leading dim with the dense shape and the
    per-channel scales span ``orig_shape[:-1]``, so one slice expression
    covers payload and scales alike — and because the page wire codec
    operates per row (blocks along the last axis, channel scales on the
    ``(rows, k)`` view), encode->decode of a shard equals the shard of
    encode->decode: concatenating the per-device fetches reconstructs the
    single-device bytes exactly."""
    size = int(p.orig_shape[axis])
    if axis >= len(p.orig_shape) - 1:
        raise ValueError(f"cannot shard the packed last axis {axis} of "
                         f"shape {tuple(p.orig_shape)}")
    if size % n != 0:
        raise ValueError(f"axis {axis} of {tuple(p.orig_shape)} does not "
                         f"split into {n} shards")
    step = size // n
    sl = [slice(None)] * len(p.orig_shape)
    sl[axis] = slice(step * i, step * (i + 1))
    orig = list(p.orig_shape)
    orig[axis] = step
    return PackedParam(packed=np.asarray(p.packed)[tuple(sl)],
                       scale=np.asarray(p.scale)[tuple(sl[:-1])],
                       bits=p.bits, orig_shape=tuple(orig))


def store_shard_axes(store: WeightStore, plan: Optional[PlacementPlan],
                     mesh: Any) -> Dict[str, Tuple[int, int]]:
    """{param name: (axis, n_shards)} for every param the mesh's "model"
    axis tensor-shards under the :func:`~repro.parallel.sharding
    ._param_pspec` rules.  With a ``plan``, restricted to its PAGED params
    (the resident hot set stays whole on the compute device); without
    one, covers the full store — the form ``plan_for_budget``'s
    ``shard_factors`` wants *before* a plan exists."""
    from repro.parallel.sharding import shard_axis
    out: Dict[str, Tuple[int, int]] = {}
    for name, p in store.params.items():
        if plan is not None and not plan.placement_for(name).paged:
            continue
        ax = shard_axis(tuple(name.split("/")), tuple(p.orig_shape), mesh)
        if ax is not None:
            out[name] = ax
    return out


class ShardedPoolLedger:
    """N per-device page pools under ONE global device-bytes budget.

    The Siracusa reading: the cluster and N-EUREKA each stream their own
    At-MRAM slice over their own memory port, but the chip still has ONE
    byte budget — so each device link gets ``budget // n`` of it (a
    private :class:`SharedPagePool`), and this ledger re-aggregates the
    per-device ``(device, wire, raw)`` counters into the global view.
    ``budget_bytes=None`` models the pool-less default (every pass
    re-swaps every page on every link — the single-device
    :class:`HostPagedStore` discipline, N links wide).

    :meth:`predict` composes the per-device
    :func:`kv_pass_counters` replays into one global prediction: each
    device's pages and events replay independently (the links are
    independent), and the sums must match the runtime counters member
    for member — the same determinism contract the single-device pool
    keeps."""

    def __init__(self, budget_bytes: Optional[int], n_devices: int,
                 name: str = "default"):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        self.name = name
        self.n_devices = int(n_devices)
        self.budget_bytes = (None if budget_bytes is None
                             else int(budget_bytes))
        self.pools: Optional[List[SharedPagePool]] = None
        if budget_bytes is not None:
            per = max(1, int(budget_bytes) // n_devices)
            self.pools = [SharedPagePool(per) for _ in range(n_devices)]
        self.stores: List["HostPagedStore"] = []
        self.pass_count = 0              # pool-less passes begun (predict)
        self._lock = threading.Lock()
        self.counters: Dict[str, Dict[str, float]] = {}
        self._tracer = None

    def register(self, store: "HostPagedStore") -> None:
        with self._lock:
            self.stores.append(store)

    def pool_for(self, device_index: int) -> Optional[SharedPagePool]:
        return None if self.pools is None else self.pools[device_index]

    def add_stall(self, name: str, exposed_s: float,
                  hidden_s: float = 0.0) -> None:
        """Ledger-level stall view of a joined pass (the engine fences
        ONE joined stream, so the split arrives already aggregated)."""
        with self._lock:
            c = self.counters.setdefault(name, dict(exposed_s=0.0,
                                                    hidden_s=0.0))
            c["exposed_s"] += float(exposed_s)
            c["hidden_s"] += float(hidden_s)

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer) -> None:
        self._tracer = tracer
        if self.pools is not None:
            for pool in self.pools:
                pool.tracer = tracer

    def predict(self, resident_slots: int = 2) -> Dict[str, int]:
        """Global counter prediction: per-device replays, summed."""
        total = dict(swaps=0, misses=0, pool_hits=0, evicted=0, dropped=0,
                     bytes_wire=0, bytes_raw=0)
        for i, store in enumerate(self.stores):
            pool = self.pool_for(i)
            if pool is not None:
                sizes = {m: page_sizes(s.pages)
                         for m, s in pool.members.items()}
                events, budget = pool.events, pool.budget_bytes
            else:
                sizes = {store.name: page_sizes(store.pages)}
                events = [("pass", store.name)] * self.pass_count
                budget = None
            pred = kv_pass_counters(sizes, budget, events,
                                    resident_slots=resident_slots)
            for c in pred.values():
                for k in total:
                    total[k] += int(c.get(k, 0))
        return total

    def summary(self) -> Dict[str, Any]:
        """The global byte ledger + the per-device split it aggregates."""
        per_device = []
        for i, store in enumerate(self.stores):
            d = dict(device=str(store.device), n_pages=len(store.pages),
                     swap_count=store.swap_count,
                     miss_count=store.miss_count,
                     bytes_streamed_wire=store.bytes_streamed_wire,
                     bytes_streamed_raw=store.bytes_streamed_raw)
            pool = self.pool_for(i)
            if pool is not None:
                d.update(budget_bytes=pool.budget_bytes,
                         live_bytes=pool.live_bytes,
                         cached_pages=len(pool._cache))
            per_device.append(d)
        with self._lock:
            stalls = {m: dict(c) for m, c in self.counters.items()}
        return dict(
            budget_bytes=self.budget_bytes,
            n_devices=self.n_devices,
            swap_count=sum(d["swap_count"] for d in per_device),
            miss_count=sum(d["miss_count"] for d in per_device),
            bytes_streamed_wire=sum(d["bytes_streamed_wire"]
                                    for d in per_device),
            bytes_streamed_raw=sum(d["bytes_streamed_raw"]
                                   for d in per_device),
            per_device=per_device, stalls=stalls)

    def close(self, wait: bool = True) -> None:
        if self.pools is not None:
            for pool in self.pools:
                pool.close(wait=wait)     # closes the member stores too
        else:
            for store in self.stores:
                store.close(wait=wait)


class ShardedPagedStore:
    """One paged store fanned out over the mesh's "model" devices — each
    device link streams ONLY its shard (duck-types
    :class:`HostPagedStore` for the engine's begin/fence pipeline).

    Parameter routing, per the :func:`store_shard_axes` rules:

      * tensor-shardable paged params are split with
        :func:`shard_packed_param`; device ``i`` holds shard ``i`` and its
        own page cache — per-link wire traffic drops ~1/N for them;
      * replicated paged params (and the plan's whole resident set, and
        the passthrough leaves) live on device 0 only — they are paged
        ONCE and broadcast at the join, so the global byte ledger for
        them equals the single-device ledger exactly.

    :meth:`begin_pass` starts one :class:`AsyncPageStream` per device
    store; the returned :class:`JoinedPageStream` fences all of them and
    concatenates the shard fetches back into full-shape device params on
    the compute device — the per-row page wire codec commutes with
    leading-axis slicing, so the joined bytes are bit-identical to a
    single-device fetch and decode stays bit-exact by construction."""

    def __init__(self, store: WeightStore, page_bytes: int, mesh: Any,
                 plan: Optional[PlacementPlan] = None,
                 budget_bytes: Optional[int] = None,
                 name: str = "default", faults: FaultsArg = None):
        axis_names = tuple(getattr(mesh, "axis_names", ()))
        if "model" not in axis_names:
            raise ValueError(f"mesh axes {axis_names} have no 'model' "
                             f"axis to shard the paged store on")
        n = int(mesh.shape["model"])
        if n < 2:
            raise ValueError("model axis of size 1 shards nothing — use "
                             "HostPagedStore directly")
        devs = np.asarray(mesh.devices).reshape(-1, n)[0]
        self.mesh = mesh
        self.devices: Tuple = tuple(devs.tolist())
        self.n_shards = n
        self.name = name
        self.plan = plan
        self.store = store
        self.shard_axes = store_shard_axes(store, plan, mesh)
        self.ledger = ShardedPoolLedger(budget_bytes, n, name=name)
        self.stores: List[HostPagedStore] = []
        self._tracer = None
        for i, dev in enumerate(self.devices):
            params: Dict[str, PackedParam] = {}
            passthrough: Dict[str, Any] = {}
            for pname, p in store.params.items():
                ax = self.shard_axes.get(pname)
                if ax is not None:
                    params[pname] = shard_packed_param(p, ax[0], n, i)
                elif i == 0:
                    params[pname] = p     # replicated/resident: dev 0 only
            if i == 0:
                passthrough = dict(store.passthrough)
            sub = HostPagedStore(
                WeightStore(params=params, passthrough=passthrough),
                page_bytes, device=dev, plan=plan,
                pool=self.ledger.pool_for(i),
                name=f"{name}@dev{i}", faults=faults)
            self.stores.append(sub)
            self.ledger.register(sub)

    # -- aggregate counters (the HostPagedStore surface) ---------------------
    @property
    def resident(self) -> Dict[str, PackedParam]:
        return self.stores[0].resident

    @property
    def pages(self) -> List[Page]:
        return [p for s in self.stores for p in s.pages]

    @property
    def swap_count(self) -> int:
        return sum(s.swap_count for s in self.stores)

    @property
    def miss_count(self) -> int:
        return sum(s.miss_count for s in self.stores)

    @property
    def bytes_streamed_wire(self) -> int:
        return sum(s.bytes_streamed_wire for s in self.stores)

    @property
    def bytes_streamed_raw(self) -> int:
        return sum(s.bytes_streamed_raw for s in self.stores)

    @property
    def decode_s(self) -> float:
        return sum(s.decode_s for s in self.stores)

    @property
    def decode_skipped_bytes(self) -> int:
        return sum(s.decode_skipped_bytes for s in self.stores)

    @property
    def wire_served(self) -> set:
        return set().union(*(s.wire_served for s in self.stores))

    @property
    def fault_counters(self) -> Dict[str, int]:
        from repro.core.faults import merge_fault_counters
        return merge_fault_counters([s.fault_counters
                                     for s in self.stores])

    @property
    def pool(self) -> Optional[ShardedPoolLedger]:
        """The engine's ``pager.pool`` hook: the ledger when a global
        budget was given (it answers ``add_stall``), None otherwise —
        mirroring the single-device pool-less default."""
        return self.ledger if self.ledger.pools is not None else None

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer) -> None:
        self._tracer = tracer
        for s in self.stores:
            s.tracer = tracer
        self.ledger.tracer = tracer

    def device_summaries(self) -> List[Dict[str, Any]]:
        """Per-device counter rows for the metrics v9 ``paging.devices``
        section (summary shape owned by the ledger)."""
        return self.ledger.summary()["per_device"]

    def template_view(self) -> Dict[str, PackedParam]:
        """Full-shape template leaves: device-0's view, with sharded
        params re-concatenated host-side along their shard axis."""
        per_dev = [s.template_view() for s in self.stores]
        view = dict(per_dev[0])
        for pname, (ax, _n) in self.shard_axes.items():
            parts = [pv[pname] for pv in per_dev]
            orig = list(parts[0].orig_shape)
            orig[ax] = sum(int(p.orig_shape[ax]) for p in parts)
            view[pname] = PackedParam(
                packed=np.concatenate([np.asarray(p.packed)
                                       for p in parts], axis=ax),
                scale=np.concatenate([np.asarray(p.scale)
                                      for p in parts], axis=ax),
                bits=parts[0].bits, orig_shape=tuple(orig))
        return view

    def begin_pass(self, resident_slots: int = 2) -> "JoinedPageStream":
        self.ledger.pass_count += 1
        return JoinedPageStream(self, resident_slots)

    def predict(self, resident_slots: int = 2) -> Dict[str, int]:
        return self.ledger.predict(resident_slots)

    def close(self, wait: bool = True) -> None:
        self.ledger.close(wait=wait)

    def __enter__(self) -> "ShardedPagedStore":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class JoinedPageStream:
    """One overlapped pass over EVERY device link of a
    :class:`ShardedPagedStore` — duck-types :class:`AsyncPageStream` for
    the engine's fence.

    Construction begins one :class:`AsyncPageStream` per device store
    (all N links stream concurrently — each store owns its own fetch
    worker/pool, so the per-device orders stay deterministic
    independently).  :meth:`fence` joins ALL of them, re-concatenates the
    shard fetches into full-shape params on the join device (device 0 —
    the compute device, so tokens stay bit-exact vs the single-device
    run), and records ONE aggregate exposed/hidden split with
    :class:`AsyncPageStream`'s exact algebra: the stream-ready time is
    the LAST link's, because the tick cannot start until the slowest
    port delivers.

    A ``timeout_s`` expiry propagates the child's
    :class:`~repro.core.faults.PageFetchTimeout` and leaves EVERY link
    resumable — already-fenced children cache their result, the raising
    child keeps its futures — so a deferred tick re-fences the same
    joined pass.  :meth:`close` closes every child (each releases its own
    pool guard), so an early exit orphans no per-device pass."""

    def __init__(self, sharded: ShardedPagedStore,
                 resident_slots: int = 2):
        self._sharded = sharded
        self._result: Optional[Dict[str, PackedParam]] = None
        self._closed = False
        self.swap_s = 0.0
        self.window_s = 0.0
        self.exposed_s = 0.0
        self.hidden_s = 0.0
        self._t_begin = time.perf_counter()
        self._streams = [s.begin_pass(resident_slots)
                         for s in sharded.stores]

    @property
    def done(self) -> bool:
        return self._result is not None or self._closed

    def fence(self, timeout_s: Optional[float] = None
              ) -> Dict[str, PackedParam]:
        if self._closed:
            raise RuntimeError("fence() after close(): the pass was "
                               "cancelled")
        if self._result is not None:
            return self._result
        import jax.numpy as jnp
        t_fence = time.perf_counter()
        per_dev = []
        for ps in self._streams:
            remaining = (None if timeout_s is None else
                         max(0.0, timeout_s - (time.perf_counter()
                                               - t_fence)))
            per_dev.append(ps.fence(timeout_s=remaining))
        target = self._sharded.devices[0]
        dev: Dict[str, PackedParam] = dict(per_dev[0])
        for name, (ax, _n) in self._sharded.shard_axes.items():
            parts = [pd[name] for pd in per_dev]
            orig = list(parts[0].orig_shape)
            orig[ax] = sum(int(p.orig_shape[ax]) for p in parts)
            dev[name] = PackedParam(
                packed=jnp.concatenate([jax.device_put(p.packed, target)
                                        for p in parts], axis=ax),
                scale=jnp.concatenate([jax.device_put(p.scale, target)
                                       for p in parts], axis=ax),
                bits=parts[0].bits, orig_shape=tuple(orig))
        jax.block_until_ready([p.packed for p in dev.values()])
        t_join = time.perf_counter()
        readys = [ps._t_ready for ps in self._streams
                  if ps._t_ready is not None]
        t_ready = max(readys) if readys else t_join
        self.window_s = t_fence - self._t_begin
        self.exposed_s = t_join - t_fence
        self.hidden_s = min(t_ready - self._t_begin, self.window_s)
        self.swap_s = self.hidden_s + self.exposed_s
        self._result = dev
        return dev

    def close(self) -> None:
        for ps in self._streams:
            ps.close()
        if self._result is None:
            self._closed = True

    def __enter__(self) -> "JoinedPageStream":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


# ---------------------------------------------------------------------------
# KV-cache paging: the per-slot KV cache flows through the SAME budget
# ---------------------------------------------------------------------------

class KVPageTable:
    """Pages a serving engine's per-slot KV cache through the *same*
    device-bytes budget — and the same begin/fence overlap — the weight
    pages use (the paper's one-memory-hierarchy constraint: §V's
    concurrent workloads share ONE At-MRAM, so long-context KV state
    cannot dodge the budget the weights respect).

    Addressing: a KV *page* is ``block_rows`` consecutive cache rows of
    one batch slot, across every layer and both k and v — page index
    ``slot * n_blocks + block`` (vLLM-style fixed-size blocks).  The
    engine's preallocated device cache stays the compute working buffer
    (jit shapes never change); the authoritative copy of every
    *completed* block lives in this table's host image:

      * a block is written back host-ward exactly once, when the
        prefill/decode frontier crosses its end (KV writes are
        append-only, so completed blocks are immutable from then on);
      * each tick the live span's completed blocks stream host->device
        through the pool and are scattered over the device cache — a
        pooled block satisfies the fetch without a swap (``pool_hits``),
        eviction under pressure is the pool's cross-model call, and a
        pool-less table re-swaps every block every pass (exactly the
        private ``HostPagedStore`` discipline);
      * the partially filled *frontier* block stays device-resident — it
        is still being appended to (vLLM keeps the active block on-GPU
        for the same reason);
      * when a batch slot is handed to a new request, the old request's
        pooled blocks are dropped (``queue_drop`` / ``flush_drops`` — the
        flush runs at the next fence, after every in-flight fetch has
        settled, so a late fetch can never resurrect a stale page).

    Counters (``swap_count`` == ``miss_count``: every non-pooled KV fetch
    is a demand swap), writebacks and drops follow the static
    :func:`kv_pass_counters` replay of the pool's event log.
    """

    def __init__(self, cache_kv: Dict[str, Any], *, block_rows: int = 16,
                 pool: Optional[SharedPagePool] = None,
                 name: str = "default/kv",
                 device: Optional[jax.Device] = None,
                 faults: FaultsArg = None):
        if block_rows < 1:
            raise ValueError(f"block_rows must be >= 1, got {block_rows}")
        k = np.asarray(cache_kv["k"])
        v = np.asarray(cache_kv["v"])
        # cache layout (n_layers, n_slots, n_kv_heads, max_len, head_dim)
        self.n_slots = int(k.shape[1])
        self.max_len = int(k.shape[3])
        self.block_rows = int(block_rows)
        self.n_blocks = -(-self.max_len // self.block_rows)
        self.host = dict(k=k.copy(), v=v.copy())
        self.row_nbytes = (k.nbytes + v.nbytes) // (self.n_slots
                                                    * self.max_len)
        self.page_nbytes = self.block_rows * self.row_nbytes
        self.name = name
        self.pool = pool
        self.device = device or jax.devices()[0]
        self.swap_count = 0
        self.miss_count = 0
        self.pool_hits = 0
        # KV rows stream in their device format ("fp" page encoding):
        # wire == raw == device bytes, so the ledger shows ratio 1.0
        self.bytes_streamed_wire = 0
        self.bytes_streamed_raw = 0
        self.writebacks = 0          # blocks written back host-ward
        self.dropped = 0             # pooled blocks invalidated (slot reuse)
        self.preempt_drops = 0       # of which: mid-request preemptions
        # KV rows move host numpy -> device directly (no wire codec), so
        # there is nothing for a bit-flip to corrupt pre-checksum: the
        # injector's transient failures / latency faults apply, bitflips
        # don't (weight pages carry the CRC-checked wire path)
        self.faults = as_injector(faults)
        self.fault_counters = new_fault_counters()
        self._closed = False
        # pool-less prediction log (pooled tables log into pool.events)
        self.events: List[Tuple] = []
        self._pending_drops: set = set()
        self._exec = ThreadPoolExecutor(max_workers=1)
        # opt-in chrome-trace hook (ServingEngine.set_tracer): per-block
        # fetch spans + kvdrop instants on the "io" track
        self.tracer = None
        if pool is not None:
            pool.register(name, self)

    @property
    def pages(self) -> range:
        return range(self.n_slots * self.n_blocks)

    @property
    def _fetch_exec(self) -> ThreadPoolExecutor:
        return self._exec if self.pool is None else self.pool._exec

    def _log(self, *event) -> None:
        if self.pool is not None:
            self.pool.log_event(*event)
        else:
            self.events.append(tuple(event))

    def page_index(self, slot: int, block: int) -> int:
        return slot * self.n_blocks + block

    def _block_rows_span(self, page_idx: int) -> Tuple[int, int, int]:
        slot, blk = divmod(page_idx, self.n_blocks)
        a = blk * self.block_rows
        return slot, a, min(a + self.block_rows, self.max_len)

    def _fetch_block(self, page_idx: int) -> Dict[str, Any]:
        tr = self.tracer
        t0 = tr.now() if tr is not None else 0.0
        if self._closed:
            raise CancelledError(f"{self.name}: table closed before fetch "
                                 f"of page {page_idx} started")
        if self.pool is not None:
            cached = self.pool.lookup(self.name, page_idx)
            if cached is not None:
                self.pool_hits += 1
                if tr is not None:       # pool hit: no host->device swap
                    tr.complete("kv_block", tr.now() - t0, track="io",
                                model=self.name, page=page_idx,
                                pool_hit=True)
                return cached
        slot, a, b = self._block_rows_span(page_idx)
        rows = retry_fetch(self, page_idx,
                           lambda attempt: self._fetch_block_once(
                               page_idx, slot, a, b, attempt))
        if self._closed:
            raise CancelledError(f"{self.name}: table closed during fetch "
                                 f"of page {page_idx}")
        self.swap_count += 1
        self.miss_count += 1
        nb = (b - a) * self.row_nbytes
        self.bytes_streamed_wire += nb
        self.bytes_streamed_raw += nb
        if self.pool is not None:
            self.pool.admit(self.name, page_idx, nb, rows)
        if tr is not None:
            tr.complete("kv_block", tr.now() - t0, track="io",
                        model=self.name, page=page_idx,
                        nbytes=(b - a) * self.row_nbytes, pool_hit=False)
        return rows

    def _fetch_block_once(self, page_idx: int, slot: int, a: int, b: int,
                          attempt: int) -> Dict[str, Any]:
        if self.faults is not None:
            self.fault_counters["injected"] += self.faults.pre_fetch(
                self.name, page_idx, attempt)
        return dict(
            k=jax.device_put(self.host["k"][:, slot, :, a:b], self.device),
            v=jax.device_put(self.host["v"][:, slot, :, a:b], self.device))

    def writeback(self, slot: int, block_lo: int, block_hi: int,
                  cache_kv: Dict[str, Any]) -> None:
        """Completed blocks ``[block_lo, block_hi)`` of ``slot`` move
        device->host from the engine's cache buffer — each row exactly
        once, at the moment its block fills (append-only KV means the
        block is immutable from here on)."""
        if block_hi <= block_lo:
            return
        a = block_lo * self.block_rows
        b = min(block_hi * self.block_rows, self.max_len)
        for part in ("k", "v"):
            self.host[part][:, slot, :, a:b] = np.asarray(
                cache_kv[part][:, slot, :, a:b])
        self.writebacks += block_hi - block_lo

    def queue_drop(self, slot: int) -> None:
        """Mark ``slot``'s pages stale (its request retired / the slot is
        being reassigned).  The actual pool invalidation is deferred to
        :meth:`flush_drops` at the next fence — after every in-flight
        fetch has settled — so a still-executing fetch of the old
        request's block cannot re-admit a page after the drop."""
        self._pending_drops.add(int(slot))

    def flush_drops(self) -> None:
        if not self._pending_drops:
            return
        for slot in sorted(self._pending_drops):
            pages = range(slot * self.n_blocks, (slot + 1) * self.n_blocks)
            if self.pool is not None:
                removed = tuple(p for p in pages
                                if self.pool.invalidate(self.name, p))
                if removed:
                    self.pool.log_event("kvdrop", self.name, removed)
                    if self.tracer is not None:
                        self.tracer.instant("kvdrop", track="io",
                                            model=self.name, slot=slot,
                                            pages=len(removed))
                self.dropped += len(removed)
            # stale rows must never be served again: zero them so a bug
            # that fetches a dropped block surfaces as loud wrong bytes
            self.host["k"][:, slot] = 0
            self.host["v"][:, slot] = 0
        self._pending_drops.clear()

    def preempt_release(self, slot: int, *, in_flight: bool) -> None:
        """Release ``slot``'s pooled blocks for a mid-request preemption.

        Same invalidation path as a retirement (``queue_drop``), but the
        flush timing is the preemption-safety decision: with no KV pass
        in flight (``in_flight=False`` — the single-scheduler admit
        point sits between fence and begin) the drop flushes NOW, so the
        slot's next occupant can write back this very tick without a
        later deferred flush zeroing its fresh blocks.  With a pass
        still unfenced (the tenancy admit point) the flush defers to
        that fence, which still lands before the usurper's first
        writeback.  Either way the pool sees one ``kvdrop`` event —
        ``kv_pass_counters`` replays preemptions natively."""
        self.queue_drop(slot)
        self.preempt_drops += 1
        if not in_flight:
            self.flush_drops()

    def begin_pass(self, full_blocks: Dict[int, int]) -> "KVPageStream":
        """Kick one overlapped KV streaming pass: ``full_blocks`` maps
        each live slot to its completed-block count; every listed block's
        fetch is submitted up front (slot order, then block order) and
        runs while the caller computes; blocks that complete between
        begin and fence are demand-fetched at the fence (that wait lands
        exposed, exactly where it belongs)."""
        return KVPageStream(self, full_blocks)

    def close(self, wait: bool = True) -> None:
        # flag first: a block fetch already running on the worker aborts
        # before installing into the pool (same discipline as
        # HostPagedStore.close)
        self._closed = True
        self._exec.shutdown(wait=wait, cancel_futures=not wait)

    def __enter__(self) -> "KVPageTable":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class KVPageStream:
    """One overlapped KV streaming pass — the KV counterpart of
    :class:`AsyncPageStream`, with the same exposed/hidden stall split
    (and the same ``stall += swap - hidden`` identity against
    :func:`repro.core.memsys.overlap_stall`).  ``fence(full_blocks)``
    takes the *current* completed-block spans so blocks that filled
    during the compute window are demand-fetched before the join."""

    def __init__(self, table: KVPageTable, full_blocks: Dict[int, int]):
        self._table = table
        self._begun = {int(s): int(n) for s, n in full_blocks.items()}
        self._futures: List[Tuple[int, Future]] = []
        self._marks: List[Future] = []
        self._result: Optional[Dict[int, Dict[str, Any]]] = None
        self._closed = False
        self.swap_s = 0.0
        self.window_s = 0.0
        self.exposed_s = 0.0
        self.hidden_s = 0.0
        self._t_last_done: Optional[float] = None
        self._t_begin = time.perf_counter()
        pages = self._page_list(self._begun)
        pool = table.pool
        if pool is not None and pages:
            # the guard brackets pass EXECUTION on the serialized worker,
            # exactly like AsyncPageStream's marker tasks
            self._marks.append(
                table._fetch_exec.submit(pool._pass_begin, table.name))
        self._submit(pages)
        if pool is not None and pages:
            self._marks.append(
                table._fetch_exec.submit(pool._pass_end, table.name))
        if not self._futures:
            # nothing streamed during the window: an all-demand fence
            # must read hidden == 0, never the whole compute window
            self._t_last_done = self._t_begin

    def _page_list(self, full_blocks: Dict[int, int],
                   already: Optional[Dict[int, int]] = None) -> List[int]:
        out = []
        for slot in sorted(full_blocks):
            lo = 0 if already is None else already.get(slot, 0)
            for blk in range(lo, full_blocks[slot]):
                out.append(self._table.page_index(slot, blk))
        return out

    def _submit(self, pages: List[int], track: bool = True) -> None:
        t = self._table
        if not pages:
            return
        t._log("kv", t.name, tuple((p, t.page_nbytes) for p in pages))
        for p in pages:
            fut = t._fetch_exec.submit(t._fetch_block, p)
            if track:
                # only the up-front (begin-batch) futures stamp the
                # stream-ready time: demand fetches submitted at the
                # fence complete after it and land wholly in exposed —
                # letting them stamp would inflate hidden to the entire
                # compute window (the trap AsyncPageStream avoids by
                # stamping only the last up-front fetch)
                fut.add_done_callback(self._mark_done)
            self._futures.append((p, fut))

    def _mark_done(self, _fut) -> None:
        self._t_last_done = time.perf_counter()

    @property
    def done(self) -> bool:
        return self._result is not None or self._closed

    def fence(self, full_blocks: Optional[Dict[int, int]] = None,
              timeout_s: Optional[float] = None
              ) -> Dict[int, Dict[str, Any]]:
        """Join the pass: demand-fetch blocks completed since begin, wait
        for every page, and record the exposed/hidden split.  Returns
        {page_index: {"k": rows, "v": rows}} for the engine to scatter.
        Idempotent, like :meth:`AsyncPageStream.fence`.

        ``timeout_s`` bounds the total wait; on expiry the fence raises
        :class:`~repro.core.faults.PageFetchTimeout` and stays resumable:
        demand fetches submitted here are folded into ``_begun`` *before*
        the join, so a re-fence after a deferred tick neither re-submits
        nor re-logs them."""
        if self._closed:
            raise RuntimeError("fence() after close(): the pass was "
                               "cancelled")
        if self._result is not None:
            return self._result
        t_fence = time.perf_counter()
        if full_blocks is not None:
            self._submit(self._page_list(full_blocks, already=self._begun),
                         track=False)
            for slot, n in full_blocks.items():
                self._begun[int(slot)] = max(self._begun.get(int(slot), 0),
                                             int(n))
        out: Dict[int, Dict[str, Any]] = {}
        for n_done, (p, fut) in enumerate(self._futures):
            try:
                remaining = (None if timeout_s is None else
                             max(0.0, timeout_s - (time.perf_counter()
                                                   - t_fence)))
                out[p] = fut.result(timeout=remaining)
            except FuturesTimeout:
                self._table.fault_counters["fetch_timeouts"] += 1
                raise PageFetchTimeout(
                    model=self._table.name, timeout_s=timeout_s,
                    pending=len(self._futures) - n_done) from None
        jax.block_until_ready([r for rows in out.values()
                               for r in rows.values()])
        t_join = time.perf_counter()
        t_ready = (self._t_last_done if self._t_last_done is not None
                   else t_join)
        self.window_s = t_fence - self._t_begin
        self.exposed_s = t_join - t_fence
        self.hidden_s = min(max(t_ready - self._t_begin, 0.0),
                            self.window_s)
        self.swap_s = self.hidden_s + self.exposed_s
        self._futures.clear()
        self._result = out
        return out

    def close(self) -> None:
        for fut in [f for _p, f in self._futures] + self._marks:
            if not fut.cancel():
                try:
                    fut.result()
                except Exception:
                    pass             # executor already shut down mid-drain
        self._futures.clear()
        self._marks.clear()
        if self._result is None:
            self._closed = True
        if self._table.pool is not None:
            self._table.pool._pass_end(self._table.name)

    def __enter__(self) -> "KVPageStream":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def kv_pass_counters(page_nbytes: Dict[str, Sequence[int]],
                     budget_bytes: Optional[int],
                     events: Sequence[Tuple],
                     resident_slots: int = 2) -> Dict[str, Dict[str, int]]:
    """Static per-member counter prediction for a pool whose members mix
    weight stores AND KV page tables — the unified eviction/accounting
    domain of KV-cache paging.

    ``events`` is the pool's :attr:`SharedPagePool.events` log (or a
    pool-less :attr:`KVPageTable.events`); ``page_nbytes`` maps each
    *weight* member to its page sizes in access order (KV batches carry
    their sizes inline).  Each size is either a plain int (device bytes;
    wire and raw default to it — the pre-encoding ledger) or a
    ``(device, wire, raw)`` triple as produced by :func:`page_sizes`:
    the cache simulation charges *device* bytes (what admission and
    eviction see) while every replayed swap accumulates *wire*/*raw*
    bytes into the member's ``bytes_wire``/``bytes_raw`` — so the
    prediction is exact in wire bytes even when cold pages stream
    compressed.  ``budget_bytes=None`` models a pool-less table: no
    cache, every fetch swaps.  Replays the runtime's exact
    lookup/admit/evict/invalidate sequence, so
    :meth:`SharedPagePool.summary` counters (and a private table's
    ``swap_count``) must match member for member.  On a weights-only
    event stream this agrees with :func:`shared_pass_counters`."""
    cache: "OrderedDict[Tuple[str, int], int]" = OrderedDict()
    live_bytes = 0
    out: Dict[str, Dict[str, int]] = {}

    def sizes3(entry) -> Tuple[int, int, int]:
        if isinstance(entry, (tuple, list)):
            dev, wire, raw = entry
            return int(dev), int(wire), int(raw)
        nb = int(entry)
        return nb, nb, nb

    def member(m: str) -> Dict[str, int]:
        return out.setdefault(m, dict(swaps=0, misses=0, pool_hits=0,
                                      evicted=0, dropped=0,
                                      bytes_wire=0, bytes_raw=0))

    def fetch(model: str, idx: int, size) -> None:
        nonlocal live_bytes
        nb, wire, raw = sizes3(size)
        key = (model, idx)
        if budget_bytes is not None and key in cache:
            cache.move_to_end(key)
            member(model)["pool_hits"] += 1
            return
        member(model)["swaps"] += 1
        member(model)["bytes_wire"] += wire
        member(model)["bytes_raw"] += raw
        if budget_bytes is None or nb > budget_bytes:
            return                  # mirrors admit's never-fits pre-check
        for victim in list(cache.keys()):
            if live_bytes + nb <= budget_bytes:
                break
            if victim[0] == model:
                continue
            live_bytes -= cache.pop(victim)
            member(victim[0])["evicted"] += 1
        if live_bytes + nb <= budget_bytes:
            cache[key] = nb
            live_bytes += nb

    for event in events:
        kind, model = event[0], event[1]
        if kind == "pass":
            m = member(model)
            sizes = page_nbytes[model]
            live: set = set()
            inflight: set = set()
            for e in make_schedule(len(sizes), resident_slots):
                if e.page in live:
                    pass
                elif e.page in inflight:
                    inflight.discard(e.page)
                    live.add(e.page)
                else:
                    m["misses"] += 1
                    fetch(model, e.page, sizes[e.page])
                    live.add(e.page)
                if e.prefetch_next is not None and e.prefetch_next not in live:
                    inflight.add(e.prefetch_next)
                    fetch(model, e.prefetch_next, sizes[e.prefetch_next])
                if e.evicts is not None:
                    live.discard(e.evicts)
        elif kind == "kv":
            m = member(model)
            for page, nb in event[2]:
                before = m["pool_hits"]
                fetch(model, int(page), nb)
                if m["pool_hits"] == before:
                    m["misses"] += 1     # every non-pooled KV fetch swaps
        elif kind == "kvdrop":
            for page in event[2]:
                nb = cache.pop((model, int(page)), None)
                if nb is not None:
                    live_bytes -= nb
                    member(model)["dropped"] += 1
        else:
            raise ValueError(f"unknown pool event kind {kind!r}")
    return out


def thread_packed(tree: Any, params: "Dict[str, PackedParam]") -> Any:
    """Return ``tree`` with each packed leaf group named in ``params``
    replaced by that PackedParam's packed/scale arrays — the inverse of
    :func:`packed_tree_store` for a subset of groups.  The serving runtime
    uses this to thread freshly streamed device pages (and the pinned
    resident set) into the tree its jitted step consumes; shapes and
    dtypes are unchanged, so the jit cache is stable across ticks."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = path_key(path)
        if key.endswith("/packed") and key[:-len("/packed")] in params:
            out.append(params[key[:-len("/packed")]].packed)
        elif key.endswith("/scale") and key[:-len("/scale")] in params:
            out.append(params[key[:-len("/scale")]].scale)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def packed_tree_store(tree: Any, plan: Optional[PlacementPlan] = None
                      ) -> WeightStore:
    """:class:`WeightStore` view over a ``freeze_for_serving`` packed tree.

    Every packable leaf group (a ``{"packed", "scale"}`` dict at path P)
    becomes one :class:`PackedParam` entry keyed by P — for the stacked LM
    tree that is one entry per parameter *group* across all depths, the
    exact granularity of ``placement.packed_sizes``/``plan_for_budget``.
    Non-packed leaves (embeddings, norms) are exposed as passthrough.
    This is the bridge the serving runtime uses to put a serve tree behind
    a :class:`HostPagedStore`."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = {path_key(p): leaf for p, leaf in flat}
    params: Dict[str, PackedParam] = {}
    passthrough: Dict[str, Any] = {}
    for key, leaf in leaves.items():
        if key.endswith("/packed"):
            base = key[:-len("/packed")]
            bits = plan.bits_for(base) if plan is not None else 8
            factor = 8 // bits
            orig_shape = (tuple(leaf.shape[:-1])
                          + (int(leaf.shape[-1]) * factor,))
            params[base] = PackedParam(packed=leaf,
                                       scale=leaves[base + "/scale"],
                                       bits=bits, orig_shape=orig_shape)
        elif (key.endswith("/scale")
                and key[:-len("/scale")] + "/packed" in leaves):
            continue
        else:
            passthrough[key] = leaf
    return WeightStore(params=params, passthrough=passthrough)

"""Per-layer weight placement — the single source of truth for *where
weights live* (paper §IV Fig 9 scenarios + §II-B2 virtual paging).

Siracusa's central result is that the integration point of the weight
memory (off-chip flash, background L3/L2 MRAM, or the At-MRAM port)
determines system latency and energy, and its virtual paging shows the
decision is made **per page, not per model**.  This module owns that
decision for the whole framework:

  * ``SCENARIOS`` — the four NVM integration points.  This is the only
    definition site; ``core.memsys`` (analytical model) and
    ``core.scenarios`` (executable weight paths) both import it, and a test
    asserts the two stacks stay in sync.
  * ``Placement`` — one parameter's placement: scenario, packed bit-width,
    and residency (``resident`` in the 4 MiB MRAM vs ``paged`` from
    background memory through the §II-B2 page cache).
  * ``PlacementPlan`` — maps parameter paths -> ``Placement`` via ordered
    glob rules with a default.  Consumed by all four layers that previously
    reinvented the concept: the executable linear dispatch
    (``models.layers.linear`` / ``core.engine``), the analytical walk
    (``memsys.network_walk``), paging (``core.paging``) and the serving
    runtime (``serving.ServingEngine``, ``launch.serve``).
  * ``plan_for_budget`` — greedy hot-set solver: pin the parameters with the
    highest bytes-used-per-inference resident until the MRAM budget is
    spent; everything else is paged from the cold scenario.

The old single-global-scenario API survives as ``PlacementPlan.uniform``
and as transparent acceptance of the legacy ``{"scenario", "mode", "bits"}``
engine dicts (``as_plan`` / ``linear_dispatch``).

Path conventions: paths are full flattened store keys — the stacked LM
tree uses ``layers/attn/wq`` (one entry per parameter *group*; the scan
executes every depth with the same placement), per-layer flat stores use
``layer03/mlp/w_down``.  Executable call sites pass the same canonical
store path, so exact-path rules (e.g. from :func:`plan_for_budget`) match
dispatch and accounting identically.  A pattern matches a path if it
glob-matches the full path or a ``/``-boundary suffix of it, so
hand-written rules can stay short (``attn/wq``, ``mlp/*``).
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.core.weight_store import SIRACUSA_MRAM_BYTES, WeightStore

# The four NVM integration scenarios (paper §IV, Fig 9), loosest->tightest
# coupling.  THE single definition site for both the analytical and the
# executable stack.
SCENARIOS = ("l3flash", "l3mram", "l2mram", "l1mram")

RESIDENCIES = ("resident", "paged")


@dataclasses.dataclass(frozen=True)
class ScenarioCost:
    """Per-byte weight-path costs for one integration scenario (filled in
    by ``memsys.scenario_costs`` from the calibrated bandwidth/energy
    constants; the dataclass lives here so the scenario *vocabulary* has a
    single home)."""
    name: str
    # bandwidth of the ingress stage feeding weights toward L2/L1
    weight_bw_Bps: float
    # energy per weight byte end-to-end (all hops)
    weight_energy_per_B: float
    # does the weight path steal L1 bandwidth from activations?
    weights_through_l1: bool
    # how many times each weight byte crosses the shared cluster port
    # (L3 scenarios store+load through L2 = 2; L2MRAM = 1; L1MRAM = 0)
    shared_port_crossings: int


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where one parameter lives: integration scenario, packed precision,
    whether it is MRAM-resident or paged from background memory, and — for
    paged parameters — the *page encoding*: the precision its bytes cross
    the host->device link at.

    ``page_bits=None`` (the ``"fp"`` encoding) streams the packed device
    buffers verbatim — bit-exact by construction, today's behaviour.
    ``page_bits=N`` declares the page logically holds fp weights shipped
    at N bits: when N equals ``weight_bits`` the wire form *is* the device
    form (handed straight to the quantized matmul, still bit-exact); when
    N differs the page is re-encoded with per-block scales
    (``core.quantize.quantize_blockwise``) and dequantized into the packed
    device buffer at fetch (lossy second quantization)."""

    scenario: str = "l1mram"
    weight_bits: int = 8
    residency: str = "resident"
    page_bits: Optional[int] = None

    def __post_init__(self):
        if self.scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; "
                             f"expected one of {SCENARIOS}")
        if self.residency not in RESIDENCIES:
            raise ValueError(f"unknown residency {self.residency!r}; "
                             f"expected one of {RESIDENCIES}")
        if self.weight_bits not in (2, 4, 8):
            raise ValueError(f"weight_bits must be 2/4/8, got "
                             f"{self.weight_bits}")
        if self.page_bits is not None and self.page_bits not in (2, 4, 8):
            raise ValueError(f"page_bits must be None or 2/4/8, got "
                             f"{self.page_bits}")

    @property
    def paged(self) -> bool:
        return self.residency == "paged"

    @property
    def page_encoding(self) -> str:
        """Wire encoding name derived from ``page_bits``: ``"fp"`` (stream
        the device form verbatim) or ``"int8"``/``"int4"``/``"int2"``."""
        return "fp" if self.page_bits is None else f"int{self.page_bits}"


# Canonical hot/cold placements for budget planning: hot weights stream
# over the dedicated At-MRAM port; cold weights page in from off-chip
# flash (§II-B2).
HOT = Placement("l1mram", 8, "resident")
COLD = Placement("l3flash", 8, "paged")


def _match(path: str, pattern: str) -> bool:
    """Glob match helper honouring the path conventions above."""
    return (fnmatch.fnmatchcase(path, pattern)
            or fnmatch.fnmatchcase(path, "*/" + pattern))


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Parameter path -> :class:`Placement`, first-matching-rule-wins.

    Frozen and hashable so it can be closed over inside jit'd model code
    exactly like the legacy engine dict.  ``mode`` is the kernel mode
    (pallas | interpret | xla) shared by every dispatch under the plan.
    """

    default: Placement = Placement()
    rules: Tuple[Tuple[str, Placement], ...] = ()
    mode: str = "xla"
    # serve int8-encoded cold pages straight from their wire form (packed
    # blockwise levels + per-block scales) via the blockscale matmul
    # kernel, skipping the host-side fetch decode; see wire_served_bits
    wire_serve: bool = False

    # -- construction -------------------------------------------------------
    @classmethod
    def uniform(cls, scenario: str = "l1mram", bits: int = 8,
                mode: str = "xla", residency: str = "resident"
                ) -> "PlacementPlan":
        """The legacy one-global-scenario API as a thin constructor."""
        return cls(default=Placement(scenario, bits, residency), mode=mode)

    def with_rule(self, pattern: str, placement: Placement) -> "PlacementPlan":
        """Return a copy with ``pattern -> placement`` appended (rules are
        evaluated in order, so earlier rules take precedence)."""
        return dataclasses.replace(self, rules=self.rules + ((pattern,
                                                              placement),))

    def replace(self, **kw) -> "PlacementPlan":
        return dataclasses.replace(self, **kw)

    def with_page_bits(self, page_bits: Optional[int]) -> "PlacementPlan":
        """Return a copy whose *paged* placements (default and rules) carry
        ``page_bits`` as their wire encoding; resident placements are left
        untouched (nothing of theirs crosses the link at serve time)."""
        def _enc(p: Placement) -> Placement:
            if not p.paged:
                return p
            return dataclasses.replace(p, page_bits=page_bits)
        return dataclasses.replace(
            self, default=_enc(self.default),
            rules=tuple((pat, _enc(p)) for pat, p in self.rules))

    # -- lookup -------------------------------------------------------------
    def placement_for(self, path: Optional[str]) -> Placement:
        if path is not None:
            for pattern, placement in self.rules:
                if _match(path, pattern):
                    return placement
        return self.default

    def scenario_for(self, path: Optional[str]) -> str:
        return self.placement_for(path).scenario

    def bits_for(self, path: Optional[str]) -> int:
        return self.placement_for(path).weight_bits

    @property
    def is_uniform(self) -> bool:
        return not self.rules

    def scenarios_used(self) -> Tuple[str, ...]:
        """Scenarios the plan can dispatch to, in SCENARIOS order."""
        used = {self.default.scenario} | {p.scenario for _, p in self.rules}
        return tuple(s for s in SCENARIOS if s in used)

    # -- store accounting ---------------------------------------------------
    def split_names(self, names: Sequence[str]
                    ) -> Tuple[List[str], List[str]]:
        """Partition parameter paths into (resident, paged), order kept."""
        resident, paged = [], []
        for n in names:
            (paged if self.placement_for(n).paged else resident).append(n)
        return resident, paged

    def resident_bytes(self, store: "StoreSizes") -> int:
        sizes = _sizes_of(store)
        resident, _ = self.split_names(list(sizes))
        return sum(sizes[n] for n in resident)

    def paged_bytes(self, store: "StoreSizes") -> int:
        sizes = _sizes_of(store)
        _, paged = self.split_names(list(sizes))
        return sum(sizes[n] for n in paged)

    def fits(self, store: "StoreSizes",
             budget_bytes: int = SIRACUSA_MRAM_BYTES) -> bool:
        return self.resident_bytes(store) <= budget_bytes

    def summary(self, store: Optional["StoreSizes"] = None) -> str:
        lines = [f"PlacementPlan(mode={self.mode}, default="
                 f"{self.default.scenario}/{self.default.weight_bits}b/"
                 f"{self.default.residency}, {len(self.rules)} rules)"]
        for pattern, p in self.rules:
            lines.append(f"  {pattern} -> {p.scenario}/{p.weight_bits}b/"
                         f"{p.residency}")
        if store is not None:
            lines.append(f"  resident {self.resident_bytes(store)} B, "
                         f"paged {self.paged_bytes(store)} B")
        return "\n".join(lines)


DEFAULT_PLAN = PlacementPlan()

# Anything that names parameter sizes: a packed WeightStore or a plain
# {path: nbytes} mapping (e.g. packed-leaf sizes of a serving tree, or the
# analytical per-layer weight bytes).
StoreSizes = Union[WeightStore, Mapping[str, int]]


def _sizes_of(store: StoreSizes) -> Dict[str, int]:
    if isinstance(store, WeightStore):
        return {n: p.nbytes_packed for n, p in store.params.items()}
    return dict(store)


# ---------------------------------------------------------------------------
# Legacy-engine interop: every model entry point threads an ``engine``
# object; historically an untyped {"scenario", "mode", "bits"} dict
# (optionally carrying "dp_axes" sharding hints for training).  These
# helpers let a PlacementPlan, an EngineConfig, a legacy dict, or None all
# flow through the same parameter.
# ---------------------------------------------------------------------------

def as_plan(engine: Any) -> PlacementPlan:
    """Normalize any engine-ish object into a PlacementPlan."""
    if engine is None:
        return DEFAULT_PLAN
    if isinstance(engine, PlacementPlan):
        return engine
    if isinstance(engine, Mapping):
        return PlacementPlan.uniform(
            scenario=engine.get("scenario", "l1mram"),
            bits=int(engine.get("bits", 8)),
            mode=engine.get("mode", "xla"))
    plan = getattr(engine, "plan", None)           # EngineConfig
    if isinstance(plan, PlacementPlan):
        return plan
    if hasattr(engine, "scenario"):
        return PlacementPlan.uniform(
            scenario=engine.scenario,
            bits=int(getattr(engine, "weight_bits", 8)),
            mode=getattr(engine, "mode", "xla"))
    raise TypeError(f"cannot interpret {type(engine).__name__} as a "
                    "placement plan")


def linear_dispatch(engine: Any, path: Optional[str]
                    ) -> Tuple[str, str, int]:
    """(scenario, mode, bits) for one linear call site.

    Legacy dicts keep their global answer; plans answer per path.
    """
    if isinstance(engine, Mapping):               # legacy fast path
        return (engine.get("scenario", "l1mram"),
                engine.get("mode", "xla"),
                int(engine.get("bits", 8)))
    plan = as_plan(engine)
    p = plan.placement_for(path)
    return p.scenario, plan.mode, p.weight_bits


def wire_served_bits(engine: Any, path: Optional[str]) -> Optional[int]:
    """Wire bits when this param is served straight from its page wire
    form, else None.

    The single source of truth for the wire-serve fast path: the paged
    store uses it to decide which fetched params skip the host decode
    (device_put the wire buffers), and :func:`repro.models.layers.linear`
    uses it to dispatch those params to the blockscale matmul.  Both
    sides MUST agree, so the predicate lives here: the plan opted in
    (``wire_serve=True``), the param is paged through the ``l1mram``
    linear path, and its wire encoding is a *re-encoded* int8 (an
    identity encoding has nothing to skip; int2/int4 stay on the host
    decode until the blockscale kernel path earns their tolerance)."""
    if isinstance(engine, Mapping) or engine is None:
        return None
    plan = as_plan(engine)
    if not getattr(plan, "wire_serve", False):
        return None
    p = plan.placement_for(path)
    if (p.paged and p.scenario == "l1mram" and p.page_bits == 8
            and p.page_bits != p.weight_bits):
        return p.page_bits
    return None


def dp_axes_of(engine: Any) -> Tuple[str, ...]:
    """Data-parallel sharding axes threaded alongside the engine (training
    path).  Placement plans carry none; legacy dicts may."""
    if isinstance(engine, Mapping):
        return tuple(engine.get("dp_axes") or ())
    return ()


def path_key(path: Sequence[Any]) -> str:
    """Canonical flat path string for a jax tree_flatten_with_path entry —
    the vocabulary PlacementPlan rules match against."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def packed_sizes(tree: Any,
                 shard_factors: Optional[Mapping[str, int]] = None
                 ) -> Dict[str, int]:
    """{param path: packed bytes} for every packed leaf of a serving tree
    (the {"packed", "scale"} dicts produced by freeze_for_serving) — the
    exact dispatch surface to feed :func:`plan_for_budget`.

    ``shard_factors`` ({name: n_shards}, e.g. from
    :func:`repro.core.paging.store_shard_axes`) divides a tensor-sharded
    param's bytes by its shard count, yielding the PER-DEVICE footprint
    a mesh-sharded pager actually pays per link."""
    import jax

    sizes: Dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = path_key(path)
        if key.endswith("/packed"):
            sizes[key[:-len("/packed")]] = int(leaf.size)
    if shard_factors:
        for name, factor in shard_factors.items():
            if name in sizes and factor > 1:
                sizes[name] = max(1, -(-sizes[name] // factor))
    return sizes


# ---------------------------------------------------------------------------
# Greedy hot-set budget solver (§II-B2 against the 4 MiB MRAM).
# ---------------------------------------------------------------------------

def plan_for_budget(store: StoreSizes,
                    budget_bytes: int = SIRACUSA_MRAM_BYTES, *,
                    uses: Optional[Mapping[str, float]] = None,
                    hot: Placement = HOT, cold: Placement = COLD,
                    mode: str = "xla", sizes_bits: int = 8,
                    shard_factors: Optional[Mapping[str, int]] = None
                    ) -> PlacementPlan:
    """Pin the highest bytes-used-per-inference parameters resident.

    ``store`` is a WeightStore (sizes = packed bytes) or a plain
    {name: nbytes} mapping (e.g. analytical layer weight bytes).  ``uses``
    optionally weights each parameter by how many times its bytes cross the
    weight port per inference (default 1).

    Byte accounting is bits-aware: ``sizes`` are taken to be measured at
    ``sizes_bits`` per weight (8 for the usual uint8-packed serving tree;
    a WeightStore carries per-param bits and overrides this).  The budget
    is charged the *resident* footprint at ``hot.weight_bits`` — an int4
    hot set at fp/int8 sizes used to over-reserve 2x — while the greedy
    score is the *wire* traffic a resident slot saves: the param's bytes
    at the cold placement's page encoding (``cold.page_bits`` falling back
    to ``cold.weight_bits``) times ``uses``.  Ties on equal score break
    deterministically by (larger size first, then name), so equal-score
    plans are stable across dict orderings.

    ``shard_factors`` ({name: n_shards}) marks params a device mesh
    tensor-shards: each device holds (and pins) only ``1/n`` of the
    param, so its RESIDENT charge against the per-device budget is
    divided by the shard count.  Replicated params (absent, or factor 1)
    charge full bytes on every device, exactly as before.  Without this
    a tight per-device budget over-evicts on meshes — sharded params
    were billed N-fold.

    Returns a plan whose rules pin the chosen hot set (exact-path rules,
    ``hot`` placement) and whose default is ``cold`` for everything else.
    """
    sizes = _sizes_of(store)
    uses = uses or {}
    shard_factors = shard_factors or {}
    bits_of = {n: p.bits for n, p in store.params.items()} \
        if isinstance(store, WeightStore) else {}

    def _at_bits(name: str, bits: int) -> int:
        """``sizes[name]`` rescaled from its measured bits to ``bits``."""
        have = bits_of.get(name, sizes_bits)
        return max(1, -(-sizes[name] * bits // have))

    def _resident(name: str) -> int:
        """Per-device resident charge: sharded params pin 1/n per link."""
        factor = int(shard_factors.get(name, 1))
        nb = _at_bits(name, hot.weight_bits)
        return max(1, -(-nb // factor)) if factor > 1 else nb

    wire_bits = cold.page_bits or cold.weight_bits

    def score(name: str) -> float:
        return _at_bits(name, wire_bits) * float(uses.get(name, 1.0))

    order = sorted(sizes, key=lambda n: (-score(n), -sizes[n], n))
    rules: List[Tuple[str, Placement]] = []
    used = 0
    for name in order:
        resident_nb = _resident(name)
        if used + resident_nb <= budget_bytes:
            rules.append((name, hot))
            used += resident_nb
    return PlacementPlan(default=cold, rules=tuple(rules), mode=mode)


# ---------------------------------------------------------------------------
# Freeze-policy bridge: drive WeightStore.freeze precision from a plan.
# ---------------------------------------------------------------------------

def freeze_policy(plan: PlacementPlan, min_size: int = 1024):
    """A ``weight_store.freeze`` policy taking per-param bits from ``plan``
    (>=2-D matmul-like leaves only, like the default policy)."""
    def _policy(path: str, leaf) -> Optional[int]:
        if leaf.ndim >= 2 and leaf.size >= min_size:
            return plan.bits_for(path)
        return None
    return _policy

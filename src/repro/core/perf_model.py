"""Network walks for the scenario study (paper §IV, Figs 10-11).

Provides the MobileNet-V2-1.0-224 job list exactly as it maps onto
N-EUREKA's three operators, and the end-to-end latency/energy walk for the
four NVM integration scenarios.  Calibration targets (paper):

    L3FLASH : 12.6 ms / 3.8 mJ   (off-chip share of energy ~ 55 %)
    L3MRAM  : ~0.8x latency of L3FLASH, ~0.5x energy
    L2MRAM  : 1.2x faster than L3MRAM, energy ~ L3MRAM
    L1MRAM  :  7.3 ms / 1.4 mJ   (1.7x / 3x vs L3FLASH)
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.memsys import (LayerShape, LayerTiming, NOMINAL, LOW_POWER,
                               OperatingPoint, network_walk, SCENARIOS)
from repro.core.placement import (HOT, COLD, Placement, PlacementPlan,
                                  plan_for_budget)

# MobileNet-V2 inverted-residual stack: (expansion t, cout, repeats n, stride s)
_MNV2_BLOCKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2_jobs(weight_bits: int = 8, img: int = 224) -> List[LayerShape]:
    """MobileNet-V2-1.0 as a sequence of N-EUREKA jobs (HWC, 8-bit act)."""
    jobs: List[LayerShape] = []
    h = w = img // 2
    jobs.append(LayerShape("conv0", "dense3x3", img, img, 3, 32, stride=2,
                           weight_bits=weight_bits))
    cin = 32
    bi = 0
    for t, c, n, s in _MNV2_BLOCKS:
        for r in range(n):
            stride = s if r == 0 else 1
            hid = cin * t
            tag = f"b{bi}"
            if t != 1:
                jobs.append(LayerShape(f"{tag}.pw_exp", "pw1x1", h, w, cin,
                                       hid, weight_bits=weight_bits))
            jobs.append(LayerShape(f"{tag}.dw", "dw3x3", h, w, hid, hid,
                                   stride=stride, weight_bits=weight_bits))
            if stride == 2:
                h, w = -(-h // 2), -(-w // 2)
            jobs.append(LayerShape(f"{tag}.pw_proj", "pw1x1", h, w, hid, c,
                                   weight_bits=weight_bits))
            cin = c
            bi += 1
    jobs.append(LayerShape("conv_last", "pw1x1", h, w, cin, 1280,
                           weight_bits=weight_bits))
    jobs.append(LayerShape("fc", "pw1x1", 1, 1, 1280, 1000,
                           weight_bits=weight_bits))
    return jobs


def mnv2_scenario_table(op: OperatingPoint = NOMINAL,
                        weight_bits: int = 8) -> dict:
    """{scenario: (latency_s, energy_j, [LayerTiming])} — reproduces Fig 10."""
    jobs = mobilenet_v2_jobs(weight_bits)
    return {s: network_walk(jobs, s, op) for s in SCENARIOS}


def mnv2_budget_plan(budget_bytes: int = 2 * 1024 * 1024,
                     weight_bits: int = 8,
                     hot: Placement = HOT,
                     cold: Placement = COLD) -> PlacementPlan:
    """A mixed placement for MobileNet-V2: greedily pin the layers with the
    highest weight-bytes-per-inference into the At-MRAM budget; everything
    else pages from the cold scenario (§II-B2 against a tightened budget —
    at the paper's 4 MiB the full 8-bit network is resident, so the mixed
    case is exercised with a smaller budget or fatter weights)."""
    jobs = mobilenet_v2_jobs(weight_bits)
    sizes = {j.name: j.weight_bytes for j in jobs}
    return plan_for_budget(sizes, budget_bytes, hot=hot, cold=cold,
                           sizes_bits=weight_bits)


def mnv2_plan_walk(plan: PlacementPlan, op: OperatingPoint = NOMINAL,
                   weight_bits: int = 8
                   ) -> Tuple[float, float, List[LayerTiming]]:
    """Latency/energy of MobileNet-V2 under a mixed placement plan."""
    return network_walk(mobilenet_v2_jobs(weight_bits), plan, op)


def mnv2_total_macs() -> int:
    return sum(j.macs for j in mobilenet_v2_jobs())


def mnv2_weight_bytes(weight_bits: int = 8) -> int:
    return sum(j.weight_bytes for j in mobilenet_v2_jobs(weight_bits))

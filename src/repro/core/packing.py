"""Sub-byte weight packing — the MRAM density model.

Siracusa's MRAM stores DNN weights at 2-8 bit precision, packed into 256-bit
rows that the weight streamer reads one per (MRAM) cycle.  On TPU the same
idea is "packed sub-byte weights in HBM": int2/int4 levels are packed 4x/2x
per int8 byte so that HBM traffic (the memory roofline term) scales with the
weight bit-width — the TPU-native equivalent of bit-serial cycle scaling.

Layout: little-endian within a byte; packing runs along the *last* axis
(the reduction axis for matmuls), which is the axis the streaming kernels
consume contiguously — exactly like the MRAM's "long streams of adjacent
addresses" (paper §II-C4).  The packed axis is padded to a multiple of the
packing factor.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

SUPPORTED_BITS = (2, 4, 8)

# One MRAM row in Siracusa = 256 bits; used by the memsys model to count
# row reads, and by the kernels to keep block shapes row-aligned.
MRAM_ROW_BITS = 256


def packing_factor(bits: int) -> int:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"packing supports bits in {SUPPORTED_BITS}, got {bits}")
    return 8 // bits


def packed_last_dim(n: int, bits: int) -> int:
    f = packing_factor(bits)
    return (n + f - 1) // f


def _to_unsigned(levels: jax.Array, bits: int) -> jax.Array:
    """Map signed levels [-2^(b-1), 2^(b-1)-1] -> unsigned field [0, 2^b-1]."""
    return (levels.astype(jnp.int32) + (1 << (bits - 1))).astype(jnp.uint8)


def _to_signed(field: jax.Array, bits: int) -> jax.Array:
    return (field.astype(jnp.int32) - (1 << (bits - 1))).astype(jnp.int8)


def pack(levels: jax.Array, bits: int) -> jax.Array:
    """Pack signed integer levels (int8 storage) into a uint8 carrier.

    levels: (..., K) int8 with values in the signed `bits` range.
    returns: (..., ceil(K / (8//bits))) uint8.
    """
    f = packing_factor(bits)
    if f == 1:
        # 8-bit: reinterpret sign bit into unsigned carrier for uniformity.
        return _to_unsigned(levels, 8)
    *lead, k = levels.shape
    pad = (-k) % f
    if pad:
        levels = jnp.pad(levels, [(0, 0)] * len(lead) + [(0, pad)])
    u = _to_unsigned(levels, bits).reshape(*lead, (k + pad) // f, f)
    shifts = (jnp.arange(f, dtype=jnp.uint8) * bits).astype(jnp.uint8)
    packed = jnp.sum(
        (u.astype(jnp.uint32) << shifts.astype(jnp.uint32)), axis=-1
    ).astype(jnp.uint8)
    return packed


def unpack(packed: jax.Array, bits: int, orig_k: int) -> jax.Array:
    """Inverse of :func:`pack` — returns int8 signed levels of length orig_k."""
    f = packing_factor(bits)
    if f == 1:
        return _to_signed(packed, 8)[..., :orig_k]
    shifts = (jnp.arange(f, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    fields = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    levels = _to_signed(fields, bits)
    *lead, kp, _ = levels.shape
    return levels.reshape(*lead, kp * f)[..., :orig_k]


def packed_nbytes(shape: Tuple[int, ...], bits: int) -> int:
    """Bytes occupied by a packed tensor of the given *unpacked* shape."""
    *lead, k = shape
    n = int(np.prod(lead)) if lead else 1
    return n * packed_last_dim(k, bits)


def mram_rows(shape: Tuple[int, ...], bits: int) -> int:
    """Number of 256-bit MRAM rows the tensor occupies (memsys accounting)."""
    return -(-packed_nbytes(shape, bits) * 8 // MRAM_ROW_BITS)


# ---------------------------------------------------------------------------
# Bit-plane layout (the bit-serial view).  N-EUREKA fetches weights one bit
# plane at a time in the 3x3 modes; the memsys cycle model charges
# `bits` planes per weight block.  We provide the plane decomposition both
# as documentation of the mechanism and as an alternative kernel layout.
# ---------------------------------------------------------------------------

def to_bitplanes(levels: jax.Array, bits: int) -> jax.Array:
    """Decompose signed levels into `bits` binary planes (offset-binary).

    Returns uint8 array (bits, ...) with plane b = bit b of the unsigned
    offset-binary encoding;  levels = sum_b plane_b * 2^b - 2^(bits-1).
    """
    u = _to_unsigned(levels, bits).astype(jnp.uint8)
    planes = [(u >> b) & 1 for b in range(bits)]
    return jnp.stack(planes, axis=0)


def from_bitplanes(planes: jax.Array, bits: int) -> jax.Array:
    weights = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(
        (bits,) + (1,) * (planes.ndim - 1))
    u = jnp.sum(planes.astype(jnp.int32) * weights, axis=0)
    return (u - (1 << (bits - 1))).astype(jnp.int8)

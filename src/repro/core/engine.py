"""NeuralEngine — the heterogeneous-cluster dispatch (paper §II-A).

Siracusa pairs N-EUREKA (quantized conv engine) with 8 RISC-V DSP cores in
one cluster sharing L1.  The framework analogue: every compute site declares
an *engine*:

  "neureka" — quantized path: packed weights (WeightStore), fused dequant
              kernels, scenario-selectable weight placement.
  "dsp"     — float path: plain XLA ops (norms, softmax, rotary, SSM scans,
              anything the quantized engine doesn't cover).

Both paths read/write the same activation arrays with no layout conversion
(zero-copy collaboration).  EngineConfig is threaded through the model zoo;
the dry-run uses mode="xla" so GSPMD sees plain HLO, tests use
mode="interpret" to execute the real Pallas kernel bodies.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import scenarios
from repro.core.placement import PlacementPlan
from repro.core.weight_store import PackedParam


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    engine: str = "dsp"           # "neureka" | "dsp"
    scenario: str = "l1mram"      # weight placement for the neureka path
    mode: str = "xla"             # kernel mode: pallas | interpret | xla
    weight_bits: int = 8          # default packing precision
    # optional per-parameter placement; overrides `scenario` when set so a
    # single model can mix integration points (hot At-MRAM, cold paged)
    plan: Optional[PlacementPlan] = None

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_plan(cls, plan: PlacementPlan, engine: str = "neureka"
                  ) -> "EngineConfig":
        return cls(engine=engine, scenario=plan.default.scenario,
                   mode=plan.mode, weight_bits=plan.default.weight_bits,
                   plan=plan)

    def scenario_for(self, path: Optional[str]) -> str:
        if self.plan is not None:
            return self.plan.scenario_for(path)
        return self.scenario


DSP = EngineConfig(engine="dsp")
NEUREKA = EngineConfig(engine="neureka")


def linear(x: jax.Array, w, cfg: EngineConfig, *, path: Optional[str] = None,
           out_dtype=None) -> jax.Array:
    """y = x @ W^T.  ``w`` is a PackedParam (neureka) or a dense (N, K) array
    (dsp).  Dense weights passed to a neureka engine raise — the packed
    store is the only weight source the accelerator reads (MRAM semantics).

    ``path`` is the parameter's placement path; when ``cfg.plan`` is set the
    scenario is resolved per parameter instead of globally.
    """
    if isinstance(w, PackedParam):
        return scenarios.linear_apply(x, w, scenario=cfg.scenario_for(path),
                                      mode=cfg.mode, out_dtype=out_dtype)
    if cfg.engine == "neureka":
        raise TypeError("neureka engine requires packed weights "
                        "(freeze the params into a WeightStore first)")
    out = jnp.matmul(x, w.T)
    return out.astype(out_dtype) if out_dtype is not None else out

"""Packed read-only weight store — the MRAM analogue.

Siracusa dedicates a 4 MiB non-volatile MRAM to DNN weights: written once at
deployment, then *read-only* at runtime, streamed to the accelerator over a
dedicated port.  The TPU-native analogue implemented here:

  * ``freeze`` converts a float param pytree into a store of packed sub-byte
    quantized tensors (+ per-channel scales).  This happens once, offline —
    the "MRAM programming" step.
  * At runtime the store is an immutable pytree of device arrays; the fused
    dequant-matmul kernels stream the packed bytes HBM->VMEM and expand them
    at the compute unit (see kernels/qmatmul.py).
  * ``capacity accounting`` mirrors the 4 MiB budget: a store reports its
    packed footprint, and `repro.core.paging` splits stores larger than the
    configured resident budget into pages streamed from "background memory"
    (host / off-accelerator), reproducing §II-B2's virtual paging.

The store is a flat dict keyed by parameter path; leaves are
``PackedParam`` pytrees so the whole store can be passed through jit/pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, quantize

# The paper's MRAM capacity; default resident budget for paging decisions.
SIRACUSA_MRAM_BYTES = 4 * 1024 * 1024
SIRACUSA_TILE_SRAM_BYTES = 4 * 1024 * 1024


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedParam:
    """One packed weight matrix + its dequant metadata (a jit-safe pytree)."""

    packed: jax.Array                 # (..., K_packed) uint8 carrier
    scale: jax.Array                  # (out_channels,) float32
    bits: int = dataclasses.field(metadata=dict(static=True))
    orig_shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes_packed(self) -> int:
        return int(np.prod(self.packed.shape))

    @property
    def nbytes_dense_bf16(self) -> int:
        return int(np.prod(self.orig_shape)) * 2

    def unpack_levels(self) -> jax.Array:
        """Materialize int8 levels (reference / non-fused paths)."""
        return packing.unpack(self.packed, self.bits, self.orig_shape[-1])

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        lv = self.unpack_levels().astype(dtype)
        scale = self.scale.astype(dtype).reshape(
            (-1,) + (1,) * (len(self.orig_shape) - 1))
        return lv * scale


def pack_param(w: jax.Array, bits: int, channel_axis: int = 0) -> PackedParam:
    qt = quantize.quantize_weights(w, bits, channel_axis=channel_axis)
    return PackedParam(
        packed=packing.pack(qt.values, bits),
        scale=qt.scale,
        bits=bits,
        orig_shape=tuple(qt.values.shape),
    )


@dataclasses.dataclass
class WeightStore:
    """Immutable packed store over a parameter pytree.

    ``params`` maps flat path -> PackedParam for quantized ("MRAM") leaves;
    ``passthrough`` holds the leaves kept at full precision (norms, biases,
    embeddings if so configured) — on Siracusa these live in SRAM.
    """

    params: Dict[str, PackedParam]
    passthrough: Dict[str, jax.Array]

    # -- capacity accounting ------------------------------------------------
    @property
    def packed_bytes(self) -> int:
        return sum(p.nbytes_packed for p in self.params.values())

    @property
    def passthrough_bytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.passthrough.values())

    @property
    def dense_equivalent_bytes(self) -> int:
        """What the same weights would occupy unquantized (bf16)."""
        return (sum(p.nbytes_dense_bf16 for p in self.params.values())
                + self.passthrough_bytes)

    def density_gain(self) -> float:
        """MRAM-style density advantage of the packed store (>= 1)."""
        denom = max(self.packed_bytes + self.passthrough_bytes, 1)
        return self.dense_equivalent_bytes / denom

    def fits(self, budget_bytes: int = SIRACUSA_MRAM_BYTES) -> bool:
        return self.packed_bytes <= budget_bytes

    # -- materialization ----------------------------------------------------
    def dequantized_params(self, dtype=jnp.float32) -> Dict[str, jax.Array]:
        out = {k: p.dequantize(dtype) for k, p in self.params.items()}
        out.update(self.passthrough)
        return out


def _flatten_with_paths(tree: Any) -> Dict[str, jax.Array]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


# Heuristic used when no explicit policy is given: quantize every >=2-D
# matmul-like weight; keep vectors (norm scales, biases) at full precision.
def default_policy(path: str, leaf: jax.Array) -> Optional[int]:
    if leaf.ndim >= 2 and leaf.size >= 1024:
        return 8
    return None


def freeze(params: Any,
           policy: Callable[[str, jax.Array], Optional[int]] = default_policy,
           channel_axis: int = 0) -> WeightStore:
    """Offline "MRAM programming": quantize+pack a trained param pytree.

    ``policy(path, leaf)`` returns the weight bit-width (2/4/8) or None to
    keep the leaf at full precision.
    """
    flat = _flatten_with_paths(params)
    packed: Dict[str, PackedParam] = {}
    passthrough: Dict[str, jax.Array] = {}
    for path, leaf in flat.items():
        bits = policy(path, leaf)
        if bits is None:
            passthrough[path] = leaf
        else:
            packed[path] = pack_param(leaf, bits, channel_axis=channel_axis)
    return WeightStore(params=packed, passthrough=passthrough)


def uniform_policy(bits: int, min_size: int = 1024):
    def _policy(path: str, leaf: jax.Array) -> Optional[int]:
        if leaf.ndim >= 2 and leaf.size >= min_size:
            return bits
        return None
    return _policy

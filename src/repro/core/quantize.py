"""NEMO-style integer quantization (Conti, arXiv:2004.05930).

Siracusa's N-EUREKA requantizes with a per-output-channel affine projection
in the *integer* domain:

    y_q = clip( (acc_32b * scale + bias) >> shift , 0, 255 )   (8-bit output)

Weights are quantized symmetric per-output-channel to ``bits`` ∈ [2, 8];
activations are quantized asymmetric uint8 (the engine consumes 8-bit
activations).  This module provides:

  * weight quantization  (float -> int levels + per-channel scale)
  * activation quantization (float -> uint8 + scale/zero-point)
  * the integer requant projection used by the kernels, and its parameters
    folded from (w_scale, in_scale, out_scale, float bias)
  * fake-quant (straight-through) versions for QAT

All functions are pure-jnp and jit-safe.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Number of fractional bits used when folding float rescale factors into the
# integer (mult, shift) pair.  24 bits keeps requant error < 2^-16 relative.
REQUANT_SHIFT_BITS = 24


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A symmetric per-channel quantized weight tensor.

    ``values`` holds signed integer *levels* stored in int8 (even when
    bits < 8 — packing to sub-byte storage is `repro.core.packing`'s job).
    ``scale`` has one entry per output channel (axis 0 after normalization).
    """

    values: jax.Array          # int8 levels, same shape as the fp tensor
    scale: jax.Array           # (out_channels,) float32
    bits: int

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self) -> jax.Array:
        scale = self.scale.reshape((-1,) + (1,) * (self.values.ndim - 1))
        return self.values.astype(jnp.float32) * scale


def weight_qrange(bits: int) -> Tuple[int, int]:
    """Symmetric signed range for a given bit-width (e.g. 4 -> [-8, 7])."""
    if not 2 <= bits <= 8:
        raise ValueError(f"weight bits must be in [2, 8], got {bits}")
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def quantize_weights(w: jax.Array, bits: int, channel_axis: int = 0) -> QuantizedTensor:
    """Symmetric per-channel weight quantization to ``bits`` levels.

    The channel axis is moved to the front so downstream code can always
    treat axis 0 as the per-channel (= per-requant-parameter) axis.
    """
    qmin, qmax = weight_qrange(bits)
    w = jnp.moveaxis(w, channel_axis, 0)
    flat = w.reshape(w.shape[0], -1)
    absmax = jnp.max(jnp.abs(flat), axis=1)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(flat / scale[:, None]), qmin, qmax).astype(jnp.int8)
    return QuantizedTensor(values=q.reshape(w.shape), scale=scale, bits=bits)


def quantize_activations(x: jax.Array, scale: jax.Array | float,
                         zero_point: jax.Array | int = 0) -> jax.Array:
    """Asymmetric uint8 activation quantization with a given scale/zp."""
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def calibrate_activation_scale(x: jax.Array, percentile: float = 100.0) -> Tuple[float, int]:
    """Pick (scale, zero_point) so that the observed range maps onto [0,255]."""
    lo = jnp.percentile(x, 100.0 - percentile)
    hi = jnp.percentile(x, percentile)
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, lo + 1e-8)
    scale = (hi - lo) / 255.0
    zp = jnp.clip(jnp.round(-lo / scale), 0, 255).astype(jnp.int32)
    return scale, zp


@dataclasses.dataclass(frozen=True)
class RequantParams:
    """Integer-domain requantization parameters (per output channel).

    y_uint8 = clip(((acc_int32 * mult) >> shift) + bias, 0, 255)

    ``mult`` is an int32 fixed-point multiplier, ``shift`` a global right
    shift (REQUANT_SHIFT_BITS), ``bias`` an int32 per-channel offset that
    already folds the float bias and the output zero-point.
    """

    mult: jax.Array    # (C,) int32
    bias: jax.Array    # (C,) int32
    shift: int


def fold_requant(w_scale: jax.Array, in_scale: jax.Array | float,
                 out_scale: jax.Array | float, bias_fp: jax.Array | None,
                 out_zero_point: int = 0) -> RequantParams:
    """Fold float scales into the NEMO integer (mult, shift, bias) triple.

    acc * (w_scale*in_scale/out_scale) + bias_fp/out_scale + zp
    """
    rescale = w_scale * in_scale / out_scale                     # (C,)
    mult = jnp.round(rescale * (1 << REQUANT_SHIFT_BITS)).astype(jnp.int32)
    if bias_fp is None:
        bias_fp = jnp.zeros_like(w_scale)
    bias = jnp.round(bias_fp / out_scale).astype(jnp.int32) + out_zero_point
    return RequantParams(mult=mult, bias=bias, shift=REQUANT_SHIFT_BITS)


def requantize(acc: jax.Array, rq: RequantParams) -> jax.Array:
    """Apply the requant projection: int32 accumulators -> uint8.

    Matches N-EUREKA's NORMQUANT unit (per-channel int multiplier, right
    shift with round-half-up, per-channel bias, clip to [0, 255]).  The
    48-bit intermediate of the silicon is emulated in float32 — exact to
    within 1 LSB of the full-integer result for |acc| < 2^24, which the
    int32 conv accumulators of the supported job shapes satisfy (TPUs have
    no int64 datapath; tests/test_quantize_packing.py bounds the error
    against a true-int64 oracle).
    """
    rescale = rq.mult.astype(jnp.float32) / jnp.float32(1 << rq.shift)
    y = jnp.floor(acc.astype(jnp.float32) * rescale + 0.5)
    y = y + rq.bias.astype(jnp.float32)
    return jnp.clip(y, 0, 255).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Fake-quant (QAT) — straight-through estimators so training can see the
# quantization grid the serving path will use.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant_weights(w: jax.Array, bits: int, channel_axis: int = 0) -> jax.Array:
    """Differentiable (STE) symmetric per-channel weight fake-quantization."""
    qmin, qmax = weight_qrange(bits)
    wm = jnp.moveaxis(w, channel_axis, 0)
    flat = wm.reshape(wm.shape[0], -1)
    absmax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(_ste_round(flat / scale), qmin, qmax) * scale
    return jnp.moveaxis(q.reshape(wm.shape), 0, channel_axis)


def int8_matmul_reference(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul in integer arithmetic (oracle helper)."""
    return jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def dequant_matmul_reference(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Float activations x quantized weights, computed at full precision."""
    w = qt.dequantize()          # (out, in)
    return jnp.matmul(x, w.T)


# ---------------------------------------------------------------------------
# Per-block wire codec — the page encoding of `repro.core.paging`.
#
# Cold pages cross the host->device link re-encoded at ``page_bits`` with
# one scale per (row, block) group instead of one per output channel: the
# finer scale granularity bounds the second-quantization error when a page
# is shipped below the plan's compute bits, and the scales travel inside
# the page payload (they are wire bytes, not a side channel).  These run
# host-side on numpy — the encode happens once when the host store is
# built, the decode on every fetch — so they are deliberately *not* jit
# functions.
# ---------------------------------------------------------------------------

# Default scale-group width (weights per scale) of the page codec.  32 keeps
# the scale overhead at 4/32 = 12.5% of an int8 payload while matching the
# N-EUREKA 32-weight fetch granule.
PAGE_SCALE_BLOCK = 32


def quantize_blockwise(w: np.ndarray, bits: int,
                       block: int = PAGE_SCALE_BLOCK
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-(row, block) quantization along the last axis.

    Returns ``(levels, scales)`` with ``levels`` int8 of ``w.shape`` and
    ``scales`` float32 ``(rows, ceil(k / block))``.  A trailing block
    shorter than ``block`` (k not a multiple of the group width) gets its
    own scale over just the tail elements.
    """
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    qmin, qmax = weight_qrange(bits)
    w = np.asarray(w, np.float32)
    if w.ndim != 2:
        raise ValueError(f"expected a 2-D (rows, k) tensor, got {w.shape}")
    rows, k = w.shape
    nblk = -(-k // block)
    wp = np.pad(w, ((0, 0), (0, nblk * block - k)))
    groups = wp.reshape(rows, nblk, block)
    absmax = np.abs(groups).max(axis=2)
    scales = np.where(absmax > 0, absmax / qmax, 1.0).astype(np.float32)
    q = np.clip(np.round(groups / scales[:, :, None]), qmin, qmax)
    levels = q.astype(np.int8).reshape(rows, nblk * block)[:, :k]
    return levels, scales


def dequantize_blockwise(levels: np.ndarray, scales: np.ndarray,
                         block: int = PAGE_SCALE_BLOCK) -> np.ndarray:
    """Inverse of :func:`quantize_blockwise`: levels x per-block scales."""
    levels = np.asarray(levels)
    rows, k = levels.shape
    nblk = scales.shape[1]
    lp = np.pad(levels.astype(np.float32), ((0, 0), (0, nblk * block - k)))
    out = lp.reshape(rows, nblk, block) * scales[:, :, None].astype(np.float32)
    return out.reshape(rows, nblk * block)[:, :k]

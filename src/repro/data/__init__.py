from repro.data.pipeline import SyntheticLMDataset, prefetch

__all__ = ["SyntheticLMDataset", "prefetch"]

"""Deterministic, restart-safe synthetic LM data pipeline.

Properties needed at scale and provided here:
  * **step-indexed determinism** — batch(step) is a pure function of
    (seed, step, host_id), so a restarted/elastically-resized job resumes
    mid-epoch with zero bookkeeping (no iterators to checkpoint);
  * **host sharding** — each host materializes only its slice of the
    global batch;
  * **prefetch** — a background thread keeps ``depth`` batches in flight
    (the IO-DMA double-buffering discipline of the paper, at the data tier).

The token stream is a Zipf-ish categorical over the vocab with
Markov structure, giving non-trivial learnable statistics for the
end-to-end examples while staying dependency-free and offline.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0,
                 family: str = "lm", d_model: int = 0, n_frames: int = 0,
                 n_patches: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id
        self.family = family
        self.d_model = d_model
        self.n_frames = n_frames
        self.n_patches = n_patches
        # fixed Markov mixing weights (learnable structure)
        base = np.random.default_rng(seed).normal(size=(64,))
        self._mix = base / np.linalg.norm(base)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.host_id)
        b, s = self.local_batch, self.seq
        # zipf-ish marginal + short-range structure: next token correlates
        # with (token % 64) of the previous one
        z = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        tokens = z % self.vocab
        shift = (tokens[:, :-1] % 64).astype(np.int64)
        tokens = (tokens[:, 1:] + shift) % self.vocab
        prev = np.concatenate([rng.integers(0, self.vocab, (b, 1)),
                               tokens[:, :-1]], axis=1)
        out: Dict[str, Any] = dict(tokens=prev.astype(np.int32),
                                   labels=tokens.astype(np.int32))
        if self.family == "encdec":
            out["frames"] = rng.normal(
                size=(b, self.n_frames, self.d_model)).astype(np.float32)
        if self.family == "vlm":
            out["patches"] = rng.normal(
                size=(b, self.n_patches, self.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def prefetch(dataset: SyntheticLMDataset, start_step: int = 0,
             depth: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetch of ``depth`` batches."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(dataset.batch(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()

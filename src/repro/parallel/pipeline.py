"""GPipe-style pipeline parallelism as a shard_map utility.

For depth-dominated models (falcon-mamba's 64 layers) a "stage" axis can
replace part of the model axis: layers are split into S contiguous stages,
microbatches flow stage-to-stage via ``jax.lax.ppermute``, and the classic
GPipe schedule (S + M - 1 ticks for M microbatches) overlaps compute with
the point-to-point transfers.  This module provides the schedule as a
reusable combinator + an analytical bubble model used by the perf log.

It is exercised by tests/test_pipeline.py on a small mesh; the assigned
production cells use DP x TP (+EP) which profiled better at these sizes
(see EXPERIMENTS.md §Perf notes), so PP stays an opt-in config knob.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble: (S-1) / (S-1+M)."""
    return (n_stages - 1) / (n_stages - 1 + n_microbatches)


def pipelined_apply(layer_fn: Callable[[jax.Array, Any], jax.Array],
                    mesh: Mesh, stage_axis: str, n_microbatches: int):
    """Build fn(x, stage_params) running a GPipe schedule over ``stage_axis``.

    ``layer_fn(x_mb, stage_params)`` applies ONE stage to one microbatch.
    x: (B, ...) with B % n_microbatches == 0; stage_params: pytree whose
    leaves carry a leading stage dim sharded over ``stage_axis``.
    """
    n_stages = mesh.shape[stage_axis]

    def stage_local(x, params):
        # x arrives already split: (M, B/M, ...) microbatches, replicated
        # copy on every stage; each stage computes only when its tick holds
        # a valid microbatch (GPipe staggering), then passes it along the
        # ring.  The LAST stage deposits finished microbatches into a
        # non-rotating accumulator, psum-broadcast at the end.
        idx = jax.lax.axis_index(stage_axis)
        m = n_microbatches
        total_ticks = n_stages + m - 1
        is_last = idx == n_stages - 1

        def tick(carry, t):
            buf, out_acc = carry            # buf rotates; out_acc stays put
            mb = t - idx                    # microbatch at this stage now
            valid = (mb >= 0) & (mb < m)
            mb_c = jnp.clip(mb, 0, m - 1)
            x_mb = jax.lax.dynamic_index_in_dim(buf, mb_c, 0, keepdims=False)
            y_mb = layer_fn(x_mb, params)
            y_mb = jnp.where(valid, y_mb, x_mb)
            buf = jax.lax.dynamic_update_index_in_dim(buf, y_mb, mb_c, 0)
            done = jnp.where(valid & is_last, y_mb,
                             jax.lax.dynamic_index_in_dim(out_acc, mb_c, 0,
                                                          keepdims=False))
            out_acc = jax.lax.dynamic_update_index_in_dim(out_acc, done,
                                                          mb_c, 0)
            # pass the freshly computed microbatch downstream
            buf = jax.lax.ppermute(
                buf, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, out_acc), None

        out0 = jnp.zeros_like(x)
        (_, out_acc), _ = jax.lax.scan(tick, (x, out0),
                                       jnp.arange(total_ticks))
        # only the last stage holds results; broadcast to every stage
        return jax.lax.psum(out_acc, stage_axis)

    def fn(x, stage_params):
        b = x.shape[0]
        assert b % n_microbatches == 0
        xm = x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])
        out = shard_map(
            stage_local, mesh=mesh,
            in_specs=(P(), P(stage_axis)),
            out_specs=P(),
            check_rep=False,
        )(xm, stage_params)
        return out.reshape(b, *x.shape[1:])

    return fn

"""Gradient compression for DP sync: int8 quantized all-reduce + error
feedback.

At 1000+ nodes the DP gradient all-reduce is the dominant cross-pod
collective; int8 compression cuts its bytes 4x (bf16) with error feedback
(residual accumulation) keeping convergence intact — the same
precision-for-bandwidth trade the paper makes for weights (2-8 b MRAM).

``compressed_psum`` is written against shard_map so the quantize /
all_reduce / dequantize pipeline is explicit per-shard; error feedback
state is carried by the caller like optimizer state.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(g: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map/pmap: int8-compress, psum, dequantize, average.

    The int8 payload is what crosses the interconnect; the psum of int32
    keeps exactness of the reduction given the shared scale bound
    (scale = max over participants, synced with a cheap f32 psum-max).
    """
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32)


def with_error_feedback(grads: Any, residual: Any, axis_name: str
                        ) -> Tuple[Any, Any]:
    """g' = compress(g + residual); residual' = (g + residual) - g'."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        out = compressed_allreduce_mean(x, axis_name)
        # residual tracks the *local* quantization error
        absmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
        scale = jnp.maximum(absmax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        return out.astype(g.dtype), x - q * scale

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, new_r


def init_residual(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

"""Sharding plans: logical rules -> NamedSharding per parameter/cache/input.

Plan (DESIGN.md §3), per mesh ("data", "model") or ("pod", "data", "model"):

  * batch dims            -> ("pod", "data")      (pure DP across pods)
  * weight out-features   -> "model"              (tensor parallel)
  * weight in-features    -> "data"               (FSDP / ZeRO-3)
  * MoE expert dim        -> "model" when divisible (EP), else the expert
                             hidden dim F -> "model" (TP-in-expert)
  * KV cache sequence     -> "model"              (sequence-parallel decode)
  * SSM channel dims      -> "model" (+"data" when divisible by both)
  * anything indivisible  -> replicated on that axis (rule checks divide)

Rules are *shape+path* based so the same planner covers every arch family
and both dense (train) and packed (serve) parameter trees.  Optimizer
states mirror their parameters (AdamW moments via tree_map; Adafactor's
factored vectors drop the packed last axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parameter leaves that get packed for At-MRAM serving.  Routers stay at
# full precision: they are tiny and routing decisions are quantization-
# sensitive (same reasoning as norm/bias params living in SRAM on-chip).
PACKABLE = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
            "in_proj", "out_proj", "x_proj", "dt_proj"}


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in dp_axes(mesh)]))


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        size = _axis_size(mesh, axis)
    return n % size == 0 and n >= size


def _maybe(n: int, mesh: Mesh, axis):
    return axis if _div(n, mesh, axis) else None


def _param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...],
                 mesh: Mesh) -> P:
    last = path[-1]
    in_layers = any(k in ("layers", "enc_layers", "dec_layers")
                    for k in path)
    # packed-serving leaves: (..., 'w_x', 'packed'|'scale')
    if last in ("packed", "scale") and len(path) >= 2:
        base = _param_pspec(path[:-1], shape if last == "packed"
                            else shape + (1,), mesh)
        if last == "scale":
            return P(*base[:-1])
        return base

    if last in ("embed", "lm_head"):
        return P(_maybe(shape[0], mesh, "model"),
                 _maybe(shape[1], mesh, "data"))
    if last in ("meta_tokens", "dec_pos"):
        return P()

    dims = shape[1:] if in_layers else shape       # strip stacked L dim
    lead: Tuple = (None,) if in_layers else ()

    if len(dims) <= 1:
        return P(*(lead + (None,) * len(dims)))

    if last == "conv_w":                           # (di, K)
        return P(*(lead + (_maybe(dims[0], mesh, "model"), None)))
    if last == "A_log":                            # (di, N)
        return P(*(lead + (_maybe(dims[0], mesh, "model"), None)))

    if len(dims) == 3:                             # MoE experts (E, F, D)
        e, a, b = dims
        if _div(e, mesh, "model"):
            return P(*(lead + ("model", None, _maybe(b, mesh, "data"))))
        if last == "w_down":                       # (E, D, F): F -> model
            return P(*(lead + (None, _maybe(a, mesh, "data"),
                               _maybe(b, mesh, "model"))))
        return P(*(lead + (None, _maybe(a, mesh, "model"),
                           _maybe(b, mesh, "data"))))

    if len(dims) == 2:                             # (out, in)
        return P(*(lead + (_maybe(dims[0], mesh, "model"),
                           _maybe(dims[1], mesh, "data"))))

    return P(*(lead + (None,) * len(dims)))


def param_shardings(params_tree: Any, mesh: Mesh) -> Any:
    """Tree of NamedSharding matching ``params_tree`` (arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = []
    for path, leaf in flat:
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        spec = _param_pspec(keys, tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_tree), out)


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def opt_state_shardings(opt_state: Any, mesh: Mesh, params_tree: Any) -> Any:
    """Optimizer-state shardings: moments mirror their parameter; factored
    Adafactor vectors / scalars fall back to shape rules.

    Moments are matched by TREE PATH, not bare shape: optimizer states
    embed the parameter path as a suffix (AdamW's ``mu``/``nu`` wrap the
    whole param tree), and two same-shape params can carry different
    partition specs — a shape-keyed lookup would silently collide
    (last-one-wins).  Shape lookup survives only as a fallback for
    pathless leaves, and only when every param of that shape agrees."""
    by_path: Dict[Tuple[str, ...], Tuple[Tuple[int, ...], P]] = {}
    by_shape: Dict[Tuple[int, ...], list] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        spec = _param_pspec(keys, shape, mesh)
        by_path[keys] = (shape, spec)
        by_shape.setdefault(shape, []).append(spec)

    def resolve(keys: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        # longest matching path suffix wins (the opt-state path prefixes
        # the param path with e.g. (0, 'mu'))
        for start in range(len(keys)):
            hit = by_path.get(keys[start:])
            if hit is not None and hit[0] == shape:
                return hit[1]
        specs = by_shape.get(shape)
        if specs is not None and all(s == specs[0] for s in specs):
            return specs[0]                        # unambiguous shape
        if len(shape) == 0:
            return P()
        # factored vectors: shard the largest shardable dim on model
        spec = [None] * len(shape)
        for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
            if _div(shape[i], mesh, "model"):
                spec[i] = "model"
                break
        return P(*spec)

    def per_leaf(path, leaf):
        return NamedSharding(mesh, resolve(_path_keys(path),
                                           tuple(leaf.shape)))

    return jax.tree_util.tree_map_with_path(per_leaf, opt_state)


def shard_axis(path_keys: Sequence[str], shape: Tuple[int, ...],
               mesh: Mesh) -> Optional[Tuple[int, int]]:
    """(axis, n_shards) the plan tensor-shards this param on, or None.

    Mirrors :func:`_param_pspec`: the first NON-LAST dim the spec pins to
    the "model" axis, provided it divides evenly.  The last (in-features /
    packed) dim is excluded on purpose — the packed payload's per-row
    scales span whole rows, so only leading-dim slices keep the page wire
    codec's shard-then-decode == decode-then-shard identity."""
    n = _axis_size(mesh, "model")
    if n <= 1:
        return None
    spec = _param_pspec(tuple(path_keys), tuple(shape), mesh)
    for ax, entry in enumerate(spec):
        if ax >= len(shape) - 1:
            break
        if entry == "model" and shape[ax] % n == 0 and shape[ax] >= n:
            return (ax, n)
    return None


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def batch_pspec(batch: int, mesh: Mesh, extra_dims: int = 1) -> P:
    axes = dp_axes(mesh)
    if not axes or batch % dp_size(mesh) != 0:
        return P(*((None,) * (1 + extra_dims)))
    return P(axes, *((None,) * extra_dims))


def cache_shardings(cache_tree: Any, mesh: Mesh, batch: int) -> Any:
    """KV cache (L, B, H, S, hd): B->dp, S->model.
    SSM state h (L, B, di, N): di->model; conv (L, B, K-1, di): di->model."""
    bspec = dp_axes(mesh) if batch % dp_size(mesh) == 0 and dp_size(mesh) > 1 else None

    def per_leaf(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        nd = len(leaf.shape)
        if keys[-1] in ("k", "v") and nd == 5:        # (L,B,H,S,hd)
            return NamedSharding(mesh, P(
                None, bspec, None,
                _maybe(leaf.shape[3], mesh, "model"), None))
        if keys[-1] in ("xk", "xv") and nd == 5:      # cross-attn KV
            return NamedSharding(mesh, P(None, bspec, None, None, None))
        if keys[-1] == "h" and nd == 4:               # (L,B,di,N)
            return NamedSharding(mesh, P(
                None, bspec, _maybe(leaf.shape[2], mesh, "model"), None))
        if keys[-1] == "conv" and nd == 4:            # (L,B,K-1,di)
            return NamedSharding(mesh, P(
                None, bspec, None, _maybe(leaf.shape[3], mesh, "model")))
        return NamedSharding(mesh, P(*((None,) * nd)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = [per_leaf(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_tree), out)


def sds(shape, dtype, sharding) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def with_shardings(spec_tree: Any, shard_tree: Any) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shard_tree)


# ---------------------------------------------------------------------------
# packed-store spec (At-MRAM serving parameters)
# ---------------------------------------------------------------------------

def freeze_for_serving(params: Any, bits: int = 8, plan: Any = None) -> Any:
    """Quantize+pack every PACKABLE matmul leaf (real arrays).

    ``plan`` (a :class:`repro.core.placement.PlacementPlan`) overrides
    ``bits`` per parameter path so the packed precision matches what the
    plan's dispatch will later assume.
    """
    from repro.core import packing, quantize

    def per_leaf(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        if keys[-1] in PACKABLE and leaf.ndim >= 2:
            b = plan.bits_for("/".join(keys)) if plan is not None else bits
            flat = leaf.reshape(-1, leaf.shape[-1])
            qt = quantize.quantize_weights(flat, b, channel_axis=0)
            packed = packing.pack(qt.values, b).reshape(
                *leaf.shape[:-1], -1)
            scale = qt.scale.reshape(leaf.shape[:-1])
            return dict(packed=packed, scale=scale)
        return leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [per_leaf(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def serve_spec_like(params_spec: Any, bits: int = 8, plan: Any = None) -> Any:
    """ShapeDtypeStruct tree of the packed store (no allocation).

    ``plan`` (PlacementPlan) overrides ``bits`` per parameter path, exactly
    mirroring :func:`freeze_for_serving` so specs and real packed arrays
    stay layout-consistent under mixed-precision plans.
    """

    def per_leaf(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        if keys[-1] in PACKABLE and len(leaf.shape) >= 2:
            b = plan.bits_for("/".join(keys)) if plan is not None else bits
            f = 8 // b
            k = leaf.shape[-1]
            return dict(
                packed=jax.ShapeDtypeStruct(
                    leaf.shape[:-1] + ((k + f - 1) // f,), jnp.uint8),
                scale=jax.ShapeDtypeStruct(leaf.shape[:-1], jnp.float32),
            )
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = [per_leaf(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)

"""Fault-tolerant training loop.

The loop is structured as it would run on a real fleet:

    restore-or-init -> [step: data(step) -> train_step -> monitor
                        -> periodic async checkpoint] -> on failure:
    re-enter restore-or-init (a fresh process/host set does the same).

Because the data pipeline is step-indexed and the checkpoint stores
(params, opt_state, step), a crash at ANY point resumes bit-exactly (the
restart-equivalence test asserts this).  Elasticity: restore() takes the
*current* mesh's shardings, so the same checkpoint brings the run up on a
different pod count.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMDataset
from repro.runtime.monitor import FailureInjector, StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 init_state: Callable[[], Dict[str, Any]],
                 dataset: SyntheticLMDataset,
                 failure_injector: Optional[FailureInjector] = None,
                 shardings: Optional[Dict[str, Any]] = None):
        """``init_state() -> {"params": ..., "opt_state": ...}``;
        ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.
        """
        self.cfg = cfg
        self.train_step = train_step
        self.init_state = init_state
        self.dataset = dataset
        self.injector = failure_injector
        self.shardings = shardings
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep_n=cfg.keep_n)
        self.monitor = StragglerMonitor()
        self.metrics_log = []
        self.restarts = 0

    # -- restore-or-init ------------------------------------------------------
    def _bring_up(self):
        state = self.init_state()
        start_step = 0
        if self.ckpt.latest_step() is not None:
            tmpl = dict(state)
            start_step, state = self.ckpt.restore(
                tmpl, shardings=self.shardings)
            start_step += 1
        return start_step, state

    # -- main loop ------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        while True:
            try:
                return self._run_once()
            except RuntimeError as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                print(f"[trainer] failure ({e}); restart "
                      f"{self.restarts}/{self.cfg.max_restarts}")

    def _run_once(self) -> Dict[str, Any]:
        step, state = self._bring_up()
        params, opt_state = state["params"], state["opt_state"]
        while step < self.cfg.total_steps:
            if self.injector is not None:
                self.injector.maybe_fail(step)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.dataset.batch(step).items()}
            self.monitor.step_start()
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            jax.block_until_ready(metrics["loss"])
            straggler = self.monitor.step_end()
            self.metrics_log.append(
                dict(step=step, loss=float(metrics["loss"]),
                     straggler=straggler))
            if step % self.cfg.log_every == 0:
                print(f"[trainer] step {step} loss {float(metrics['loss']):.4f}"
                      + (" [straggler]" if straggler else ""))
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, dict(params=params,
                                          opt_state=opt_state))
            step += 1
        self.ckpt.save(self.cfg.total_steps - 1,
                       dict(params=params, opt_state=opt_state), block=True)
        self.ckpt.wait()
        return dict(params=params, opt_state=opt_state,
                    metrics=self.metrics_log, restarts=self.restarts)

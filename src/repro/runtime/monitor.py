"""Straggler detection + failure injection for fault-tolerance tests.

StragglerMonitor keeps an EWMA of step latency and flags steps that
exceed ``threshold`` x the moving estimate — on a real fleet this signal
feeds the controller that hot-swaps the slow host (and, within a step,
XLA's collective timeouts do the intra-step mitigation).  The monitor also
exports the history the perf log reads.

Step timing rides on the serving tracer's span primitive
(:class:`repro.serving.trace.Tracer`) instead of ad-hoc ``perf_counter``
bracketing: every step is a ``"step"`` span on the ``"train"`` track and
every straggler verdict an instant event, so ``monitor.tracer.write(path)``
drops a Chrome Trace Event JSON of the training loop for free — the same
timeline format the serving tick pipeline emits.  Pass your own tracer to
merge the training track into a larger trace; by default the monitor owns
a private enabled one.

FailureInjector deterministically raises at chosen steps to exercise the
restart path in tests and examples (chaos-monkey style).
"""

from __future__ import annotations

from typing import List, Optional

from repro.serving.trace import Span, Tracer


class StragglerMonitor:
    def __init__(self, alpha: float = 0.2, threshold: float = 2.0,
                 warmup: int = 3, tracer: Optional[Tracer] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.history: List[float] = []
        self.flagged: List[int] = []
        self.tracer = tracer if tracer is not None else Tracer()
        self._span: Optional[Span] = None

    def step_start(self) -> None:
        self._span = self.tracer.span("step", track="train",
                                      step=len(self.history))
        self._span.__enter__()

    def step_end(self) -> bool:
        """Record one step; returns True if the step was a straggler."""
        assert self._span is not None
        span, self._span = self._span, None
        span.__exit__(None, None, None)
        dt = span.dur_s
        self.history.append(dt)
        is_straggler = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if (len(self.history) > self.warmup
                    and dt > self.threshold * self.ewma):
                is_straggler = True
                self.flagged.append(len(self.history) - 1)
                self.tracer.instant("straggler", track="train",
                                    step=len(self.history) - 1,
                                    dt_ms=dt * 1e3,
                                    ewma_ms=self.ewma * 1e3)
            # EWMA ignores flagged outliers so one straggler doesn't mask
            # the next
            if not is_straggler:
                self.ewma = self.alpha * dt + (1 - self.alpha) * self.ewma
        return is_straggler


class FailureInjector:
    """Raises RuntimeError at the given steps — once each."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")

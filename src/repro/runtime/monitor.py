"""Straggler detection + failure injection for fault-tolerance tests.

StragglerMonitor keeps an EWMA of step latency and flags steps that
exceed ``threshold`` x the moving estimate — on a real fleet this signal
feeds the controller that hot-swaps the slow host (and, within a step,
XLA's collective timeouts do the intra-step mitigation).  The monitor also
exports the history the perf log reads.

FailureInjector deterministically raises at chosen steps to exercise the
restart path in tests and examples (chaos-monkey style).
"""

from __future__ import annotations

import time
from typing import List, Optional


class StragglerMonitor:
    def __init__(self, alpha: float = 0.2, threshold: float = 2.0,
                 warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.history: List[float] = []
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self) -> bool:
        """Record one step; returns True if the step was a straggler."""
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.history.append(dt)
        is_straggler = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if (len(self.history) > self.warmup
                    and dt > self.threshold * self.ewma):
                is_straggler = True
                self.flagged.append(len(self.history) - 1)
            # EWMA ignores flagged outliers so one straggler doesn't mask
            # the next
            if not is_straggler:
                self.ewma = self.alpha * dt + (1 - self.alpha) * self.ewma
        return is_straggler


class FailureInjector:
    """Raises RuntimeError at the given steps — once each."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")

from repro.checkpoint.manager import (CheckpointManager,
                                      CheckpointRestoreError,
                                      save_pytree, restore_pytree)

__all__ = ["CheckpointManager", "CheckpointRestoreError",
           "save_pytree", "restore_pytree"]

"""Sharded, atomic, async checkpointing with elastic restore.

Design points for 1000+-node runs (no external deps):

  * **Atomicity** — checkpoints are written to ``step_XXXX.tmp`` and
    renamed only after every leaf + manifest is fsynced; a crashed writer
    can never leave a half checkpoint that restore would accept.
  * **Sharding-agnostic layout** — leaves are stored as full logical
    arrays keyed by tree path, with the manifest recording shapes/dtypes.
    Restore re-shards onto *any* mesh (elastic scaling: save on 2 pods,
    restore on 1, or vice versa).  On a real multi-host run each host
    writes only the shards it owns (addressable_shards) into a per-host
    file; this single-process implementation writes the gathered arrays,
    which is the degenerate single-host case of the same layout.
  * **Async** — save() snapshots to host memory synchronously (cheap) and
    writes in a background thread so the train loop never blocks on disk.
  * **Keep-N + best-effort GC**, restore-latest, and step indexing for
    the fault-tolerant trainer (runtime/trainer.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointRestoreError(RuntimeError):
    """A restore failed; names the step (and root) it failed for.

    Raised when no checkpoint exists to restore, or when the named step's
    directory is unreadable (missing/corrupt manifest, missing leaf file)
    — i.e. everything short of a structural mismatch with the caller's
    ``tree_like``, which keeps its specific KeyError/ValueError."""

    def __init__(self, message: str, *, step: Optional[int] = None,
                 root: Optional[Path] = None):
        self.step = step
        self.root = root
        super().__init__(message)


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree: Any, directory: str | Path) -> None:
    """Atomic synchronous save of one pytree."""
    directory = Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {}
    arrays = {}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":
            # ml_dtypes (bfloat16, ...) have no native .npy representation;
            # store as f32 (lossless for bf16) and restore via the manifest
            arr = arr.astype(np.float32)
        fname = f"leaf_{len(arrays)}.npy"
        arrays[fname] = arr
        manifest[key] = dict(file=fname, shape=list(arr.shape),
                             dtype=logical_dtype)
    for fname, arr in arrays.items():
        np.save(tmp / fname, arr)
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if directory.exists():
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(tree_like: Any, directory: str | Path,
                   shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings`` (same structure, NamedSharding leaves) enables elastic
    restore onto a different mesh than the one that saved.
    """
    directory = Path(directory)
    with open(directory / "manifest.json") as f:
        manifest = json.load(f)
    flat = _flatten(tree_like)
    shard_flat = (None if shardings is None
                  else [s for _, s in _flatten(shardings)])
    out = []
    for i, (key, leaf) in enumerate(flat):
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = manifest[key]
        arr = np.load(directory / rec["file"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        val = jax.numpy.asarray(arr).astype(leaf.dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            out.append(jax.device_put(val, shard_flat[i]))
        else:
            out.append(jax.device_put(val))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out)


class CheckpointManager:
    def __init__(self, root: str | Path, keep_n: int = 3,
                 async_save: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, block: bool = False) -> None:
        # snapshot to host synchronously: the train loop can donate/overwrite
        # device buffers immediately after this returns
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()                     # one writer at a time

        def _write():
            try:
                save_pytree(host_tree, self.root / f"step_{step:08d}")
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._last_error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.root.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise CheckpointRestoreError(
                f"no checkpoints under {self.root}", root=self.root)
        try:
            tree = restore_pytree(tree_like, self.root / f"step_{step:08d}",
                                  shardings)
        except (OSError, json.JSONDecodeError) as e:
            # a half-written .tmp never reaches all_steps(), so landing
            # here means the renamed directory itself is damaged
            raise CheckpointRestoreError(
                f"checkpoint step {step} under {self.root} is unreadable: "
                f"{e}", step=step, root=self.root) from e
        return step, tree

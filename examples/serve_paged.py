"""Serving a model LARGER than the resident weight budget — the paper's
software-assisted virtual paging (§II-B2) at LM scale, driven by a
PlacementPlan.

``plan_for_budget`` splits the packed store against the resident budget:
the hottest parameters (highest bytes-used-per-inference) are pinned
l1mram-resident, the rest are marked paged/l3flash.  The plan-aware
``HostPagedStore`` then uploads the hot set once and streams only the
paged parameters host->device double-buffered ahead of use (proactive
swap) — synchronously via ``stream()`` or overlapped via
``begin_pass()``/``fence()``, where the page traffic rides behind the
caller's compute and only the *exposed* fence wait hits the critical
path.  We check the mixed execution is bit-identical to the fully
resident one, and the async schedule to the sync one.

Run:  PYTHONPATH=src python examples/serve_paged.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.paging import HostPagedStore, StallModel, build_pages
from repro.core.placement import plan_for_budget
from repro.core.weight_store import freeze, uniform_policy
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving


def main():
    cfg = get_config("qwen2.5-3b").smoke().replace(n_layers=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)

    # resident (reference) packed serving
    packed = freeze_for_serving(params, bits=8)
    ref_logits = tfm.forward(packed, tokens, cfg,
                             engine=dict(scenario="l1mram", mode="xla", bits=8))

    # paged: LAYER-GRANULAR store built from the unstacked params (a page
    # holds whole layers, matching the deterministic access order)
    per_layer = {}
    for i in range(cfg.n_layers):
        layer_i = jax.tree_util.tree_map(lambda x: x[i], params["layers"])
        for path, leaf in jax.tree_util.tree_flatten_with_path(layer_i)[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            per_layer[f"layer{i:02d}/{key}"] = leaf
    flat_store = freeze(per_layer, uniform_policy(8, min_size=256))

    # budget ~ half the model: plan_for_budget pins the hot half resident,
    # the cold half pages through two live slots (MRAM + tile SRAM)
    budget = flat_store.packed_bytes // 2
    plan = plan_for_budget(flat_store, budget)
    layer_bytes = flat_store.packed_bytes // cfg.n_layers
    page_bytes = 2 * layer_bytes + 64
    pages = build_pages(flat_store, page_bytes, plan=plan)
    print(f"model: {flat_store.packed_bytes/1e6:.2f} MB packed; plan pins "
          f"{plan.resident_bytes(flat_store)/1e6:.2f} MB resident "
          f"(budget {budget/1e6:.2f} MB), pages "
          f"{plan.paged_bytes(flat_store)/1e6:.2f} MB across {len(pages)} "
          f"pages of <= {page_bytes/1e6:.2f} MB")
    assert plan.fits(flat_store, budget)

    paged = HostPagedStore(flat_store, page_bytes, plan=plan)
    streamed = dict(paged.resident)      # hot set pinned at construction
    for page, dev_params in paged.stream(resident_slots=2):
        streamed.update(dev_params)
    print(f"  swaps: {paged.swap_count}, demand misses: {paged.miss_count} "
          f"(proactive prefetch hid all but the cold start)")

    # the ASYNC version of the same pass: begin_pass() kicks the whole
    # fetch loop and returns immediately; we "compute" (here: re-run the
    # reference forward) while the pages stream, then fence at first use.
    # Only the exposed wait would land on a serving tick's critical path.
    apass = paged.begin_pass(resident_slots=2)
    jax.block_until_ready(tfm.forward(packed, tokens, cfg,
                                      engine=dict(scenario="l1mram",
                                                  mode="xla", bits=8)))
    overlapped = dict(paged.resident)
    overlapped.update(apass.fence())
    assert all(int(jnp.max(jnp.abs(
        overlapped[n].packed.astype(jnp.int32)
        - streamed[n].packed.astype(jnp.int32)))) == 0
        for n in flat_store.params)      # same bytes, different schedule
    print(f"  async pass: {apass.swap_s*1e3:.2f} ms stream wall = "
          f"{apass.hidden_s*1e3:.2f} ms hidden behind compute + "
          f"{apass.exposed_s*1e3:.2f} ms exposed at the fence "
          f"({apass.hidden_s/max(apass.swap_s, 1e-12)*100:.0f}% overlapped)")

    # every leaf — pinned or streamed — is bit-identical to the reference
    drift = 0
    for name, p in flat_store.params.items():
        drift = max(drift, int(jnp.max(jnp.abs(
            streamed[name].packed.astype(jnp.int32)
            - p.packed.astype(jnp.int32)))))
    print(f"  streamed-vs-resident packed drift: {drift} (must be 0)")
    assert drift == 0

    # stall model over the PAGED traffic only: what the plan's cold half
    # costs on the SoC (the hot half never swaps)
    sm = StallModel(swap_bandwidth_bytes_per_s=550e6)   # HyperBus
    compute = [0.8e-3] * len(pages)                     # per-page compute
    r = sm.run(pages, compute)
    print(f"  stall model: {r['stall_s']*1e3:.2f} ms stalls over "
          f"{r['total_s']*1e3:.2f} ms total "
          f"({r['stall_fraction']*100:.1f}% — the cost of exceeding "
          f"on-chip capacity, paper section II-B2)")

    # the SERVING consumption of the same machinery: the engine attaches a
    # HostPagedStore over its plan's cold parameter groups and re-streams
    # them between ticks (repro.serving.sched drives the same path with
    # deadlines on top — see repro.launch.serve --budget-mb).
    from repro.core.placement import packed_sizes
    from repro.serving import Request, Scheduler, ServingEngine

    scfg = get_config("qwen3-0.6b").smoke()
    sparams = tfm.init_params(scfg, jax.random.PRNGKey(0))
    spacked = freeze_for_serving(sparams, bits=8)
    sizes = packed_sizes(spacked)
    splan = plan_for_budget(sizes, sum(sizes.values()) // 2)

    prompts = [rng.integers(0, scfg.vocab_size, 6 + uid).astype(np.int32)
               for uid in range(4)]

    def serve(plan, paged, async_io=True, tree=None, faults=None):
        eng = ServingEngine(scfg, spacked if tree is None else tree,
                            batch_slots=2, max_len=64,
                            plan=plan)
        if paged:
            eng.attach_paging(faults=faults)
        sched = Scheduler(eng, prefill_chunk=8, async_io=async_io)
        for uid, prompt in enumerate(prompts):
            sched.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
        sched.run_until_done()
        return {q.uid: q.generated for q in sched.finished}, eng, sched

    from repro.core.placement import PlacementPlan
    mixed, eng, sched = serve(splan, paged=True)           # overlapped
    syncd, seng, _ = serve(splan, paged=True, async_io=False)
    resident, _, _ = serve(PlacementPlan.uniform(), paged=False)
    assert mixed == syncd == resident   # overlap changes WHEN pages move,
    assert eng.swap_count == seng.swap_count   # never what anyone computes
    pg = eng.paging_summary()
    print(f"  scheduler serve (async): {sched.ticks} ticks, "
          f"{eng.swap_count} live swaps over {len(eng.pager.pages)} pages, "
          f"{pg['exposed_s']*1e3:.1f} ms exposed + {pg['hidden_s']*1e3:.1f} "
          f"ms hidden ({pg['overlap_frac']*100:.0f}% of the stream rode "
          f"behind compute; sync path stalled "
          f"{seng.paging_stall_s*1e3:.1f} ms) — tokens bit-exact vs sync "
          f"and vs the fully resident plan")

    # ENCODED pages (repro.launch.serve --page-bits): the same cold set
    # streamed as blockwise-quantized intN payload + scales, dequantized
    # at fetch.  page_bits == store bits (int8 here) is the zero-decode
    # identity — tokens stay bit-exact while the wire traffic drops ~4x
    # vs the fp32-dense equivalent the raw ledger counts.
    q8, qeng, _ = serve(splan.with_page_bits(8), paged=True)
    assert q8 == resident
    wire = qeng.pager.bytes_streamed_wire
    raw = qeng.pager.bytes_streamed_raw
    print(f"  encoded pages (int8 wire): {wire} B streamed for {raw} B "
          f"fp32-dense raw ({raw/max(wire,1):.1f}x compression), tokens "
          f"bit-exact vs resident")

    # a NARROWER wire encoding (int4 pages under an int8 store) is lossy
    # but deterministic: serving it equals serving a resident tree whose
    # cold weights took the same encode->decode round trip.
    from repro.core.paging import (packed_tree_store, page_roundtrip_param,
                                   thread_packed)
    qplan4 = splan.with_page_bits(4)
    store4 = packed_tree_store(spacked, qplan4)
    rt = {n: page_roundtrip_param(p, 4) for n, p in store4.params.items()
          if qplan4.placement_for(n).paged}
    q4, _, _ = serve(qplan4, paged=True)
    want4, _, _ = serve(PlacementPlan.uniform(), paged=False,
                        tree=thread_packed(spacked, rt))
    assert q4 == want4
    print(f"  encoded pages (int4 wire, lossy): {len(rt)} cold params "
          f"round-tripped; tokens bit-exact vs the round-tripped "
          f"resident reference")

    # CHAOS (repro.launch.serve --fault-seed): the same paged serve under
    # a seeded FaultPlan — transient fetch failures retried with backoff,
    # wire bit-flips caught by the per-page CRC and re-fetched.  Faults
    # cost retries, never tokens: the generation stays bit-exact vs the
    # fully resident plan.  Every decision is a pure hash of
    # (seed, kind, model, page, attempt), so this run's fault sequence
    # is identical on every machine.
    from repro.core.faults import FaultPlan
    chaos, ceng, csched = serve(
        splan, paged=True,
        faults=FaultPlan(seed=3, fail_rate=0.2, bitflip_rate=0.2))
    assert chaos == resident            # recovery is invisible to tokens
    ft = csched.faults_summary()
    assert ft["injected"] > 0 and ft["retries"] > 0
    assert ft["checksum_failures"] == ft["refetches"]   # no corrupt install
    print(f"  chaos serve (seed 3): {ft['injected']} faults injected, "
          f"{ft['retries']} retries, {ft['checksum_failures']} CRC misses "
          f"all re-fetched — tokens bit-exact vs resident")
    print("serve_paged OK")


if __name__ == "__main__":
    main()

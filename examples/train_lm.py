"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — fault-tolerant trainer, async checkpoints,
straggler monitor, step-indexed data, and a mid-run injected failure that
the loop survives.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(defaults are sized for this CPU container; on a TPU slice drop --tiny)
"""

import argparse
import shutil

import jax

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import FailureInjector, Trainer, TrainerConfig


def hundred_m_config() -> ModelConfig:
    """~100M params: 12L x d512, GQA 8/4 heads, swiglu — qwen3 family."""
    return get_config("qwen3-0.6b").replace(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32768, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="shrink further for very fast CPU runs")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    if args.tiny:
        cfg = cfg.smoke()
    n = tfm.total_param_count(cfg)
    print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params")

    shutil.rmtree(args.ckpt, ignore_errors=True)
    opt = adamw()
    step_fn = jax.jit(make_train_step(cfg, opt, lr=3e-4))

    def init_state():
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        return dict(params=params, opt_state=opt.init(params))

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch, seed=0)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                      checkpoint_dir=args.ckpt, log_every=20),
        step_fn, init_state, ds,
        failure_injector=FailureInjector([args.steps // 2]))  # chaos monkey
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps; "
          f"survived {out['restarts']} injected failure(s); "
          f"{len(trainer.monitor.flagged)} straggler steps flagged")
    assert losses[-1] < losses[0], "training did not improve"
    print("train_lm OK")


if __name__ == "__main__":
    main()

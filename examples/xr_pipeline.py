"""The paper's XR workload: a heterogeneous frame pipeline.

Per camera frame (the paper's >30 FPS visual loop):
  DSP path (RISC-V cluster analogue):  lens distortion correction ->
  N-EUREKA path:                       int8 MobileNet-V2 from the packed
                                       At-MRAM store ->
  DSP path:                            FFT post-processing on a sensor
                                       channel + kmeans gesture clustering

Both engines read/write the same arrays zero-copy (paper §II-A), weights
never leave the packed store (§II-C4), and the frame budget is checked
against the memsys model's 7.3 ms L1MRAM walk.

Run:  PYTHONPATH=src python examples/xr_pipeline.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import mnv2_scenario_table
from repro.models import mobilenet_v2 as mnv2

IMG = 64    # reduced from 224 for the CPU container; same network family


@jax.jit
def distortion_correct(img):
    h, w, _ = img.shape
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, h), jnp.linspace(-1, 1, w),
                          indexing="ij")
    r2 = xx ** 2 + yy ** 2
    f = 1 + 0.08 * r2
    xs = jnp.clip(((xx * f + 1) / 2 * (w - 1)).astype(jnp.int32), 0, w - 1)
    ys = jnp.clip(((yy * f + 1) / 2 * (h - 1)).astype(jnp.int32), 0, h - 1)
    return img[ys, xs]


@jax.jit
def post_process(features):
    spec = jnp.abs(jnp.fft.rfft(features.astype(jnp.float32)))
    # 4-means over the spectrum (gesture clustering stand-in)
    cents = spec[:4, None]
    for _ in range(3):
        d = jnp.abs(spec[None, :] - cents)
        assign = jnp.argmin(d, axis=0)
        cents = jnp.stack([jnp.where(assign == i, spec, 0).sum()
                           / jnp.maximum((assign == i).sum(), 1)
                           for i in range(4)])[:, None]
    return cents[:, 0]


def main():
    rng = np.random.default_rng(0)
    print("programming the MRAM store (int8 MobileNet-V2)...")
    params = mnv2.init_params(jax.random.PRNGKey(0), weight_bits=8, img=IMG)
    packed = mnv2.freeze_packed(params, weight_bits=8, img=IMG)
    wbytes = sum(np.asarray(p["packed"]).nbytes for p in packed.values())
    print(f"  packed weights: {wbytes/1e6:.2f} MB "
          f"(224px network: 3.47 MB < 4 MiB MRAM)")

    apply_fn = jax.jit(lambda img: mnv2.apply(packed, img, weight_bits=8,
                                              mode="xla", img=IMG))

    frames = [jnp.asarray(rng.integers(0, 255, (IMG, IMG, 3)), jnp.uint8)
              for _ in range(5)]
    # warmup/compile
    _ = jax.block_until_ready(post_process(apply_fn(distortion_correct(frames[0]))))

    t0 = time.perf_counter()
    for fr in frames:
        corrected = distortion_correct(fr)          # DSP engine
        logits = apply_fn(corrected)                # N-EUREKA engine
        gestures = post_process(logits)             # DSP engine
        jax.block_until_ready(gestures)
    dt = (time.perf_counter() - t0) / len(frames)
    print(f"  host pipeline: {dt*1e3:.1f} ms/frame (functional check)")

    tab = mnv2_scenario_table()
    t_l1, e_l1, _ = tab["l1mram"]
    print(f"  Siracusa model @0.8V: {t_l1*1e3:.2f} ms/frame, "
          f"{e_l1*1e3:.2f} mJ/frame -> {1/t_l1:.0f} FPS capable, "
          f"{e_l1*30*1e3:.0f} mW at 30 FPS (paper target: >30 FPS, <60 mW)")
    assert 1 / t_l1 > 30

    # the paper's "complex heterogeneous application workloads" (§V): two
    # tenant models — a dense assistant LM and an SSM frame-tracker —
    # share ONE MultiScheduler (a single EDF-with-priority admission
    # loop) and ONE SharedPagePool device-bytes budget, with one tenancy
    # tick interleaved per camera frame so chunked prefill can never
    # stall the visual loop.  The tick loop is the ASYNC paging pipeline:
    # each tick fences the page pass begun last tick and immediately
    # begins the next one, so the tenants' weight I/O streams while the
    # frame loop computes and only the exposed fence wait costs latency.
    from repro.configs import get_config
    from repro.core.paging import SharedPagePool, kv_pass_counters
    from repro.core.placement import packed_sizes, plan_for_budget
    from repro.models import transformer as tfm
    from repro.parallel.sharding import freeze_for_serving
    from repro.serving import (MultiScheduler, Request, Scheduler,
                               ServingEngine, Tracer, validate)
    from repro.serving.trace import validate as validate_trace

    def build(arch, seed):
        cfg = get_config(arch).smoke()
        packed = freeze_for_serving(
            tfm.init_params(cfg, jax.random.PRNGKey(seed)), bits=8)
        sizes = packed_sizes(packed)
        # half the packed store resident, the rest paged through the pool
        return cfg, packed, plan_for_budget(sizes, sum(sizes.values()) // 2)

    tenants = {"assistant": build("qwen3-0.6b", 1),
               "tracker": build("falcon-mamba-7b", 2)}
    cold = sum(plan.paged_bytes(packed_sizes(packed))
               for _c, packed, plan in tenants.values())
    pool = SharedPagePool(max(int(cold * 0.6), 1))   # tight: forces churn
    print(f"tenancy: assistant LM + SSM tracker share a "
          f"{pool.budget_bytes} B page pool ({cold} B cold)")

    def requests(cfg, n, length, max_new, seed):
        r = np.random.default_rng(seed)
        return [Request(uid=uid,
                        prompt=r.integers(0, cfg.vocab_size,
                                          length).astype(np.int32),
                        max_new_tokens=max_new) for uid in range(n)]

    def submit_all(target, is_multi):
        for name, (cfg, _p, _pl) in tenants.items():
            n, length, max_new = ((3, 20, 4) if name == "assistant"
                                  else (4, 6, 2))
            for req in requests(cfg, n, length, max_new,
                                seed=sum(name.encode()) % 97):
                if is_multi:
                    target.submit(name, req, stream=name)
                else:
                    target[name].submit(req, stream=name)

    # continuous batching: one global token budget re-planned every tick
    # and mid-request preemption, so an urgent wake-word request seizes a
    # slot THIS tick instead of queueing behind a long assistant prefill
    # record the whole tenancy run as a Chrome trace: one track per
    # tenant (fence/admit/begin/compute spans + the predicted-stall
    # overlay), one io track for page traffic, preempts as instants
    tracer = Tracer()
    ms = MultiScheduler(pool=pool, token_budget=24, preemptive=True,
                        tracer=tracer)
    for name, (cfg, packed, plan) in tenants.items():
        eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64, seed=0,
                            plan=plan)
        # the assistant's long-context KV cache pages through the SAME
        # pool budget as everyone's weights (one memory hierarchy); the
        # SSM tracker has recurrent state, not a KV cache
        ms.add_model(name, eng, prefill_chunk=8,
                     kv_paged="kv" in eng.cache, kv_block_rows=8)
    ms.add_stream("assistant", "assistant", priority=1, deadline_ms=20.0)
    ms.add_stream("tracker", "tracker", priority=2, deadline_ms=15.0)
    ms.add_stream("assistant", "wake", priority=3, deadline_ms=10.0)
    submit_all(ms, is_multi=True)
    wake_rng = np.random.default_rng(11)
    wake = Request(uid=100,
                   prompt=wake_rng.integers(
                       0, tenants["assistant"][0].vocab_size,
                       4).astype(np.int32),
                   max_new_tokens=2)

    served = {}
    while ms.pending:         # frame loop with one tenancy tick per frame
        corrected = distortion_correct(frames[0])
        _ = apply_fn(corrected)
        for name, reqs in ms.tick().items():
            served.setdefault(name, []).extend(reqs)
        if ms.ticks == 2:
            # mid-run urgent arrival: both assistant slots are busy with
            # long prompts, so the wake request preempts one mid-service
            ms.submit("assistant", wake, stream="wake")

    doc = validate(ms.summary())
    for name in tenants:
        dl = doc["models"][name]["deadlines"]
        pc = doc["shared_pool"]["models"][name]
        pg = doc["models"][name]["paging"]
        print(f"  {name}: {doc['models'][name]['requests']['count']} "
              f"requests over {ms.ticks} interleaved ticks, deadline "
              f"misses {dl['missed']}/{dl['with_deadline']}, paging "
              f"{pc['swaps']} swaps / {pc['pool_hits']} pool hits / "
              f"evicted {pc['evicted']}x (host-CPU timing; the SoC "
              f"budget check is the memsys walk above)")
        print(f"    I/O overlap: {pg['exposed_s']*1e3:.1f} ms exposed "
              f"stall vs {pg['hidden_s']*1e3:.1f} ms hidden behind the "
              f"frame loop's compute ({pg['overlap_frac']*100:.0f}% of "
              f"the page stream reclaimed by the async pipeline)")
    tot = doc["totals"]
    sc = doc["models"]["assistant"]["scheduler"]
    print(f"  continuous batching: budget "
          f"{sc['budget_tokens_per_tick']} tok/tick at "
          f"{sc['budget_utilization']*100:.0f}% utilization; "
          f"{tot['preemptions']} preemption(s) / {tot['restores']} "
          f"restore(s) — the wake-word request seized a busy slot and "
          f"its victim resumed bit-exactly")
    assert tot["preemptions"] >= 1
    assert tot["preemptions"] == tot["restores"]

    # the §V claim, checked: concurrency changes WHO pays the swaps, not
    # what anyone computes — each tenant's tokens are bit-exact vs
    # serving that model alone on a private pager, and the shared-pool
    # counters follow the static prediction.
    pred = kv_pass_counters(
        {name: [p.nbytes for p in ms.model(name).engine.pager.pages]
         for name in tenants},
        pool.budget_bytes, events=pool.events)
    for name in pred:                       # weight members AND */kv
        got = doc["shared_pool"]["models"][name]
        assert all(got[k] == pred[name][k]
                   for k in ("swaps", "misses", "pool_hits", "evicted")), \
            (name, got, pred[name])
    kv_pg = doc["models"]["assistant"]["paging"]
    print(f"  assistant KV paging: {kv_pg['kv_swaps']} block swaps / "
          f"{kv_pg['kv_pool_hits']} pool hits / "
          f"{kv_pg['kv_writebacks']} writebacks through the shared pool")

    for name, (cfg, packed, plan) in tenants.items():
        eng = ServingEngine(cfg, packed, batch_slots=2, max_len=64, seed=0,
                            plan=plan).attach_paging()
        if "kv" in eng.cache:
            eng.attach_kv_paging(8)        # private table: same tokens
        solo = Scheduler(eng, prefill_chunk=8)
        solo.add_stream(name, priority=1, deadline_ms=20.0)
        n, length, max_new = ((3, 20, 4) if name == "assistant"
                              else (4, 6, 2))
        for req in requests(cfg, n, length, max_new, seed=sum(name.encode()) % 97):
            solo.submit(req, stream=name)
        if name == "assistant":
            # the wake request rides in the solo reference too — greedy
            # tokens are slot-isolated, so WHEN it was admitted (or whom
            # it preempted) must not change a single token
            solo.submit(Request(uid=100,
                                prompt=np.asarray(wake.prompt, np.int32),
                                max_new_tokens=2), stream=name)
        want = {r.uid: r.generated for r in solo.run_until_done()}
        got = {r.uid: r.generated for r in served[name]}
        assert got == want, f"{name}: tenant tokens diverge from solo"
        eng.pager.close()
        if eng.kv_table is not None:
            eng.kv_table.close()
    print("  tenant tokens bit-exact vs solo private pagers; pool "
          "counters (weights AND kv) match kv_pass_counters")
    ms.close()

    tdoc = tracer.to_dict()
    validate_trace(tdoc)
    tracer.write("xr_pipeline_trace.json")
    print(f"  trace: {tracer.event_count} events on "
          f"{len(tracer.track_names)} tracks -> xr_pipeline_trace.json "
          f"(open in chrome://tracing or ui.perfetto.dev)")
    print("xr_pipeline OK")


if __name__ == "__main__":
    main()

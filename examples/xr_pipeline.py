"""The paper's XR workload: a heterogeneous frame pipeline.

Per camera frame (the paper's >30 FPS visual loop):
  DSP path (RISC-V cluster analogue):  lens distortion correction ->
  N-EUREKA path:                       int8 MobileNet-V2 from the packed
                                       At-MRAM store ->
  DSP path:                            FFT post-processing on a sensor
                                       channel + kmeans gesture clustering

Both engines read/write the same arrays zero-copy (paper §II-A), weights
never leave the packed store (§II-C4), and the frame budget is checked
against the memsys model's 7.3 ms L1MRAM walk.

Run:  PYTHONPATH=src python examples/xr_pipeline.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perf_model import mnv2_scenario_table
from repro.models import mobilenet_v2 as mnv2

IMG = 64    # reduced from 224 for the CPU container; same network family


@jax.jit
def distortion_correct(img):
    h, w, _ = img.shape
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, h), jnp.linspace(-1, 1, w),
                          indexing="ij")
    r2 = xx ** 2 + yy ** 2
    f = 1 + 0.08 * r2
    xs = jnp.clip(((xx * f + 1) / 2 * (w - 1)).astype(jnp.int32), 0, w - 1)
    ys = jnp.clip(((yy * f + 1) / 2 * (h - 1)).astype(jnp.int32), 0, h - 1)
    return img[ys, xs]


@jax.jit
def post_process(features):
    spec = jnp.abs(jnp.fft.rfft(features.astype(jnp.float32)))
    # 4-means over the spectrum (gesture clustering stand-in)
    cents = spec[:4, None]
    for _ in range(3):
        d = jnp.abs(spec[None, :] - cents)
        assign = jnp.argmin(d, axis=0)
        cents = jnp.stack([jnp.where(assign == i, spec, 0).sum()
                           / jnp.maximum((assign == i).sum(), 1)
                           for i in range(4)])[:, None]
    return cents[:, 0]


def main():
    rng = np.random.default_rng(0)
    print("programming the MRAM store (int8 MobileNet-V2)...")
    params = mnv2.init_params(jax.random.PRNGKey(0), weight_bits=8, img=IMG)
    packed = mnv2.freeze_packed(params, weight_bits=8, img=IMG)
    wbytes = sum(np.asarray(p["packed"]).nbytes for p in packed.values())
    print(f"  packed weights: {wbytes/1e6:.2f} MB "
          f"(224px network: 3.47 MB < 4 MiB MRAM)")

    apply_fn = jax.jit(lambda img: mnv2.apply(packed, img, weight_bits=8,
                                              mode="xla", img=IMG))

    frames = [jnp.asarray(rng.integers(0, 255, (IMG, IMG, 3)), jnp.uint8)
              for _ in range(5)]
    # warmup/compile
    _ = jax.block_until_ready(post_process(apply_fn(distortion_correct(frames[0]))))

    t0 = time.perf_counter()
    for fr in frames:
        corrected = distortion_correct(fr)          # DSP engine
        logits = apply_fn(corrected)                # N-EUREKA engine
        gestures = post_process(logits)             # DSP engine
        jax.block_until_ready(gestures)
    dt = (time.perf_counter() - t0) / len(frames)
    print(f"  host pipeline: {dt*1e3:.1f} ms/frame (functional check)")

    tab = mnv2_scenario_table()
    t_l1, e_l1, _ = tab["l1mram"]
    print(f"  Siracusa model @0.8V: {t_l1*1e3:.2f} ms/frame, "
          f"{e_l1*1e3:.2f} mJ/frame -> {1/t_l1:.0f} FPS capable, "
          f"{e_l1*30*1e3:.0f} mW at 30 FPS (paper target: >30 FPS, <60 mW)")
    assert 1 / t_l1 > 30

    # the paper's "complex heterogeneous application workloads": alongside
    # the frame loop, an LM assistant stream serves under a deadline via
    # the EDF scheduler — one scheduler tick interleaved per frame, so a
    # long prompt (chunked prefill) can never stall the visual loop.
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.sharding import freeze_for_serving
    from repro.serving import Request, Scheduler, ServingEngine

    lm_cfg = get_config("qwen3-0.6b").smoke()
    lm = freeze_for_serving(tfm.init_params(lm_cfg, jax.random.PRNGKey(1)),
                            bits=8)
    eng = ServingEngine(lm_cfg, lm, batch_slots=2, max_len=64)
    sched = Scheduler(eng, prefill_chunk=8)
    sched.add_stream("assistant", priority=1, deadline_ms=20.0)
    for uid in range(3):
        sched.submit(Request(uid=uid,
                             prompt=rng.integers(0, lm_cfg.vocab_size,
                                                 20).astype(np.int32),
                             max_new_tokens=4), stream="assistant")
    while sched.pending:      # frame loop with one LM tick per frame
        corrected = distortion_correct(frames[0])
        _ = apply_fn(corrected)
        sched.tick()
    dl = sched.metrics.summary()["deadlines"]
    tl = sched.metrics.summary()["ticks"]["latency_ms"]
    print(f"  assistant stream: {len(sched.finished)} requests over "
          f"{sched.ticks} interleaved ticks, p99 tick "
          f"{tl['p99']:.1f} ms, deadline misses "
          f"{dl['missed']}/{dl['with_deadline']} (host-CPU timing; the "
          f"SoC budget check is the memsys walk above)")
    print("xr_pipeline OK")


if __name__ == "__main__":
    main()

"""Quickstart: the At-MRAM pipeline end-to-end in two minutes on CPU.

1. train a tiny LM (reduced qwen3 family config),
2. freeze it into the packed At-MRAM WeightStore (2/4/8-bit),
3. serve batched requests through the fused dequant path,
4. show the density gain + scenario comparison that is the paper's point.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, ServingEngine


def main():
    cfg = get_config("qwen3-0.6b").smoke()
    print(f"config: {cfg.name} (reduced) — {cfg.n_layers}L d{cfg.d_model}")

    # 1. train
    opt = adamw()
    step = jax.jit(make_train_step(cfg, opt, lr=1e-3))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    ds = SyntheticLMDataset(cfg.vocab_size, 64, 8, seed=0)
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.4f}")
    print(f"  final loss {float(m['loss']):.4f}")

    # 2. freeze into the packed store ("MRAM programming")
    for bits in (8, 4):
        packed = freeze_for_serving(params, bits=bits)
        dense_b = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(params))
        packed_b = sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(packed))
        print(f"  W{bits}: {dense_b/1e6:.2f} MB dense -> {packed_b/1e6:.2f} MB "
              f"packed ({dense_b/packed_b:.1f}x density, the MRAM advantage)")

    # 3. serve through the fused At-MRAM path
    packed = freeze_for_serving(params, bits=8)
    eng = ServingEngine(cfg, packed, batch_slots=4, max_len=128,
                        engine=dict(scenario="l1mram", mode="xla", bits=8))
    rng = np.random.default_rng(0)
    for uid in range(6):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                           max_new_tokens=8))
    done = eng.run_until_done()
    print(f"  served {len(done)} requests "
          f"({sum(len(r.generated) for r in done)} tokens)")

    # 4. all four NVM scenarios give identical numerics, different bytes
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    outs = {}
    for sc in ("l1mram", "l2mram", "l3mram"):
        outs[sc] = tfm.forward(packed, tokens, cfg,
                               engine=dict(scenario=sc, mode="xla", bits=8))
    drift = max(float(jnp.max(jnp.abs(outs[s] - outs["l1mram"])))
                for s in outs)
    print(f"  scenario numerics drift: {drift:.2e} (identical math, "
          f"different weight paths — Fig 9 of the paper)")
    print("quickstart OK")


if __name__ == "__main__":
    main()

"""Paper Fig 8 — N-EUREKA throughput & energy efficiency per operator.

Two parts:
  (a) the calibrated silicon model across operators x weight bits x
      operating points (anchors: 698 GOp/s dense3x3 8b, 1947 GOp/s 2b,
      8.84 TOp/J peak, 2.68 TOp/J 8b);
  (b) wall-clock of OUR Pallas kernels in interpret mode on the paper's
      peak-utilization job shapes (functional check, not TPU perf).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memsys import LOW_POWER, NOMINAL, neureka_gops
from repro.kernels import ops

from benchmarks.common import row, time_fn


def model_part() -> None:
    for op_kind in ("dense3x3", "pw1x1", "dw3x3"):
        for bits in (2, 4, 8):
            for pt in (NOMINAL, LOW_POWER):
                gops = neureka_gops(op_kind, bits, pt)
                # efficiency anchored at the two published points
                eff = 8.84e12 if (bits == 2 and pt is LOW_POWER) else \
                    2.68e12 * (8 + 1.353) / (bits + 1.353) * \
                    (0.65 / pt.voltage) ** -2 * \
                    (1.0 if pt is LOW_POWER else 0.82)
                row(f"fig8.{op_kind}.{bits}b.{pt.name}", 0.0,
                    f"{gops/1e9:.0f}GOp/s {eff/1e12:.2f}TOp/J")
    row("fig8.anchor.dense3x3_8b", 0.0,
        f"{neureka_gops('dense3x3', 8)/1e9:.0f}GOp/s (paper 698, ideal 738)")
    row("fig8.anchor.dense3x3_2b", 0.0,
        f"{neureka_gops('dense3x3', 2)/1e9:.0f}GOp/s (paper 1947)")


def kernel_part() -> None:
    """Paper's peak-utilization jobs through the real Pallas kernels."""
    rng = np.random.default_rng(0)
    # dense 3x3: 6x6 spatial, 252 in ch, 32 out ch (paper III-A)
    x = jnp.asarray(rng.integers(0, 255, (6, 6, 252)), jnp.uint8)
    w = jnp.asarray(rng.normal(size=(32, 3, 3, 252)), jnp.float32)
    for bits in (2, 8):
        packed, scale = ops.prep_conv3x3(w, bits)
        mult = jnp.full((32,), 1e-3, jnp.float32)
        bias = jnp.zeros((32,), jnp.int32)
        fn = jax.jit(lambda x_, p_, m_, b_, bits=bits: ops.neureka_conv2d(
            x_, p_, m_, b_, op="dense3x3", bits=bits, cin=252, mode="xla"))
        us = time_fn(fn, x, packed, mult, bias)
        macs = 6 * 6 * 9 * 252 * 32
        row(f"fig8.kernel.dense3x3.{bits}b", us,
            f"{2*macs/us/1e3:.2f}GOp/s-host (xla path)")
    # pointwise: 6x6, 224 -> 32
    x = jnp.asarray(rng.integers(0, 255, (6, 6, 224)), jnp.uint8)
    w = jnp.asarray(rng.normal(size=(32, 224)), jnp.float32)
    packed, scale = ops.prep_linear(w, 8)
    fn = jax.jit(lambda x_, p_: ops.neureka_conv2d(
        x_, p_, jnp.full((32,), 1e-3, jnp.float32), jnp.zeros((32,), jnp.int32),
        op="pw1x1", bits=8, cin=224, mode="xla"))
    us = time_fn(fn, x, packed)
    row("fig8.kernel.pw1x1.8b", us,
        f"{2*6*6*224*32/us/1e3:.2f}GOp/s-host")


def main() -> None:
    print("# Fig 8: N-EUREKA ops; model anchors + Pallas kernel functional timing")
    model_part()
    kernel_part()


if __name__ == "__main__":
    main()

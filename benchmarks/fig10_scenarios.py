"""Paper Fig 10 — MobileNet-V2 end-to-end latency/energy under the four
NVM integration scenarios, plus a mixed PlacementPlan (hot layers pinned
At-MRAM within a tightened budget, cold layers paged off-chip — the
§II-B2 deployment point between the uniform extremes).  THE headline
reproduction: L3FLASH 12.6 ms / 3.8 mJ -> L1MRAM 7.3 ms / 1.4 mJ
(1.7x / 3x)."""

from repro.core.perf_model import (mnv2_budget_plan, mnv2_plan_walk,
                                   mnv2_scenario_table)

from benchmarks.common import row

PAPER = dict(l3flash=(12.6, 3.8), l3mram=(10.1, 1.9),
             l2mram=(9.0, 1.8), l1mram=(7.3, 1.4))

MIXED_BUDGET = 2 * 1024 * 1024      # bytes (2 MiB) of resident MRAM


def main() -> None:
    print("# Fig 10: MobileNet-V2 x NVM scenario; derived = model vs paper")
    tab = mnv2_scenario_table()
    for sc, (t, e, _) in tab.items():
        pt, pe = PAPER[sc]
        row(f"fig10.{sc}", t * 1e6,
            f"model={t*1e3:.2f}ms/{e*1e3:.2f}mJ paper~{pt}ms/{pe}mJ")
    lat_ratio = tab["l3flash"][0] / tab["l1mram"][0]
    en_ratio = tab["l3flash"][1] / tab["l1mram"][1]
    row("fig10.headline", 0.0,
        f"latency x{lat_ratio:.2f} (paper 1.7x), energy x{en_ratio:.2f} "
        f"(paper 3x)")
    # At 30 FPS the L1MRAM energy meets the power budget
    p_avg = tab["l1mram"][1] * 30
    row("fig10.power_30fps", 0.0,
        f"{p_avg*1e3:.1f}mW average (paper: <60 mW target)")

    # mixed placement: greedy hot set inside a 2 MiB budget, rest paged
    plan = mnv2_budget_plan(MIXED_BUDGET)
    tm, em, _ = mnv2_plan_walk(plan)
    n_hot = len(plan.rules)
    row("fig10.mixed_2mib", tm * 1e6,
        f"model={tm*1e3:.2f}ms/{em*1e3:.2f}mJ ({n_hot} hot layers "
        f"l1mram-resident, rest paged l3flash; between uniform l3flash "
        f"and l1mram)")


if __name__ == "__main__":
    main()

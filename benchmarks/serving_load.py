"""Serving-load benchmark: the deadline-aware scheduler under mixed XR
traffic, with live paged-weight streaming.

Three request streams model the paper's concurrent XR workload (§V):
a high-priority hand-tracking stream on a 15 ms deadline, a gaze stream
on 10 ms, and a best-effort background assistant.  The packed store is
split by ``plan_for_budget`` so the cold half pages through the
double-buffered HostPagedStore every tick.

Emits the ``repro.serving.metrics/v1`` JSON (default
``BENCH_serving.json``) — tok/s, p99 tick latency, TTFT, deadline-miss
rate, paging stalls — the bench-trajectory artefact for serving PRs.

Run:  PYTHONPATH=src python benchmarks/serving_load.py --smoke
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.placement import packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving
from repro.serving import Request, Scheduler, ServingEngine

STREAMS = (
    ("hand_tracking", dict(priority=2, deadline_ms=15.0)),
    ("gaze", dict(priority=1, deadline_ms=10.0)),
    ("assistant", dict(priority=0, deadline_ms=None)),
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--budget-frac", type=float, default=0.5,
                    help="resident budget as a fraction of the packed "
                         "store (the §II-B2 pressure knob)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    packed = freeze_for_serving(params, bits=8)
    sizes = packed_sizes(packed)
    budget = int(sum(sizes.values()) * args.budget_frac)
    plan = plan_for_budget(sizes, budget)
    print(plan.summary(sizes))

    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan, seed=args.seed)
    if plan.paged_bytes(sizes) > 0:
        eng.attach_paging()
    sched = Scheduler(eng, prefill_chunk=args.prefill_chunk)
    for name, kw in STREAMS:
        sched.add_stream(name, **kw)

    rng = np.random.default_rng(args.seed)
    names = [s[0] for s in STREAMS]
    for uid in range(args.requests):
        hi = max(3, min(48, args.max_len - args.max_new - 2))
        prompt_len = int(rng.integers(2, hi))
        sched.submit(
            Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new),
            stream=names[uid % len(names)])

    done = sched.run_until_done()
    summary = sched.metrics.summary(paging=eng.paging_summary())
    sched.metrics.write(args.out, paging=eng.paging_summary(),
                        config=dict(arch=cfg.name, smoke=args.smoke,
                                    requests=args.requests,
                                    slots=args.slots,
                                    budget_bytes=budget,
                                    prefill_chunk=sched.prefill_chunk))

    thr, dl, ticks = (summary["throughput"], summary["deadlines"],
                      summary["ticks"])
    # harness contract: name,us_per_call,derived
    print(f"serving_tick,{ticks['latency_ms']['p50'] * 1e3:.2f},"
          f"p99_ms={ticks['latency_ms']['p99']:.2f}")
    print(f"serving_load,{1e6 / max(thr['tok_per_s'], 1e-9):.2f},"
          f"tok_per_s={thr['tok_per_s']:.1f}"
          f";miss_rate={dl['miss_rate']:.3f}"
          f";swaps={summary['paging']['swap_count']}")
    print(f"served {len(done)} requests over {sched.ticks} ticks; "
          f"metrics -> {args.out}")
    return summary


if __name__ == "__main__":
    main()

"""Serving-load benchmark: the deadline-aware scheduler under mixed XR
traffic, with live paged-weight streaming — single-model AND
multi-tenant.

Three request streams model the paper's concurrent XR workload (§V):
a high-priority hand-tracking stream on a 15 ms deadline, a gaze stream
on 10 ms, and a best-effort background assistant.  The packed store is
split by ``plan_for_budget`` so the cold half pages through the
double-buffered HostPagedStore every tick.

The multi-tenant section then serves TWO models (``--arch`` plus
``--arch2``, a dense LM and an SSM by default) through one
``MultiScheduler`` with all cold pages contending for one
``SharedPagePool`` budget (``--shared-budget-frac`` of the combined cold
bytes), asserts the pool counters against the static
``shared_pass_counters`` prediction and — under ``--smoke`` — each
tenant's tokens bit-exact versus serving that model alone on a private
pager.

Paged weights stream through the **async overlapped pipeline** by
default: tick t+1's host->device pass is begun while tick t computes and
fenced at first use, so the metrics split paging stall into *exposed*
(blocked the tick) and *hidden* (rode behind compute).  ``--sync-io``
runs the pre-overlap blocking schedule instead — CI runs the smoke bench
both ways and asserts the async run hides a nonzero fraction
(``overlap_frac > 0``) while tokens and swap/miss counters stay
identical.  A micro-bench section times the cached thread-template tick
threading against the old full-tree rebuild.

``--kv-paged`` additionally pages every tenant's per-slot KV cache
through the SAME budgeted stream (single model: a private
``KVPageTable``; tenants: ``<name>/kv`` members of the shared pool) and
asserts the generations bit-exact versus the resident-KV engine.

The **XR deadline gate** section then replays the same open-loop XR
traffic (periodic hand/gaze tracker invocations against a backlog of
long assistant requests) twice on a deterministic virtual clock — once
with the PR 5 run-to-completion scheduler, once with continuous
batching (per-tick token budget + mid-request preemption + admission
control) — and asserts the headline claim: the tracker streams'
deadline ``miss_rate <= 0.05`` under continuous batching while the
assistant's throughput stays within 10% of the run-to-completion
baseline, every request's tokens bit-exact across the two policies
(preempt/restore must not change a single token), and the weight-paging
counters still on the static ``ticks x pass_counters`` prediction under
preemption.  The virtual clock advances a fixed ``--tick-ms`` per tick
(plus 1 µs per read, keeping intra-tick stamps ordered), so the gate
measures SCHEDULING — not the host machine.

``--page-bits N`` streams every cold page *encoded*: blockwise-quantized
intN payload + scales over the wire, dequantized into the packed device
format at fetch.  The bench then asserts the compression is real —
int8 cold pages must move <= 0.3 wire bytes per fp32-dense raw byte
(>= 3.5x compression) — that the pool counters INCLUDING the wire/raw
byte ledgers still sit on the static ``kv_pass_counters`` prediction,
and times the fetch-side decode as the ``serving_page_decode``
micro-line.

Emits the ``repro.serving.metrics/v8`` multi document (default
``BENCH_serving.json``; the single-model summary rides along under
``single_model``, the deadline gate under ``xr_gate``) — tok/s, p99
tick latency, TTFT, deadline-miss rate, exposed/hidden paging stalls,
wire-vs-raw streamed bytes, shared-pool contention, preemption/
admission counters — the bench-trajectory artefact for serving PRs.

``--trace-json PATH`` additionally records the whole bench — the solo
leg, both tenants, and the continuous XR-gate leg — as one Chrome Trace
Event JSON (per-tenant fence/admit/begin/compute spans, per-page I/O
spans, preempt/restore/reject instants, and the predicted-vs-measured
stall overlay); a disabled-``Tracer`` micro-gate holds the untraced
hot-path hook under 5 us/call either way.

Run:  PYTHONPATH=src python benchmarks/serving_load.py --smoke
"""

from __future__ import annotations

import argparse
from collections import deque

import jax
import numpy as np

from repro.configs import get_config
from repro.core.paging import (SharedPagePool, kv_pass_counters,
                               page_sizes, pass_counters)
from repro.core.faults import FaultPlan
from repro.core.placement import Placement, packed_sizes, plan_for_budget
from repro.models import transformer as tfm
from repro.parallel.sharding import freeze_for_serving
from repro.serving import (MultiScheduler, Request, Scheduler,
                           ServingEngine, Stopwatch, Tracer, validate)
from repro.serving.trace import validate as validate_trace

STREAMS = (
    ("hand_tracking", dict(priority=2, deadline_ms=15.0)),
    ("gaze", dict(priority=1, deadline_ms=10.0)),
    ("assistant", dict(priority=0, deadline_ms=None)),
)


def _build(arch, smoke, budget_frac, seed, page_bits=None, wire_serve=False):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    if wire_serve:
        # wire-serve wants re-encoded int8 pages (page_bits != weight
        # bits): an int4 device store whose cold pages stay blockwise
        # int8 on the wire and skip the fetch decode entirely
        packed = freeze_for_serving(params, bits=4)
        sizes = packed_sizes(packed)
        plan = plan_for_budget(sizes,
                               int(sum(sizes.values()) * budget_frac),
                               hot=Placement("l1mram", 4, "resident"),
                               cold=Placement("l1mram", 4, "paged", 8),
                               sizes_bits=4)
        return cfg, packed, plan
    packed = freeze_for_serving(params, bits=8)
    sizes = packed_sizes(packed)
    plan = plan_for_budget(sizes, int(sum(sizes.values()) * budget_frac))
    if page_bits is not None:
        plan = plan.with_page_bits(page_bits)
    return cfg, packed, plan


def _tenant_reqs(cfg, args, salt):
    rng = np.random.default_rng(args.seed + salt)
    out = []
    for uid in range(args.requests):
        hi = max(3, min(48, args.max_len - args.max_new - 2))
        prompt_len = int(rng.integers(2, hi))
        out.append(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               prompt_len).astype(np.int32),
                           max_new_tokens=args.max_new))
    return out


def _bench_multi(args, tracer=None):
    """Two tenants, one MultiScheduler, one SharedPagePool budget."""
    tenants = {args.arch: _build(args.arch, args.smoke,
                                 args.budget_frac, seed=0,
                                 page_bits=args.page_bits)}
    name2 = args.arch2 if args.arch2 != args.arch else args.arch2 + "#2"
    tenants[name2] = _build(args.arch2, args.smoke, args.budget_frac,
                            seed=1, page_bits=args.page_bits)
    cold = sum(plan.paged_bytes(packed_sizes(packed))
               for _c, packed, plan in tenants.values())
    budget = max(int(cold * args.shared_budget_frac), 1)
    ms = MultiScheduler(pool=SharedPagePool(budget) if cold else None,
                        async_io=args.async_io, tracer=tracer)
    for name, (cfg, packed, plan) in tenants.items():
        eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                            max_len=args.max_len, plan=plan,
                            seed=args.seed)
        ms.add_model(name, eng, prefill_chunk=args.prefill_chunk,
                     kv_paged=args.kv_paged and "kv" in eng.cache,
                     kv_block_rows=args.kv_block)
        for sname, kw in STREAMS:
            ms.add_stream(name, sname, **kw)
    names = [s[0] for s in STREAMS]
    for salt, (name, (cfg, _p, _pl)) in enumerate(tenants.items()):
        for req in _tenant_reqs(cfg, args, salt):
            ms.submit(name, req, stream=names[req.uid % len(names)])
    done = ms.run_until_done()
    doc = validate(ms.summary())

    pred_ok = True
    if ms.pool is not None:
        # the unified replay covers weight members AND (under --kv-paged)
        # the <name>/kv page tables contending for the same budget
        pred = kv_pass_counters(
            {name: page_sizes(ms.model(name).engine.pager.pages)
             for name in tenants
             if ms.model(name).engine.pager is not None},
            ms.pool.budget_bytes, events=ms.pool.events)
        pool_models = doc["shared_pool"]["models"]
        pred_ok = all(
            all(pool_models[m][k] == pred[m][k]
                for k in ("swaps", "misses", "pool_hits", "evicted"))
            and pool_models[m]["bytes_streamed_wire"] == pred[m]["bytes_wire"]
            and pool_models[m]["bytes_streamed_raw"] == pred[m]["bytes_raw"]
            for m in pred)

    exact_ok = True
    if args.smoke:
        # bit-exactness vs solo private pagers (smoke only: 2 extra runs)
        for salt, (name, (cfg, packed, plan)) in enumerate(tenants.items()):
            eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                                max_len=args.max_len, plan=plan,
                                seed=args.seed)
            if plan.paged_bytes(packed_sizes(packed)) > 0:
                eng.attach_paging()
            if args.kv_paged and "kv" in eng.cache:
                eng.attach_kv_paging(args.kv_block)
            solo = Scheduler(eng, prefill_chunk=args.prefill_chunk,
                             async_io=args.async_io)
            for sname, kw in STREAMS:
                solo.add_stream(sname, **kw)
            for req in _tenant_reqs(cfg, args, salt):
                solo.submit(req, stream=names[req.uid % len(names)])
            want = {r.uid: r.generated for r in solo.run_until_done()}
            got = {r.uid: r.generated for r in done.get(name, [])}
            exact_ok = exact_ok and (got == want)
            if eng.pager is not None:
                eng.pager.close()
            if eng.kv_table is not None:
                eng.kv_table.close()

    ms.close()
    if not (pred_ok and exact_ok):
        raise SystemExit(
            f"multi-tenant bench invariants violated: "
            f"counters_match={pred_ok} bit_exact={exact_ok}")
    return doc, dict(tenants=list(tenants), shared_budget_bytes=budget,
                     counters_match=pred_ok,
                     bit_exact_vs_solo=exact_ok if args.smoke else None)


def _bench_chaos(args):
    """Chaos leg (``--fault-seed``): the SAME two-tenant pooled run twice
    — fault-free, then under a seeded :class:`FaultPlan` — asserting the
    headline robustness guarantee end to end: bit-exact tokens, retries
    actually absorbed faults, and no corrupted page ever reached compute
    (every checksum failure was caught pre-install and re-fetched)."""

    def run(faults):
        tenants = {args.arch: _build(args.arch, args.smoke,
                                     args.budget_frac, seed=0,
                                     page_bits=args.page_bits)}
        name2 = args.arch2 if args.arch2 != args.arch else args.arch2 + "#2"
        tenants[name2] = _build(args.arch2, args.smoke, args.budget_frac,
                                seed=1, page_bits=args.page_bits)
        cold = sum(plan.paged_bytes(packed_sizes(packed))
                   for _c, packed, plan in tenants.values())
        budget = max(int(cold * args.shared_budget_frac), 1)
        ms = MultiScheduler(pool=SharedPagePool(budget) if cold else None,
                            async_io=args.async_io, faults=faults)
        for name, (cfg, packed, plan) in tenants.items():
            eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                                max_len=args.max_len, plan=plan,
                                seed=args.seed)
            ms.add_model(name, eng, prefill_chunk=args.prefill_chunk,
                         kv_paged=args.kv_paged and "kv" in eng.cache,
                         kv_block_rows=args.kv_block)
        for salt, (name, (cfg, _p, _pl)) in enumerate(tenants.items()):
            for req in _tenant_reqs(cfg, args, salt):
                ms.submit(name, req)
        done = ms.run_until_done()
        doc = validate(ms.summary())
        ms.close()
        toks = {name: {r.uid: r.generated for r in rs}
                for name, rs in done.items()}
        return toks, doc

    base_toks, base_doc = run(None)
    assert all(v == 0 for v in base_doc["totals"]["faults"].values()), \
        "fault-free leg reported nonzero fault counters"
    plan = FaultPlan(seed=args.fault_seed, fail_rate=args.fault_rate,
                     bitflip_rate=args.fault_bitflip, spike_rate=0.05,
                     spike_s=0.0005)
    chaos_toks, doc = run(plan)
    ft = doc["totals"]["faults"]
    bit_exact = chaos_toks == base_toks
    if not bit_exact:
        raise SystemExit("chaos leg: tokens diverged from the fault-free "
                         "run under seeded faults")
    if ft["retries"] <= 0 or ft["checksum_failures"] <= 0:
        raise SystemExit(f"chaos leg exercised too little ({ft}) — it "
                         f"must see at least one retried transient AND "
                         f"one CRC-caught bit-flip; raise the rates or "
                         f"pick a seed that hits the tenants' pages")
    # every corrupted wire payload must have been caught by the page CRC
    # and re-fetched; none may survive to an install (bit-exact tokens
    # above are the end-to-end evidence, this is the ledger-level check)
    if ft["checksum_failures"] != ft["refetches"]:
        raise SystemExit(f"chaos leg: {ft['checksum_failures']} checksum "
                         f"failures but {ft['refetches']} refetches")
    doc["chaos"] = dict(fault_plan=dict(seed=args.fault_seed,
                                        fail_rate=args.fault_rate,
                                        bitflip_rate=args.fault_bitflip,
                                        spike_rate=0.05),
                        bit_exact_vs_fault_free=bit_exact)
    return doc


class _VirtualClock:
    """Deterministic bench time: the drive loop advances a fixed
    ``--tick-ms`` per scheduler tick and every read adds 1 µs so
    intra-tick timestamps stay strictly ordered (and the admission EMAs
    stay nonzero).  Deadline math then measures SCHEDULING decisions —
    who waited how many ticks — not the host machine's jit latency."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        self.now += 1e-6
        return self.now

    def advance(self, seconds):
        self.now += seconds


def _xr_traffic(cfg, args):
    """Open-loop XR trace: a t=0 backlog of long best-effort assistant
    requests plus periodic short hand/gaze tracker invocations.  Returns
    submission events sorted by virtual arrival time."""
    rng = np.random.default_rng(args.seed + 7)
    events, uid = [], 0
    n_per_stream = max(args.xr_requests // 3, 2)
    for _ in range(n_per_stream):
        n = int(rng.integers(16, 48))
        events.append((0.0, "assistant", Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=args.xr_assist_new)))
        uid += 1
    period = args.xr_period_ms / 1e3
    for k in range(n_per_stream):
        for off, stream, lo, hi in ((0.004, "hand_tracking", 4, 9),
                                    (0.006, "gaze", 2, 7)):
            n = int(rng.integers(lo, hi))
            events.append((off + k * period, stream, Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                max_new_tokens=2)))
            uid += 1
    return sorted(events, key=lambda e: (e[0], e[2].uid))


def _run_xr(cfg, packed, plan, args, continuous, tracer=None):
    """Serve the XR trace under one scheduling policy on the virtual
    clock.  ``continuous=False`` is the PR 5 run-to-completion baseline;
    ``continuous=True`` turns on the per-tick token budget, preemption
    and reject-mode admission control."""
    clock = _VirtualClock()
    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan, seed=args.seed)
    if plan.paged_bytes(packed_sizes(packed)) > 0:
        eng.attach_paging()
    sched = Scheduler(eng, prefill_chunk=args.prefill_chunk,
                      async_io=args.async_io, clock=clock,
                      token_budget=args.token_budget if continuous else None,
                      preemptive=continuous,
                      admission="reject" if continuous else None,
                      # pin the admission cost model to the virtual tick
                      # (measured EMAs would mix the engine's REAL stall
                      # seconds into virtual-clock deadline math and
                      # reject nondeterministically under host load)
                      est_tick_s=args.tick_ms / 1e3 if continuous else None,
                      # span timestamps stay on the tracer's wall clock:
                      # the virtual clock only drives deadline math
                      tracer=tracer, trace_track="xr")
    for name, kw in STREAMS:
        sched.add_stream(name, **kw)
    arrivals = deque(_xr_traffic(cfg, args))
    done = []
    while arrivals or sched.pending:
        if not sched.pending and arrivals and arrivals[0][0] > clock.now:
            clock.advance(arrivals[0][0] - clock.now)  # idle gap: jump
        while arrivals and arrivals[0][0] <= clock.now:
            _t, stream, req = arrivals.popleft()
            sched.submit(req, stream=stream)
        done += sched.tick()
        clock.advance(args.tick_ms / 1e3)
    summary = validate(sched.metrics.summary(paging=eng.paging_summary()))
    counters_ok = True
    if eng.pager is not None:
        # preemption must not bend the weight-streaming structure: the
        # runtime counters stay on the static ticks x pass_counters line
        per_pass = pass_counters(len(eng.pager.pages),
                                 eng.page_resident_slots)
        counters_ok = (eng.swap_count == sched.ticks * per_pass["swaps"]
                       and eng.miss_count == sched.ticks * per_pass["misses"])
        eng.pager.close()
    wall = max(summary["throughput"]["wall_s"], 1e-9)
    assist_tok_s = sum(r.n_generated for r in sched.metrics.records
                       if r.stream == "assistant") / wall
    toks = {r.uid: r.generated for r in done}
    return toks, summary, assist_tok_s, counters_ok


def _bench_xr_gate(cfg, packed, plan, args, tracer=None):
    """The headline acceptance gate: continuous batching makes the
    tracker deadlines real (miss_rate <= 0.05) without costing the
    assistant more than 10% throughput, changing a single token, or
    bending the paging counters off their static prediction."""
    base_toks, base, base_assist, base_ok = _run_xr(
        cfg, packed, plan, args, continuous=False)
    # only the continuous leg is traced: it is the run with preempt /
    # restore / reject traffic worth looking at on a timeline
    cont_toks, cont, cont_assist, cont_ok = _run_xr(
        cfg, packed, plan, args, continuous=True, tracer=tracer)
    trackers = ("hand_tracking", "gaze")
    miss = max(cont["streams"][s]["miss_rate"] for s in trackers
               if s in cont["streams"])
    base_miss = max(base["streams"][s]["miss_rate"] for s in trackers
                    if s in base["streams"])
    tok_ratio = cont_assist / max(base_assist, 1e-9)
    bit_exact = (base_toks.keys() == cont_toks.keys()
                 and all(base_toks[u] == cont_toks[u] for u in base_toks))
    gate = dict(deadline_miss_rate=miss,
                baseline_miss_rate=base_miss,
                assistant_tok_ratio=tok_ratio,
                preemptions=cont["scheduler"]["preemptions"],
                restores=cont["scheduler"]["restores"],
                rejected=cont["scheduler"]["rejected"],
                bit_exact=bit_exact,
                counters_match=base_ok and cont_ok)
    ok = (miss <= 0.05 and tok_ratio >= 0.90 and bit_exact
          and gate["counters_match"] and gate["preemptions"] > 0)
    if not ok:
        raise SystemExit(f"XR deadline gate failed: {gate}")
    return dict(baseline=base, continuous=cont, gate=gate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--arch2", default="falcon-mamba-7b",
                    help="second tenant for the multi-model section "
                         "(dense LM + SSM tracker by default)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--budget-frac", type=float, default=0.5,
                    help="resident budget as a fraction of the packed "
                         "store (the §II-B2 pressure knob)")
    ap.add_argument("--page-bits", type=int, default=None,
                    choices=(2, 4, 8),
                    help="stream cold pages ENCODED (blockwise intN "
                         "payload + scales, dequantized at fetch) instead "
                         "of the packed device format; with the bench's "
                         "int8 store, --page-bits 8 is the zero-decode "
                         "identity whose wire/raw ratio the bench gates "
                         "at <= 0.3 (>= 3.5x vs fp32 dense)")
    ap.add_argument("--shared-budget-frac", type=float, default=0.6,
                    help="SharedPagePool budget as a fraction of the "
                         "tenants' combined cold bytes (the cross-model "
                         "contention knob)")
    ap.add_argument("--kv-paged", action="store_true",
                    help="page the per-slot KV cache through the same "
                         "budgeted stream as the weights (single model: "
                         "private table; tenants: <name>/kv pool members)")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="KV page size in cache rows")
    ap.add_argument("--token-budget", type=int, default=96,
                    help="per-tick token budget for the continuous-"
                         "batching leg of the XR deadline gate")
    ap.add_argument("--xr-requests", type=int, default=60,
                    help="XR-gate trace length (requests across the 3 "
                         "streams); long enough that the preemption "
                         "tail raggedness amortizes out of the "
                         "assistant-throughput ratio")
    ap.add_argument("--tick-ms", type=float, default=1.0,
                    help="virtual-clock advance per tick in the XR gate")
    ap.add_argument("--xr-period-ms", type=float, default=6.0,
                    help="tracker invocation period in the XR trace")
    ap.add_argument("--xr-assist-new", type=int, default=24,
                    help="assistant decode length in the XR trace (long "
                         "enough that run-to-completion blows the "
                         "tracker deadlines)")
    ap.add_argument("--no-xr-gate", action="store_true",
                    help="skip the XR deadline-gate section")
    io = ap.add_mutually_exclusive_group()
    io.add_argument("--async-io", dest="async_io", action="store_true",
                    default=True,
                    help="overlapped page streaming (default)")
    io.add_argument("--sync-io", dest="async_io", action="store_false",
                    help="blocking stream-then-step ticks (the overlap "
                         "baseline CI compares against)")
    ap.add_argument("--trace-json", default=None,
                    help="record the whole bench (solo leg, tenants, "
                         "continuous XR-gate leg) as ONE Chrome Trace "
                         "Event JSON at this path; open in "
                         "chrome://tracing or ui.perfetto.dev")
    ap.add_argument("--wire-serve", action="store_true",
                    help="solo leg: int4 device store whose cold pages "
                         "are re-encoded int8 and served straight from "
                         "the wire form by the blockscale matmul (no "
                         "fetch decode); incompatible with --page-bits")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="run the chaos leg: repeat the two-tenant run "
                         "under a FaultPlan with this seed and assert "
                         "bit-exact tokens vs the fault-free leg "
                         "(writes BENCH_serving_chaos.json)")
    ap.add_argument("--fault-rate", type=float, default=0.15,
                    help="chaos leg transient fetch-failure probability")
    ap.add_argument("--fault-bitflip", type=float, default=0.15,
                    help="chaos leg wire bit-flip probability")
    ap.add_argument("--chaos-out", default="BENCH_serving_chaos.json")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.wire_serve and args.page_bits is not None:
        ap.error("--wire-serve fixes the page encoding (int8 over an "
                 "int4 store); drop --page-bits")

    cfg, packed, plan = _build(args.arch, args.smoke, args.budget_frac,
                               seed=0, page_bits=args.page_bits,
                               wire_serve=args.wire_serve)
    sizes = packed_sizes(packed)
    budget = int(sum(sizes.values()) * args.budget_frac)
    print(plan.summary(sizes))

    tracer = Tracer() if args.trace_json else None
    eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                        max_len=args.max_len, plan=plan, seed=args.seed)
    if plan.paged_bytes(sizes) > 0:
        eng.attach_paging(wire_serve=args.wire_serve)
    if args.kv_paged:
        eng.attach_kv_paging(args.kv_block)
    # the solo leg runs under the SAME continuous-batching token budget
    # as the XR gate — without it the wall-clock deadline numbers here
    # are run-to-completion artifacts (miss_rate 1.0, TTFTs dominated by
    # jit compile) that read like regressions next to the gate's
    sched = Scheduler(eng, prefill_chunk=args.prefill_chunk,
                      async_io=args.async_io,
                      token_budget=args.token_budget,
                      tracer=tracer, trace_track=f"solo:{args.arch}")
    for name, kw in STREAMS:
        sched.add_stream(name, **kw)

    names = [s[0] for s in STREAMS]
    for req in _tenant_reqs(cfg, args, 0):
        sched.submit(req, stream=names[req.uid % len(names)])

    done = sched.run_until_done()
    summary = validate(sched.metrics.summary(paging=eng.paging_summary(),
                                             trace=sched.trace_summary()))
    if args.async_io and eng.pager is not None:
        # the overlapped pipeline must actually hide stream time behind
        # compute (the first tick's demand fence is the only fully
        # exposed pass) — the CI acceptance gate for the async path
        assert summary["paging"]["overlap_frac"] > 0.0, \
            "async run hid no paging stall (overlap_frac == 0)"
        assert summary["paging"]["hidden_s"] > 0.0
    if args.kv_paged:
        assert summary["paging"]["kv_swaps"] > 0, "no KV blocks streamed"
        assert summary["paging"]["kv_writebacks"] > 0
    if args.page_bits is not None and eng.pager is not None:
        # the compression acceptance gate: encoded cold pages must
        # actually shrink the link traffic relative to fp32 dense
        wire = summary["paging"]["bytes_streamed_wire"]
        raw = summary["paging"]["bytes_streamed_raw"]
        assert wire > 0 and raw > 0, "encoded paging streamed no bytes"
        if args.page_bits == 8:
            assert wire / raw <= 0.3, \
                f"int8 pages wire/raw {wire / raw:.3f} exceeds 0.3"
            assert raw / wire >= 3.5, \
                f"int8 pages compress only {raw / wire:.2f}x (< 3.5x)"
    if args.kv_paged and args.smoke:
        # KV paging must change WHERE cache rows live, never the tokens:
        # re-serve the same traffic on the resident-KV engine and compare
        ref_eng = ServingEngine(cfg, packed, batch_slots=args.slots,
                                max_len=args.max_len, plan=plan,
                                seed=args.seed)
        if plan.paged_bytes(sizes) > 0:
            ref_eng.attach_paging()
        # same token budget as the paged run so the schedules line up
        # tick for tick, not just token for token
        ref_sched = Scheduler(ref_eng, prefill_chunk=args.prefill_chunk,
                              async_io=args.async_io,
                              token_budget=args.token_budget)
        for name, kw in STREAMS:
            ref_sched.add_stream(name, **kw)
        for req in _tenant_reqs(cfg, args, 0):
            ref_sched.submit(req, stream=names[req.uid % len(names)])
        ref_done = ref_sched.run_until_done()
        assert ({r.uid: r.generated for r in done}
                == {r.uid: r.generated for r in ref_done}), \
            "kv-paged tokens diverged from the resident-KV engine"
        if ref_eng.pager is not None:
            ref_eng.pager.close()

    tick_overhead = None
    if eng.pager is not None:
        # satellite micro-bench: cached thread-template threading vs the
        # old per-tick full-tree rebuild (one extra pass is streamed for
        # the probe, AFTER the counters above were recorded)
        import time as _time
        from repro.core.paging import thread_packed
        dev = eng.pager.begin_pass(eng.page_resident_slots).fence()
        reps = 20
        t0 = _time.perf_counter()
        for _ in range(reps):
            eng._thread_tick(dev)
        cached_us = (_time.perf_counter() - t0) / reps * 1e6
        t0 = _time.perf_counter()
        for _ in range(reps):
            thread_packed(eng.params, dev)
        rebuild_us = (_time.perf_counter() - t0) / reps * 1e6
        tick_overhead = dict(thread_cached_us=cached_us,
                             thread_rebuild_us=rebuild_us,
                             speedup=rebuild_us / max(cached_us, 1e-9))
    page_decode = None
    if eng.pager is not None:
        # satellite micro-bench: fetch-side page decode (unpack intN ->
        # blockwise dequant -> requantize -> repack for re-encoded pages;
        # a passthrough for fp/identity encodings).  Host-side numpy only,
        # the cost the streaming pipeline pays per parameter per swap.
        import time as _time
        host = list(eng.pager._host.items())
        reps = 5
        t0 = _time.perf_counter()
        for _ in range(reps):
            for _name, hp in host:
                hp.decode()
        decode_us = ((_time.perf_counter() - t0)
                     / max(reps * len(host), 1) * 1e6)
        page_decode = dict(
            decode_us_per_param=decode_us, params=len(host),
            encoding=("int8" if args.wire_serve
                      else "fp" if args.page_bits is None
                      else f"int{args.page_bits}"),
            decode_s_in_run=eng.pager.decode_s,
            # wire-serve: wire bytes that never paid the decode above
            # (served straight to the blockscale matmul)
            decode_skipped_bytes=eng.pager.decode_skipped_bytes,
            bytes_streamed_wire=eng.pager.bytes_streamed_wire,
            bytes_streamed_raw=eng.pager.bytes_streamed_raw)
        if args.wire_serve:
            assert eng.pager.decode_skipped_bytes > 0, \
                "--wire-serve streamed every page through the decode path"
            assert eng.pager.decode_s == 0.0, \
                "--wire-serve still paid fetch decode time"
    if eng.pager is not None:
        eng.pager.close()
    if eng.kv_table is not None:
        eng.kv_table.close()

    # disabled-tracer overhead gate: the tracer= hook must cost nothing
    # when tracing is off — time the enabled=False no-op fast path the
    # hot tick takes on every untraced run and hold it under 5 us/call
    off = Tracer(enabled=False)
    reps = 10_000
    with Stopwatch() as sw:
        for i in range(reps):
            with off.span("tick", track="bench", i=i):
                pass
            off.instant("mark", track="bench")
    tracer_disabled_us = sw.elapsed_s / (2 * reps) * 1e6
    assert tracer_disabled_us < 5.0, \
        f"disabled tracer costs {tracer_disabled_us:.2f} us/call on the " \
        f"tick path (no-op budget is 5 us)"
    assert off.event_count == 0, "disabled tracer recorded events"
    tick_overhead = dict(tick_overhead or {},
                         tracer_disabled_us=tracer_disabled_us)

    multi_doc, multi_cfg = _bench_multi(args, tracer=tracer)
    multi_doc["single_model"] = summary
    multi_doc["tick_overhead"] = tick_overhead
    multi_doc["page_decode"] = page_decode
    xr = (None if args.no_xr_gate
          else _bench_xr_gate(cfg, packed, plan, args, tracer=tracer))
    multi_doc["xr_gate"] = xr
    multi_doc["config"] = dict(arch=cfg.name, smoke=args.smoke,
                               requests=args.requests, slots=args.slots,
                               budget_bytes=budget,
                               prefill_chunk=sched.prefill_chunk,
                               async_io=args.async_io,
                               kv_paged=args.kv_paged,
                               kv_block=args.kv_block,
                               token_budget=args.token_budget,
                               page_bits=args.page_bits,
                               tick_ms=args.tick_ms,
                               xr_requests=args.xr_requests,
                               # the solo leg serves on the WALL clock, so
                               # its deadline/TTFT numbers absorb jit
                               # compile; the virtual-clock xr_gate is the
                               # deadline-meaningful section
                               solo=dict(clock="wall",
                                         token_budget=args.token_budget,
                                         admission=None, preemptive=False),
                               traced=tracer is not None,
                               multi=multi_cfg)
    validate(multi_doc)
    import json
    with open(args.out, "w") as fh:
        json.dump(multi_doc, fh, indent=2)
        fh.write("\n")
    if tracer is not None:
        validate_trace(tracer.to_dict())
        tracer.write(args.trace_json)

    thr, dl, ticks = (summary["throughput"], summary["deadlines"],
                      summary["ticks"])
    # harness contract: name,us_per_call,derived
    print(f"serving_tick,{ticks['latency_ms']['p50'] * 1e3:.2f},"
          f"p99_ms={ticks['latency_ms']['p99']:.2f}")
    pg = summary["paging"]
    print(f"serving_load,{1e6 / max(thr['tok_per_s'], 1e-9):.2f},"
          f"tok_per_s={thr['tok_per_s']:.1f}"
          f";miss_rate={dl['miss_rate']:.3f}"
          f";swaps={pg['swap_count']}"
          f";exposed_ms={pg['exposed_s'] * 1e3:.2f}"
          f";hidden_ms={pg['hidden_s'] * 1e3:.2f}"
          f";overlap={pg['overlap_frac']:.3f}")
    if args.kv_paged:
        print(f"serving_kv_paging,{pg['kv_swaps']},"
              f"kv_pool_hits={pg['kv_pool_hits']}"
              f";kv_writebacks={pg['kv_writebacks']}"
              f";kv_dropped={pg['kv_dropped']}"
              f";kv_exposed_ms={pg['kv_exposed_s'] * 1e3:.2f}"
              f";kv_hidden_ms={pg['kv_hidden_s'] * 1e3:.2f}")
    if page_decode is not None:
        pd = page_decode
        ratio = (pd["bytes_streamed_raw"] / pd["bytes_streamed_wire"]
                 if pd["bytes_streamed_wire"] else 1.0)
        print(f"serving_page_decode,{pd['decode_us_per_param']:.2f},"
              f"encoding={pd['encoding']}"
              f";params={pd['params']}"
              f";decode_ms_in_run={pd['decode_s_in_run'] * 1e3:.2f}"
              f";decode_skipped_bytes={pd['decode_skipped_bytes']}"
              f";wire_bytes={pd['bytes_streamed_wire']}"
              f";raw_bytes={pd['bytes_streamed_raw']}"
              f";compression={ratio:.2f}x")
    if "thread_cached_us" in tick_overhead:
        print(f"serving_thread_cache,{tick_overhead['thread_cached_us']:.2f},"
              f"rebuild_us={tick_overhead['thread_rebuild_us']:.2f}"
              f";speedup={tick_overhead['speedup']:.1f}x")
    print(f"serving_tracer_off,{tick_overhead['tracer_disabled_us']:.3f},"
          f"budget_us=5.0")
    if tracer is not None:
        tr = summary["trace"]
        print(f"serving_trace,{tracer.event_count},"
              f"tracks={len(tracer.track_names)}"
              f";pred_vs_meas={tr['predicted_vs_measured_stall_ratio']:.3f}"
              f";path={args.trace_json}")
    if xr is not None:
        g = xr["gate"]
        print(f"serving_xr_gate,{g['deadline_miss_rate']:.3f},"
              f"baseline_miss={g['baseline_miss_rate']:.3f}"
              f";assistant_tok_ratio={g['assistant_tok_ratio']:.3f}"
              f";preemptions={g['preemptions']}"
              f";restores={g['restores']}"
              f";rejected={g['rejected']}"
              f";bit_exact={g['bit_exact']}"
              f";counters_match={g['counters_match']}")
    tot = multi_doc["totals"]
    pool = multi_doc["shared_pool"]
    print(f"serving_tenancy,{1e6 / max(tot['tok_per_s'], 1e-9):.2f},"
          f"tok_per_s={tot['tok_per_s']:.1f}"
          f";models={len(multi_doc['models'])}"
          f";evictions={pool.get('evictions', 0)}"
          f";counters_match={multi_cfg['counters_match']}"
          f";bit_exact={multi_cfg['bit_exact_vs_solo']}")
    if args.fault_seed is not None:
        chaos_doc = _bench_chaos(args)
        with open(args.chaos_out, "w") as fh:
            json.dump(chaos_doc, fh, indent=2)
            fh.write("\n")
        cf = chaos_doc["totals"]["faults"]
        print(f"serving_chaos,{cf['injected']},"
              f"retries={cf['retries']}"
              f";checksum_failures={cf['checksum_failures']}"
              f";refetches={cf['refetches']}"
              f";fetch_timeouts={cf['fetch_timeouts']}"
              f";deferred_ticks={cf['deferred_ticks']}"
              f";bit_exact={chaos_doc['chaos']['bit_exact_vs_fault_free']}"
              f";out={args.chaos_out}")
    print(f"served {len(done)} single-model + {tot['requests']} tenant "
          f"requests over {sched.ticks} ticks; metrics -> {args.out}")
    return multi_doc


if __name__ == "__main__":
    main()
